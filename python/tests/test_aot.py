"""AOT lowering smoke tests: every entry point lowers to parseable HLO
text, the manifest matches, and a lowered module evaluates identically to
the eager model (via jax's own HLO round-trip of the same computation)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_entry_point_inventory():
    eps = aot.entry_points()
    names = [n for n, _, _ in eps]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for r in aot.RANKS:
        for n in aot.ARITIES:
            assert f"mttkrp{n}_b{aot.BLOCK}_r{r}" in names
        assert f"gram_t{aot.GRAM_TILE}_r{r}" in names
        assert f"factor_update_b{aot.BLOCK}_r{r}" in names


def test_shape_format():
    s = aot._fmt(jax.ShapeDtypeStruct((1024, 16), jnp.float32))
    assert s == "f32[1024,16]"
    s = aot._fmt(jax.ShapeDtypeStruct((8,), jnp.int32))
    assert s == "s32[8]"


def test_lower_one_entry_produces_hlo_text():
    name, fn, args = aot.entry_points()[0]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root must be a tuple
    assert "tuple(" in text.replace(" ", "") or "tuple" in text


def test_lower_all_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    aot.lower_all(str(out))
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(aot.entry_points())
    for line in manifest:
        name, fname, ins, outs = line.split("\t")
        assert (out / fname).exists(), fname
        assert ins.startswith("in=") and outs.startswith("out=")
        head = (out / fname).read_text()[:200]
        assert "HloModule" in head


def test_lowered_mttkrp3_numerics_roundtrip():
    """Execute the jitted entry point at the AOT shapes and compare with
    the eager model — guards against lowering-time shape/dtype drift."""
    rng = np.random.default_rng(0)
    b, r = aot.BLOCK, 16
    vals = rng.standard_normal(b).astype(np.float32)
    seg = rng.integers(0, b, b).astype(np.int32)
    f1 = rng.standard_normal((b, r)).astype(np.float32)
    f2 = rng.standard_normal((b, r)).astype(np.float32)
    import functools

    fn = functools.partial(model.mttkrp_block_3, num_segments=b)
    got = np.asarray(jax.jit(fn)(vals, seg, f1, f2))
    want = np.asarray(fn(vals, seg, f1, f2))
    np.testing.assert_allclose(got, want, rtol=1e-6)
