"""Layer-2 model tests: block MTTKRP vs a dense einsum oracle, shapes,
segment handling, and the CP-ALS helper algebra."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import mttkrp as k
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("ci")

B = k.ROW_TILE  # one-tile blocks keep the sweep fast


def dense_mttkrp_mode0(dense, factors):
    """Oracle: full dense MTTKRP for mode 0 via einsum (3-mode)."""
    b, c = factors
    return np.einsum("ijk,jr,kr->ir", dense, b, c)


@hypothesis.given(
    dims=st.tuples(st.integers(2, 8), st.integers(2, 8), st.integers(2, 8)),
    rank=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_mttkrp_equals_dense_einsum(dims, rank, seed):
    """Scatter a random sparse tensor into a block and compare to einsum."""
    rng = np.random.default_rng(seed)
    i0, i1, i2 = dims
    nnz = min(B, i0 * i1 * i2 // 2 + 1)
    coords = np.stack(
        [rng.integers(0, d, size=nnz) for d in dims], axis=1
    )
    vals = rng.standard_normal(nnz).astype(np.float32)
    dense = np.zeros(dims, np.float32)
    for (a, b_, c), v in zip(coords, vals):
        dense[a, b_, c] += v
    fb = rng.standard_normal((i1, rank)).astype(np.float32)
    fc = rng.standard_normal((i2, rank)).astype(np.float32)

    # pad the block to B
    pv = np.zeros(B, np.float32)
    pv[:nnz] = vals
    seg = np.zeros(B, np.int32)  # padding rows scatter into segment 0 with v=0
    seg[:nnz] = coords[:, 0]
    g1 = np.zeros((B, rank), np.float32)
    g2 = np.zeros((B, rank), np.float32)
    g1[:nnz] = fb[coords[:, 1]]
    g2[:nnz] = fc[coords[:, 2]]

    out = np.asarray(
        model.mttkrp_block(jnp.asarray(pv), jnp.asarray(seg), jnp.asarray(g1), jnp.asarray(g2), num_segments=B)
    )
    want = dense_mttkrp_mode0(dense, (fb, fc))
    np.testing.assert_allclose(out[:i0], want, rtol=1e-4, atol=1e-4)
    # rows beyond i0 untouched
    assert np.all(out[i0:] == 0.0)


def test_block_matches_ref_composition():
    rng = np.random.default_rng(1)
    vals = rng.standard_normal(B).astype(np.float32)
    seg = rng.integers(0, 50, B).astype(np.int32)
    f1 = rng.standard_normal((B, 16)).astype(np.float32)
    f2 = rng.standard_normal((B, 16)).astype(np.float32)
    got = np.asarray(model.mttkrp_block(vals, seg, f1, f2, num_segments=B))
    want = np.asarray(ref.mttkrp_block_ref(jnp.asarray(vals), jnp.asarray(seg), B, jnp.asarray(f1), jnp.asarray(f2)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_arity_wrappers_shapes():
    rng = np.random.default_rng(2)
    vals = rng.standard_normal(B).astype(np.float32)
    seg = np.zeros(B, np.int32)
    fs = [rng.standard_normal((B, 16)).astype(np.float32) for _ in range(4)]
    o3 = model.mttkrp_block_3(vals, seg, *fs[:2], num_segments=B)
    o4 = model.mttkrp_block_4(vals, seg, *fs[:3], num_segments=B)
    o5 = model.mttkrp_block_5(vals, seg, *fs[:4], num_segments=B)
    for o in (o3, o4, o5):
        assert o.shape == (B, 16)
        assert o.dtype == jnp.float32


def test_linearity_in_values():
    """MTTKRP is linear in tensor values: f(2v) = 2 f(v)."""
    rng = np.random.default_rng(3)
    vals = rng.standard_normal(B).astype(np.float32)
    seg = rng.integers(0, 10, B).astype(np.int32)
    f1 = rng.standard_normal((B, 8)).astype(np.float32)
    a = np.asarray(model.mttkrp_block(vals, seg, f1, num_segments=B))
    b = np.asarray(model.mttkrp_block(2 * vals, seg, f1, num_segments=B))
    np.testing.assert_allclose(b, 2 * a, rtol=1e-5)


def test_permutation_invariance_within_block():
    """Reordering nonzeros inside a block cannot change the output."""
    rng = np.random.default_rng(4)
    vals = rng.standard_normal(B).astype(np.float32)
    seg = rng.integers(0, 33, B).astype(np.int32)
    f1 = rng.standard_normal((B, 16)).astype(np.float32)
    f2 = rng.standard_normal((B, 16)).astype(np.float32)
    perm = rng.permutation(B)
    a = np.asarray(model.mttkrp_block(vals, seg, f1, f2, num_segments=B))
    b = np.asarray(
        model.mttkrp_block(vals[perm], seg[perm], f1[perm], f2[perm], num_segments=B)
    )
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_hadamard_grams_and_factor_update_algebra():
    rng = np.random.default_rng(5)
    g = rng.standard_normal((3, 16, 16)).astype(np.float32)
    hg = np.asarray(model.hadamard_grams(jnp.asarray(g)))
    np.testing.assert_allclose(hg, g[0] * g[1] * g[2], rtol=1e-6)
    rows = rng.standard_normal((B, 16)).astype(np.float32)
    upd = np.asarray(model.factor_update(rows, np.eye(16, dtype=np.float32)))
    np.testing.assert_allclose(upd, rows, rtol=1e-6)


def test_model_jit_stability():
    rng = np.random.default_rng(6)
    vals = rng.standard_normal(B).astype(np.float32)
    seg = rng.integers(0, 5, B).astype(np.int32)
    f1 = rng.standard_normal((B, 16)).astype(np.float32)
    f2 = rng.standard_normal((B, 16)).astype(np.float32)
    fn = jax.jit(lambda v, s, a, b: model.mttkrp_block(v, s, a, b, num_segments=B))
    np.testing.assert_allclose(
        np.asarray(fn(vals, seg, f1, f2)),
        np.asarray(model.mttkrp_block(vals, seg, f1, f2, num_segments=B)),
        rtol=1e-6,
    )
