"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps block shapes, ranks, factor counts, value ranges and
dtypes; every case asserts allclose between the Pallas kernel (interpret
mode — identical numerics to what the rust runtime executes) and ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import mttkrp as k
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand(rng, *shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# scaled_hadamard
# ---------------------------------------------------------------------------


@hypothesis.given(
    tiles=st.integers(1, 4),
    rank=st.sampled_from([4, 8, 16, 32]),
    n_factors=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_scaled_hadamard_matches_ref(tiles, rank, n_factors, seed):
    rng = np.random.default_rng(seed)
    b = tiles * k.ROW_TILE
    vals = rand(rng, b)
    factors = [rand(rng, b, rank) for _ in range(n_factors)]
    got = k.scaled_hadamard(vals, *factors)
    want = ref.scaled_hadamard_ref(jnp.asarray(vals), *map(jnp.asarray, factors))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@hypothesis.given(scale=st.sampled_from([1e-6, 1.0, 1e6]), seed=st.integers(0, 2**31 - 1))
def test_scaled_hadamard_value_ranges(scale, seed):
    rng = np.random.default_rng(seed)
    b = k.ROW_TILE
    vals = rand(rng, b, scale=scale)
    f1 = rand(rng, b, 16)
    f2 = rand(rng, b, 16)
    got = np.asarray(k.scaled_hadamard(vals, f1, f2))
    want = np.asarray(ref.scaled_hadamard_ref(jnp.asarray(vals), jnp.asarray(f1), jnp.asarray(f2)))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_scaled_hadamard_accepts_f64_inputs_downcasting():
    rng = np.random.default_rng(0)
    b = k.ROW_TILE
    vals = rand(rng, b, dtype=np.float64)
    f1 = rand(rng, b, 8, dtype=np.float64)
    got = np.asarray(k.scaled_hadamard(vals, f1))
    assert got.dtype == np.float32
    want = (vals[:, None] * f1).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_scaled_hadamard_rejects_ragged_block():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        k.scaled_hadamard(rand(rng, 100), rand(rng, 100, 16))


def test_scaled_hadamard_zero_vals_zero_out():
    rng = np.random.default_rng(1)
    b = k.ROW_TILE
    got = np.asarray(k.scaled_hadamard(np.zeros(b, np.float32), rand(rng, b, 16)))
    assert np.all(got == 0.0)


# ---------------------------------------------------------------------------
# gram_tile
# ---------------------------------------------------------------------------


@hypothesis.given(
    tiles=st.integers(1, 4),
    rank=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(tiles, rank, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, tiles * k.ROW_TILE, rank)
    got = np.asarray(k.gram_tile(f))
    want = np.asarray(ref.gram_ref(jnp.asarray(f)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(3)
    g = np.asarray(k.gram_tile(rand(rng, k.ROW_TILE, 16)))
    np.testing.assert_allclose(g, g.T, rtol=1e-6)
    evals = np.linalg.eigvalsh(g)
    assert evals.min() > -1e-3


# ---------------------------------------------------------------------------
# row_matmul
# ---------------------------------------------------------------------------


@hypothesis.given(
    tiles=st.integers(1, 3),
    rank=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_matmul_matches_ref(tiles, rank, seed):
    rng = np.random.default_rng(seed)
    rows = rand(rng, tiles * k.ROW_TILE, rank)
    m = rand(rng, rank, rank)
    got = np.asarray(k.row_matmul(rows, m))
    want = np.asarray(ref.row_matmul_ref(jnp.asarray(rows), jnp.asarray(m)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_row_matmul_identity_is_noop():
    rng = np.random.default_rng(5)
    rows = rand(rng, k.ROW_TILE, 16)
    got = np.asarray(k.row_matmul(rows, np.eye(16, dtype=np.float32)))
    np.testing.assert_allclose(got, rows, rtol=1e-6)


# ---------------------------------------------------------------------------
# kernels under jit (the exact path the AOT lowering takes)
# ---------------------------------------------------------------------------


def test_kernels_jit_and_grad_safe():
    rng = np.random.default_rng(7)
    b = k.ROW_TILE
    vals, f1, f2 = rand(rng, b), rand(rng, b, 16), rand(rng, b, 16)
    jitted = jax.jit(lambda v, a, c: k.scaled_hadamard(v, a, c))
    np.testing.assert_allclose(
        np.asarray(jitted(vals, f1, f2)),
        np.asarray(k.scaled_hadamard(vals, f1, f2)),
        rtol=1e-6,
    )
