"""AOT lowering: JAX/Pallas model → HLO text artifacts for the rust runtime.

Run via ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every entry point is lowered at fixed shapes (PJRT compiles one executable
per artifact) and recorded in ``manifest.txt`` as tab-separated
``name\tfile\tin=<dtype[shape],...>\tout=<dtype[shape]>`` lines the rust
`runtime::artifacts` module parses.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# The paper's block/rank geometry: block = psum buffer (Table I),
# R = 16 (§V-A2). R = 32 variants exercise the rank ablation. The 4096
# block amortizes PJRT dispatch overhead 4x on the rust hot path (§Perf);
# the rust blocking layer picks the largest block the manifest offers.
BLOCK = 1024
BLOCKS = (1024, 4096)
RANKS = (16, 32)
ARITIES = (3, 4, 5)  # tensor mode counts of Table II
GRAM_TILE = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt(spec) -> str:
    d = {"float32": "f32", "int32": "s32"}[str(spec.dtype)]
    dims = ",".join(str(x) for x in spec.shape)
    return f"{d}[{dims}]"


def entry_points():
    """(name, fn, arg_specs) for every artifact."""
    eps = []
    for r in RANKS:
        for n in ARITIES:
            n_factors = n - 1
            for block in BLOCKS:
                fn = {
                    3: functools.partial(model.mttkrp_block_3, num_segments=block),
                    4: functools.partial(model.mttkrp_block_4, num_segments=block),
                    5: functools.partial(model.mttkrp_block_5, num_segments=block),
                }[n]
                args = [_spec((block,)), _spec((block,), jnp.int32)] + [
                    _spec((block, r)) for _ in range(n_factors)
                ]
                eps.append((f"mttkrp{n}_b{block}_r{r}", fn, args))
                # scatter-free variant: the L1 product kernel alone; the
                # rust coordinator performs the segment accumulation
                # (§Perf: XLA-CPU scatter dominates the fused variant's
                # dispatch cost and scales super-linearly in block size)
                hargs = [_spec((block,))] + [_spec((block, r)) for _ in range(n_factors)]
                eps.append(
                    (f"hadamard{n}_b{block}_r{r}", model.scaled_hadamard_block, hargs)
                )
        eps.append((f"gram_t{GRAM_TILE}_r{r}", model.gram, [_spec((GRAM_TILE, r))]))
        eps.append(
            (
                f"factor_update_b{BLOCK}_r{r}",
                model.factor_update,
                [_spec((BLOCK, r)), _spec((r, r))],
            )
        )
        for k in (2, 3, 4):
            eps.append(
                (f"hadamard_grams{k}_r{r}", model.hadamard_grams, [_spec((k, r, r))])
            )
    return eps


def lower_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in entry_points():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        out_spec = jax.eval_shape(fn, *args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        ins = ",".join(_fmt(a) for a in args)
        manifest_lines.append(f"{name}\t{fname}\tin={ins}\tout={_fmt(out_spec)}")
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {out_dir}/manifest.txt")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    lower_all(args.out_dir)


if __name__ == "__main__":
    main()
