"""Layer-1 Pallas kernels for the spMTTKRP compute hot-spot.

These kernels express the PE datapath of the paper's accelerator (Fig. 4)
in Pallas for TPU-class hardware — see DESIGN.md §Hardware-Adaptation:

* the paper's 80 electrical rank-16 pipelines map to the VPU lanes of a
  (block × R) tile: ``scaled_hadamard`` is the elementwise
  ``x × B(i1,:) × C(i2,:)`` of Algorithm 1 over a whole block of nonzeros;
* the partial-sum buffer maps to the accumulation tile of
  ``mttkrp_block`` (product + in-kernel segment accumulation);
* the CP-ALS gram matrix ``Fᵀ F`` is the only matmul-shaped op and maps
  to the MXU via ``gram_tile`` / ``row_matmul``.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin the
rust runtime uses cannot execute Mosaic custom-calls, and interpret-mode
lowers to plain HLO with identical numerics (the TPU mapping is an
estimate documented in DESIGN.md §9). VMEM budgeting: the default
block=1024, R=16 tiles keep ≤ 5 f32 operands of 64 KiB each in VMEM —
~320 KiB, far under a TensorCore's ~16 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block of nonzeros processed per kernel invocation; 1024 matches
# the paper's psum-buffer sizing (Table I).
DEFAULT_BLOCK = 1024
# Sub-tile the grid walks; 256 rows keeps every ref a few KiB.
ROW_TILE = 256


def _hadamard_kernel(n_factors, vals_ref, *refs):
    """o = vals[:, None] * f0 * f1 * ...  (refs = factor refs + out ref)."""
    out_ref = refs[n_factors]
    acc = vals_ref[...][:, None] * refs[0][...]
    for k in range(1, n_factors):
        acc = acc * refs[k][...]
    out_ref[...] = acc


def scaled_hadamard(vals, *factors, row_tile=ROW_TILE):
    """Pallas: ``out[b, r] = vals[b] * prod_k factors[k][b, r]``.

    `vals`: f32[B]; each factor: f32[B, R]. B must be a multiple of
    `row_tile` (the AOT wrapper pads). Grid walks B in `row_tile` chunks —
    the same HBM→VMEM streaming schedule the paper's DMA performs into the
    PE pipelines.
    """
    b, r = factors[0].shape
    assert b % row_tile == 0, f"block {b} not a multiple of {row_tile}"
    n = len(factors)
    grid = (b // row_tile,)
    kernel = functools.partial(_hadamard_kernel, n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile,), lambda i: (i,))]
        + [pl.BlockSpec((row_tile, r), lambda i: (i, 0)) for _ in range(n)],
        out_specs=pl.BlockSpec((row_tile, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(vals.astype(jnp.float32), *[f.astype(jnp.float32) for f in factors])


def _gram_kernel(f_ref, o_ref):
    """Accumulating Fᵀ F over the row-tile grid (MXU-shaped contraction)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = f_ref[...]
    # fp32 accumulation on the MXU (preferred_element_type pins the
    # accumulator precision like the hardware's 32-bit accumulators).
    o_ref[...] += jax.lax.dot_general(
        tile,
        tile,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def gram_tile(f, row_tile=ROW_TILE):
    """Pallas: ``G = Fᵀ F`` for a factor tile f32[I, R] (CP-ALS grams)."""
    i, r = f.shape
    assert i % row_tile == 0, f"tile rows {i} not a multiple of {row_tile}"
    return pl.pallas_call(
        _gram_kernel,
        grid=(i // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, r), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((r, r), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(f.astype(jnp.float32))


def _row_matmul_kernel(rows_ref, m_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        rows_ref[...],
        m_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def row_matmul(rows, m, row_tile=ROW_TILE):
    """Pallas: ``out = rows @ m`` — the factor update ``MTTKRP @ inv``."""
    b, r = rows.shape
    r2, r3 = m.shape
    assert r == r2 == r3, "square RxR update matrix expected"
    assert b % row_tile == 0
    return pl.pallas_call(
        _row_matmul_kernel,
        grid=(b // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(rows.astype(jnp.float32), m.astype(jnp.float32))
