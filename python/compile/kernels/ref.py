"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in `mttkrp.py` has a reference implementation here
written with nothing but `jax.numpy` ops; pytest asserts allclose between
the two across shapes and dtypes (see python/tests/test_kernels.py).
"""

import jax
import jax.numpy as jnp


def scaled_hadamard_ref(vals, *factors):
    """out[b, r] = vals[b] * prod_k factors[k][b, r].

    The Algorithm 1 inner loop over a block of nonzeros: `vals` are the
    tensor values, each `factors[k]` holds the gathered rows of input
    factor matrix k for those nonzeros.
    """
    out = vals[:, None].astype(jnp.float32)
    for f in factors:
        out = out * f.astype(jnp.float32)
    return out


def segment_rows_ref(contrib, seg_ids, num_segments):
    """out[s, r] = sum over b with seg_ids[b] == s of contrib[b, r].

    Accumulates per-nonzero contributions into output factor rows (the
    `A(i0, r) +=` of Algorithm 1) for a block whose nonzeros are grouped
    by output index.
    """
    return jax.ops.segment_sum(contrib, seg_ids, num_segments=num_segments)


def mttkrp_block_ref(vals, seg_ids, num_segments, *factors):
    """Fused block MTTKRP: scaled Hadamard then segment accumulation."""
    return segment_rows_ref(scaled_hadamard_ref(vals, *factors), seg_ids, num_segments)


def gram_ref(f):
    """G = Fᵀ F for a factor tile F[i, r] (CP-ALS normal equations)."""
    f32 = f.astype(jnp.float32)
    return f32.T @ f32


def row_matmul_ref(rows, m):
    """out = rows @ m — the CP-ALS factor update `MTTKRP(X) @ pinv(...)`."""
    return rows.astype(jnp.float32) @ m.astype(jnp.float32)
