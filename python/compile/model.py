"""Layer-2 JAX model: the spMTTKRP compute graph the rust coordinator
executes through PJRT.

Entry points (all jit-able with static shapes, AOT-lowered by `aot.py`):

* ``mttkrp_block_<N>`` — one block of Algorithm 1 for an N-mode tensor:
  scaled-Hadamard product of the gathered input factor rows (L1 Pallas
  kernel) followed by segment accumulation into output rows. The rust
  driver gathers rows / builds segment ids (that is the memory system the
  paper models); this graph is the arithmetic.
* ``gram`` — partial CP-ALS gram matrix of a factor tile (L1 MXU kernel);
  the driver accumulates tiles.
* ``factor_update`` — `rows @ M` applying the inverted Hadamard-of-grams
  to the MTTKRP output (L1 MXU kernel).

Python exists only at artifact-build time; nothing here runs at serving
time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import mttkrp as kernels


def mttkrp_block(vals, seg_ids, *factors, num_segments):
    """Block MTTKRP: ``out[s, :] = Σ_{b: seg[b]=s} vals[b] · Π_k Fk[b, :]``.

    vals: f32[B]; seg_ids: i32[B] in [0, num_segments); factors: f32[B, R]
    each (rows already gathered). Returns f32[num_segments, R].
    Nonzeros are grouped by output index (the Algorithm 1 ordering), but
    correctness does not depend on it — segment_sum handles any grouping.
    """
    contrib = kernels.scaled_hadamard(vals, *factors)
    return jax.ops.segment_sum(contrib, seg_ids, num_segments=num_segments)


def mttkrp_block_3(vals, seg_ids, f1, f2, *, num_segments):
    """3-mode tensor block (two input factor matrices)."""
    return mttkrp_block(vals, seg_ids, f1, f2, num_segments=num_segments)


def mttkrp_block_4(vals, seg_ids, f1, f2, f3, *, num_segments):
    """4-mode tensor block (DELICIOUS-class)."""
    return mttkrp_block(vals, seg_ids, f1, f2, f3, num_segments=num_segments)


def mttkrp_block_5(vals, seg_ids, f1, f2, f3, f4, *, num_segments):
    """5-mode tensor block (LBNL-class)."""
    return mttkrp_block(vals, seg_ids, f1, f2, f3, f4, num_segments=num_segments)


def scaled_hadamard_block(vals, *factors):
    """Scatter-free block kernel: just the L1 product
    ``out[b, :] = vals[b] · Π_k Fk[b, :]`` — the coordinator accumulates
    rows on the rust side (cheaper than XLA-CPU scatter; see aot.py).

    Lowered as a single grid step: in interpret mode every grid iteration
    re-materializes the whole output via dynamic-update-slice (O(block²)
    per call); one step keeps the CPU execution linear. The multi-step
    BlockSpec schedule remains the TPU-facing story (kernels.ROW_TILE).
    """
    return kernels.scaled_hadamard(vals, *factors, row_tile=factors[0].shape[0])


def gram(f_tile):
    """Partial gram ``Fᵀ F`` of one factor tile f32[TILE, R]."""
    return kernels.gram_tile(f_tile)


def factor_update(rows, m):
    """CP-ALS update: ``A_new = MTTKRP_rows @ M`` with M = pinv(⊛ grams)."""
    return kernels.row_matmul(rows, m)


def hadamard_grams(grams):
    """Elementwise (Hadamard) product of the input grams, f32[K, R, R] →
    f32[R, R] — the CP-ALS normal-equations matrix before inversion. Small
    and bandwidth-trivial, so plain jnp (fused by XLA) rather than Pallas.
    """
    return jnp.prod(grams.astype(jnp.float32), axis=0)
