//! The streaming bottleneck engine.
//!
//! Simulates one output mode of a sparse kernel on the Fig. 4
//! accelerator: the kernel's chunked access-stream IR
//! ([`crate::kernel::SparseKernel::stream`]) is partitioned across PEs by
//! output slice; each PE walks its share charging occupancy to every
//! resource an op touches (DRAM channel, the three caches, psum buffer,
//! exec pipelines, DMA buffers). Runtime per PE is the busiest resource's
//! total (all units are deeply pipelined and run concurrently — the
//! classic bottleneck/roofline abstraction the paper's own model uses)
//! plus the un-hideable startup/drain latency; mode runtime is the
//! slowest PE.
//!
//! The engine is **kernel-agnostic** and technology-agnostic: the
//! workload arrives as chunks of factor-read ops and slice boundaries
//! (never a materialized full trace — per-PE live memory is O(chunk), so
//! sweeps scale to multi-hundred-million-nonzero tensors), and every
//! structural choice — banking, tag→data serialization, the DRAM overlap
//! derate — derives from the registry-resolved [`MemTechnology`]
//! parameter set itself.
//!
//! Complexity is O(nnz × reads-per-nonzero) per mode — the cache lookups
//! dominate, so the engine streams tens of millions of nonzeros per
//! second per core (see EXPERIMENTS.md §Perf). The hot loop pulls the
//! stream through the zero-allocation [`AccessChunk`] fill API, and the
//! independent per-PE walks fan across OS threads under the
//! [`SimBudget`] thread budget — per-PE reports are reduced in fixed PE
//! order, so every `f64` is bit-identical at any thread count. For
//! many-scenario runs, [`crate::sim::sweep`] fans independent
//! simulations across the same budget one level up.
//!
//! This is the *analytic* backend of the [`crate::sim::SimEngine`] trait;
//! [`crate::sim::event`] is the event-driven backend that replays the same
//! access stream through bank-arbitrated and queue-arbitrated resources to
//! cross-validate the perfect-overlap assumption made here.

use crate::accel::config::AcceleratorConfig;
use crate::cache::pipeline::ArrayTiming;
use crate::controller::mc::MemoryController;
use crate::kernel::{AccessChunk, KernelKind, SparseKernel};
use crate::mem::tech::MemTechnology;
use crate::obs::{metrics, Span};
use crate::pe::exec::{ExecCharge, ExecUnit};
use crate::sim::par::parallel_map_init;
use crate::sim::result::{ModeReport, PeReport, SimReport};
use crate::sim::SimBudget;
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

// --- shared engine plumbing -------------------------------------------------
//
// Both simulation backends must price *identical* work from *identical*
// constants — the cross-engine contracts (`event >= analytic`, bit-identical
// busy/traffic accounting) depend on it. Everything below is therefore
// defined once here and imported by `crate::sim::event`, like
// [`partition_slices`] is.

/// Bytes of one streamed nonzero record: N 4-byte coordinates + the value.
pub(crate) fn nnz_item_bytes(n_modes: usize) -> u64 {
    (4 * n_modes + 4) as u64
}

/// Startup/drain latency that pipelining cannot hide: one DRAM round-trip
/// to prime the stream + one cache fill latency + the exec pipeline depth.
/// The event engine measures its contention stall relative to this same
/// bound, so the formula must never fork between engines.
pub(crate) fn startup_latency(cfg: &AcceleratorConfig, mc: &MemoryController) -> f64 {
    cfg.dram.row_miss_ns * 1e-9 * cfg.fabric_hz + mc.cache_timing.hit_latency() + cfg.rank as f64
}

/// Price one PE's exec-unit totals from its integer work counters: the
/// pipelines run once per nonzero (a drain never occupies them — see
/// [`crate::pe::exec::ExecUnit::drain_slice`]), the psum array runs per
/// nonzero and per slice drain. One multiply per hoisted constant, so a
/// counts-only pricing pass (the reuse-distance profiler) reproduces the
/// walked engines bit for bit. Returns
/// `(pipeline_cycles, psum_cycles, psum_words)`.
pub(crate) fn price_exec(
    per_nnz: &ExecCharge,
    per_drain: &ExecCharge,
    pe_nnz: u64,
    drains: u64,
) -> (f64, f64, u64) {
    let pipeline_cycles = pe_nnz as f64 * per_nnz.pipeline_cycles;
    let psum_cycles =
        pe_nnz as f64 * per_nnz.psum_cycles + drains as f64 * per_drain.psum_cycles;
    let psum_words = pe_nnz * per_nnz.psum_words + drains * per_drain.psum_words;
    (pipeline_cycles, psum_cycles, psum_words)
}

/// Assemble one PE's [`PeReport`] from its controller and priced exec
/// totals. Every busy field reads the controller's **derived** getters,
/// so a counts-loaded controller (the profiler's pricing pass, see
/// [`MemoryController::load_counts`]) produces the same report as a
/// directly walked one — the single owner of the per-PE report shape
/// for the analytic engine, the event replay loops and the profiler.
#[allow(clippy::too_many_arguments)]
pub(crate) fn assemble_pe_report(
    mc: &MemoryController,
    pe_idx: usize,
    pe_nnz: u64,
    n_slices_pe: u64,
    pipeline_cycles: f64,
    psum_cycles: f64,
    psum_words: u64,
    latency_overhead: f64,
) -> PeReport {
    PeReport {
        pe: pe_idx,
        nnz: pe_nnz,
        slices: n_slices_pe,
        dram_cycles: mc.dram_busy(),
        cache_cycles: mc.cache_busy_vec(),
        psum_cycles,
        pipeline_cycles,
        stream_dma_cycles: mc.stream_busy,
        element_dma_cycles: mc.element_busy(),
        latency_overhead_cycles: latency_overhead,
        stall_cycles: 0.0,
        stall_stderr_cycles: 0.0,
        sampled_nnz: pe_nnz,
        cache_stats: mc.cache_stats(),
        dram_stream_bytes: mc.dram.bytes_streamed,
        dram_random_bytes: mc.dram.bytes_random,
        dram_random_accesses: mc.dram.random_accesses,
        cache_words: mc.cache_words,
        psum_words,
        dma_words: mc.dma_words,
        levels: mc.level_reports(),
    }
}

/// Charge one PE's §IV-A sequential streams in the canonical order (the
/// tensor's nonzeros in, the output rows out). The *call order* is part of
/// the cross-engine contract: both engines issue these exact `stream`
/// calls after the nonzero walk, keeping the reported traffic/busy fields
/// bit-identical. `row_bytes` is the kernel's output-row width
/// ([`SparseKernel::out_row_bytes`]).
pub(crate) fn charge_streams(
    mc: &mut MemoryController,
    pe_nnz: u64,
    n_slices_pe: u64,
    item_bytes: u64,
    row_bytes: u64,
) {
    mc.stream(pe_nnz * item_bytes);
    mc.stream(n_slices_pe * row_bytes);
}

/// Partition the view's slices into `n_pes` contiguous chunks balanced by
/// nonzero count. Returns per-PE slice index ranges `[lo, hi)`.
///
/// The ranges are always in order, non-overlapping, and cover
/// `[0, n_slices)` exactly — including when `n_pes > n_slices`, where the
/// trailing PEs receive valid *empty* ranges. Targets are computed with
/// exact integer arithmetic so billion-nonzero tensors cannot hit f64
/// rounding artifacts.
///
/// **Shared-path invariant:** this is the *only* slice-partitioning logic
/// in the crate. The analytic engine (this module), the event engine
/// ([`crate::sim::event`]) and the PE scheduler
/// ([`crate::coordinator::scheduler`]) all call this one function, so for
/// a given `(view, n_pes)` every backend simulates *identical* per-PE
/// work assignments — the engine-agreement tests rely on the runtimes
/// differing only in timing assembly, never in workload split.
pub fn partition_slices(view: &ModeView, n_pes: usize) -> Vec<(usize, usize)> {
    assert!(n_pes > 0);
    let n_slices = view.n_slices();
    let total: u64 = view.nnz() as u64;
    let mut parts = Vec::with_capacity(n_pes);
    let mut lo = 0usize;
    let mut consumed = 0u64;
    for pe in 0..n_pes {
        let hi = if pe == n_pes - 1 {
            n_slices
        } else {
            // cumulative nonzero target after this PE
            let want = ((pe as u128 + 1) * total as u128 / n_pes as u128) as u64;
            let mut hi = lo;
            while hi < n_slices && consumed < want {
                consumed += (view.slice_ptr[hi + 1] - view.slice_ptr[hi]) as u64;
                hi += 1;
            }
            hi
        };
        parts.push((lo, hi));
        lo = hi;
    }
    parts
}

/// Simulate one output mode of `tensor` under `kernel` on the accelerator
/// with memory technology `tech` (any registry-resolved parameter set).
/// The tensor does **not** need to be pre-sorted — the engine builds the
/// per-mode view itself (counting sort, O(nnz)).
pub fn simulate_kernel_mode(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    let view = ModeView::build(tensor, mode);
    simulate_kernel_mode_with_view(kernel, tensor, &view, mode, cfg, tech)
}

/// [`simulate_kernel_mode`] with a caller-supplied mode view, so
/// many-scenario runs (the [`crate::sim::sweep`] engine sweeping one
/// tensor across N technologies) pay the O(nnz) view build once per
/// (tensor, mode) instead of once per scenario. `view` must be
/// `ModeView::build(tensor, mode)` for the same tensor and mode.
pub fn simulate_kernel_mode_with_view(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode_with_view_budget(
        kernel,
        tensor,
        view,
        mode,
        cfg,
        tech,
        SimBudget::default(),
    )
}

/// [`simulate_kernel_mode_with_view`] under an explicit host-execution
/// [`SimBudget`]: the independent per-PE walks fan across
/// `budget.pe_threads(cfg.n_pes)` OS threads, each worker reusing one
/// scratch [`AccessChunk`] through the zero-allocation
/// [`crate::kernel::AccessStream::fill`] loop. Per-PE reports land in
/// fixed PE order and every `f64` is accumulated inside its own PE, so
/// the report is **bit-identical** for any thread count and any chunk
/// size (pinned by `rust/tests/parallel_determinism.rs`).
pub fn simulate_kernel_mode_with_view_budget(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    budget: SimBudget,
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    if let Err(e) = kernel.validate(tensor, mode) {
        panic!("kernel `{}` rejected the workload: {e}", kernel.name());
    }
    cfg.validate().expect("invalid accelerator config");
    // observation rides beside the computation: the span is inert
    // unless a front-end enabled recording, and the chunk counter is a
    // relaxed atomic resolved once, off the result path entirely
    let _span = Span::enter("engine.analytic.mode", "engine");
    let chunk_counter = metrics::global().counter("sim_analytic_chunks_total");
    let parts = partition_slices(view, cfg.n_pes);

    // The kernel's input slots: which factor matrix each FactorRead slot
    // addresses; the controller's bypass routing needs their row counts.
    let read_modes = kernel.read_modes(tensor, mode);
    let matrix_rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();
    let rpn = read_modes.len();

    let t = cfg.tuned_tech(tech);
    let banks = cfg.bank_factor(&t);
    let psum_timing = ArrayTiming::new(&t, cfg.fabric_hz, banks);
    // psum banking: one bank per group of 10 pipelines (Table I's 80
    // pipelines share 8 psum banks — a fixed design property, see
    // DESIGN.md §4).
    let psum_banks = (cfg.n_pipelines / 10).max(1);

    let item_bytes = nnz_item_bytes(tensor.n_modes());
    let row_bytes = kernel.out_row_bytes(cfg.rank, tensor.n_modes());
    let chunk_nnz = budget.chunk();

    // Every PE owns its controller, caches, DRAM channel and exec unit,
    // and its slice range never overlaps another's — the walks are
    // independent by construction, so they fan across threads with no
    // shared mutable state. Slot-ordered results keep PE order fixed.
    let pes = parallel_map_init(
        &parts,
        budget.pe_threads(cfg.n_pes),
        AccessChunk::default,
        |scratch, pe_idx, &(slo, shi)| {
            let mut mc = MemoryController::new(cfg, &t, &matrix_rows);
            let exec = ExecUnit::new(cfg.n_pipelines, cfg.rank, psum_timing.clone(), psum_banks);

            let mut pe_nnz = 0u64;
            let mut drains = 0u64;

            let per_nnz = kernel.nnz_exec(&exec, tensor.n_modes());
            let per_drain = kernel.drain_exec(&exec, tensor.n_modes());

            let mut stream = kernel.stream(tensor, view, (slo, shi), chunk_nnz);
            let mut n_chunks = 0u64;
            while stream.fill(scratch) {
                let chunk = &*scratch;
                n_chunks += 1;
                pe_nnz += chunk.n_nnz as u64;
                // every slice drains exactly once (psum row out)
                drains += chunk.slice_ends.len() as u64;
                for i in 0..chunk.n_nnz {
                    for read in &chunk.reads[i * rpn..(i + 1) * rpn] {
                        mc.factor_row_load(read.slot() as usize, read.row());
                    }
                }
            }
            chunk_counter.add(n_chunks);

            // Sequential traffic, charged in bulk: the tensor's nonzeros
            // stream in once (coordinates + value), the output rows
            // stream out once.
            let n_slices_pe = (shi - slo) as u64;
            charge_streams(&mut mc, pe_nnz, n_slices_pe, item_bytes, row_bytes);

            let latency_overhead = startup_latency(cfg, &mc);
            let (pipeline_cycles, psum_cycles, psum_words) =
                price_exec(&per_nnz, &per_drain, pe_nnz, drains);
            assemble_pe_report(
                &mc,
                pe_idx,
                pe_nnz,
                n_slices_pe,
                pipeline_cycles,
                psum_cycles,
                psum_words,
                latency_overhead,
            )
        },
    );

    ModeReport {
        tensor: tensor.name.clone(),
        kernel: kernel.name().to_string(),
        mode,
        tech: t,
        rank: cfg.rank,
        fabric_hz: cfg.fabric_hz,
        pes,
    }
}

/// Simulate one output mode of the default spMTTKRP kernel (the paper's
/// workload) — the pre-kernel-IR entry point, preserved verbatim.
pub fn simulate_mode(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode(KernelKind::Spmttkrp.kernel(), tensor, mode, cfg, tech)
}

/// [`simulate_mode`] with a caller-supplied mode view.
pub fn simulate_mode_with_view(
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode_with_view(KernelKind::Spmttkrp.kernel(), tensor, view, mode, cfg, tech)
}

/// Simulate every output mode of `kernel` (the full sweep of Fig. 7's
/// x-axis for MTTKRP; the mode-product chain for TTM). The report
/// assembly has one owner — the [`crate::sim::SimEngine`] trait default —
/// so this delegates rather than re-building the [`SimReport`] shape.
pub fn simulate_kernel_all_modes(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    crate::sim::EngineKind::Analytic.simulate_kernel_all_modes(kernel, tensor, cfg, tech)
}

/// Simulate every output mode of the default spMTTKRP kernel.
pub fn simulate_all_modes(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    simulate_kernel_all_modes(KernelKind::Spmttkrp.kernel(), tensor, cfg, tech)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::tensor::gen::{self, FrosttTensor, TensorSpec};

    fn small_cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    fn assert_valid_partition(parts: &[(usize, usize)], v: &ModeView, n_pes: usize) {
        assert_eq!(parts.len(), n_pes);
        assert_eq!(parts[0].0, 0);
        assert_eq!(parts.last().unwrap().1, v.n_slices());
        for &(lo, hi) in parts {
            assert!(lo <= hi, "range ({lo},{hi}) out of order");
            assert!(hi <= v.n_slices(), "range end {hi} past {}", v.n_slices());
        }
        for w in parts.windows(2) {
            assert_eq!(w[0].1, w[1].0, "contiguous");
        }
        let covered: u64 = parts
            .iter()
            .flat_map(|&(lo, hi)| (lo..hi).map(|s| v.slice(s).len() as u64))
            .sum();
        assert_eq!(covered, v.nnz() as u64, "nnz conserved");
    }

    #[test]
    fn partition_covers_all_slices_once() {
        let t = gen::random(&[100, 50, 60], 5000, 1);
        let v = ModeView::build(&t, 0);
        for n_pes in [1, 2, 4, 7] {
            let parts = partition_slices(&v, n_pes);
            assert_valid_partition(&parts, &v, n_pes);
        }
    }

    #[test]
    fn partition_is_roughly_balanced() {
        let t = gen::random(&[1000, 50, 60], 40_000, 2);
        let v = ModeView::build(&t, 0);
        let parts = partition_slices(&v, 4);
        for &(lo, hi) in &parts {
            let nnz: u64 = (lo..hi).map(|s| v.slice(s).len() as u64).sum();
            assert!(
                (nnz as f64 - 10_000.0).abs() < 2_000.0,
                "partition nnz {nnz} far from target"
            );
        }
    }

    #[test]
    fn partition_with_more_pes_than_slices_is_valid() {
        // regression: 3 output slices shared by 8 PEs must produce ordered,
        // non-overlapping ranges with valid empty tails — not garbage
        let t = gen::random(&[3, 40, 40], 3_000, 5);
        let v = ModeView::build(&t, 0);
        assert!(v.n_slices() <= 3);
        for n_pes in [4, 8, 17] {
            let parts = partition_slices(&v, n_pes);
            assert_valid_partition(&parts, &v, n_pes);
            // at least one PE must be empty, and empty ranges are well-formed
            assert!(parts.iter().any(|&(lo, hi)| lo == hi));
        }
    }

    #[test]
    fn partition_of_empty_view_is_all_empty() {
        let t = SparseTensor::new("empty", vec![10, 10]);
        let v = ModeView::build(&t, 0);
        let parts = partition_slices(&v, 6);
        assert_valid_partition(&parts, &v, 6);
    }

    #[test]
    fn simulate_with_more_pes_than_slices() {
        // end to end: empty PE partitions must simulate cleanly and the
        // nonzero count must be conserved across the PE reports
        let t = gen::random(&[2, 64, 64], 4_000, 7);
        let mut cfg = small_cfg();
        cfg.n_pes = 8;
        let r = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        assert_eq!(r.pes.len(), 8);
        assert_eq!(r.total_nnz(), 4_000);
        assert!(r.pes.iter().any(|p| p.nnz == 0), "some PE must be empty");
        assert!(r.runtime_cycles() > 0.0);
    }

    #[test]
    fn all_nonzeros_processed_once() {
        let t = gen::random(&[64, 64, 64], 10_000, 3);
        let r = simulate_mode(&t, 0, &small_cfg(), &tech("e-sram"));
        assert_eq!(r.total_nnz(), 10_000);
        assert_eq!(r.pes.len(), 4);
        assert_eq!(r.kernel, "spmttkrp");
    }

    #[test]
    fn osram_never_slower_esram_never_faster() {
        let cfg = small_cfg();
        for spec in [
            TensorSpec::custom("hot", vec![200, 200, 200], 30_000, 1.2),
            TensorSpec::custom("cold", vec![500_000, 400_000, 600_000], 30_000, 0.1),
        ] {
            let t = spec.generate(11);
            for mode in 0..3 {
                let e = simulate_mode(&t, mode, &cfg, &tech("e-sram"));
                let o = simulate_mode(&t, mode, &cfg, &tech("o-sram"));
                assert!(
                    e.runtime_cycles() >= o.runtime_cycles() * 0.999,
                    "{} mode {mode}: E {} < O {}",
                    t.name,
                    e.runtime_cycles(),
                    o.runtime_cycles()
                );
                // functional cache behaviour must be identical
                assert_eq!(e.hit_rate(), o.hit_rate());
            }
        }
    }

    #[test]
    fn hot_tensor_speedup_exceeds_cold() {
        let cfg = small_cfg();
        // hot: factor matrices fit the (scaled) caches entirely
        let hot = TensorSpec::custom("hot", vec![48, 48, 48], 60_000, 1.2).generate(5);
        let cold =
            TensorSpec::custom("cold", vec![800_000, 700_000, 900_000], 60_000, 0.05).generate(5);
        let sp = |t: &SparseTensor| {
            let e = simulate_mode(t, 0, &cfg, &tech("e-sram"));
            let o = simulate_mode(t, 0, &cfg, &tech("o-sram"));
            e.runtime_cycles() / o.runtime_cycles()
        };
        let (sh, sc) = (sp(&hot), sp(&cold));
        assert!(sh > sc, "hot speedup {sh} should exceed cold {sc}");
        assert!(sh > 1.5, "hot speedup {sh} too small");
        assert!(sc < 2.0, "cold speedup {sc} too large");
    }

    #[test]
    fn runtime_scales_with_nnz() {
        // cache-resident factor matrices ⇒ no cold-miss amortization ⇒
        // runtime must scale linearly in nnz
        let cfg = small_cfg();
        let t1 = gen::random(&[64, 64, 64], 50_000, 7);
        let t2 = gen::random(&[64, 64, 64], 200_000, 7);
        let r1 = simulate_mode(&t1, 0, &cfg, &tech("o-sram"));
        let r2 = simulate_mode(&t2, 0, &cfg, &tech("o-sram"));
        let ratio = r2.runtime_cycles() / r1.runtime_cycles();
        assert!(ratio > 3.5 && ratio < 4.5, "4x nnz should be ~4x time, got {ratio}");
    }

    #[test]
    fn cold_miss_amortization_improves_hit_rate() {
        // same dims, more nnz ⇒ compulsory misses amortize ⇒ hit rate up
        let cfg = small_cfg();
        let t1 = gen::random(&[256, 256, 256], 10_000, 7);
        let t2 = gen::random(&[256, 256, 256], 40_000, 7);
        let r1 = simulate_mode(&t1, 0, &cfg, &tech("o-sram"));
        let r2 = simulate_mode(&t2, 0, &cfg, &tech("o-sram"));
        assert!(r2.hit_rate() > r1.hit_rate());
    }

    #[test]
    fn all_modes_report_covers_every_mode() {
        let spec = gen::preset(FrosttTensor::Lbnl).scaled(1.0 / 64.0);
        let t = spec.generate(4);
        let r = simulate_all_modes(&t, &small_cfg(), &tech("o-sram"));
        assert_eq!(r.modes.len(), 5);
        for (i, m) in r.modes.iter().enumerate() {
            assert_eq!(m.mode, i);
            assert_eq!(m.total_nnz() as u64, t.nnz() as u64);
            assert_eq!(m.tech.name, "o-sram");
        }
        assert_eq!(r.kernel, "spmttkrp");
        assert!(r.total_runtime_s() > 0.0);
    }

    #[test]
    fn single_pe_configuration_works() {
        let mut cfg = small_cfg();
        cfg.n_pes = 1;
        let t = gen::random(&[64, 64], 1000, 9);
        let r = simulate_mode(&t, 1, &cfg, &tech("e-sram"));
        assert_eq!(r.pes.len(), 1);
        assert_eq!(r.total_nnz(), 1000);
    }

    #[test]
    fn empty_tensor_simulates_to_near_zero() {
        let t = SparseTensor::new("empty", vec![10, 10]);
        let r = simulate_mode(&t, 0, &small_cfg(), &tech("o-sram"));
        assert_eq!(r.total_nnz(), 0);
        // only fixed latency overhead remains
        assert!(r.runtime_cycles() < 100.0);
    }

    #[test]
    fn more_pes_reduce_runtime() {
        let t = gen::random(&[2048, 512, 512], 100_000, 13);
        let mut c1 = small_cfg();
        c1.n_pes = 1;
        let mut c4 = small_cfg();
        c4.n_pes = 4;
        let r1 = simulate_mode(&t, 0, &c1, &tech("o-sram"));
        let r4 = simulate_mode(&t, 0, &c4, &tech("o-sram"));
        let sp = r1.runtime_cycles() / r4.runtime_cycles();
        assert!(sp > 2.5, "4 PEs should give ≥2.5x over 1, got {sp}");
    }

    #[test]
    fn every_registered_technology_simulates() {
        // the engine must be closed over the registry: any entry runs
        let t = gen::random(&[64, 64, 64], 5_000, 21);
        let cfg = small_cfg();
        for tname in crate::mem::registry::names() {
            let r = simulate_mode(&t, 0, &cfg, &tech(&tname));
            assert_eq!(r.total_nnz(), 5_000, "{tname}");
            assert!(r.runtime_cycles() > 0.0, "{tname}");
            assert_eq!(r.tech.name, tname);
        }
    }

    #[test]
    fn every_builtin_kernel_simulates_on_every_technology() {
        // the engine must be closed over *both* open axes: any registered
        // kernel × any registered technology runs with no per-name code
        let t = gen::random(&[64, 64, 64], 5_000, 23);
        let cfg = small_cfg();
        for kind in KernelKind::ALL {
            for tname in crate::mem::registry::names() {
                let r = simulate_kernel_mode(kind.kernel(), &t, 0, &cfg, &tech(&tname));
                assert_eq!(r.total_nnz(), 5_000, "{kind} on {tname}");
                assert!(r.runtime_cycles() > 0.0, "{kind} on {tname}");
                assert_eq!(r.kernel, kind.name());
            }
        }
    }

    #[test]
    fn kernels_differ_where_they_should() {
        // same tensor, same technology: spmm does 1/2 the cache requests
        // of spmttkrp on a 3-mode tensor; spttm matches spmttkrp's
        // requests but is strictly psum/compute-heavier
        let t = gen::random(&[256, 256, 256], 20_000, 3);
        let cfg = small_cfg();
        let mt = simulate_kernel_mode(KernelKind::Spmttkrp.kernel(), &t, 0, &cfg, &tech("o-sram"));
        let mm = simulate_kernel_mode(KernelKind::Spmm.kernel(), &t, 0, &cfg, &tech("o-sram"));
        let tm = simulate_kernel_mode(KernelKind::Spttm.kernel(), &t, 0, &cfg, &tech("o-sram"));
        let accesses =
            |r: &ModeReport| r.pes.iter().map(|p| p.cache_stats.accesses()).sum::<u64>();
        assert_eq!(accesses(&mt), 2 * accesses(&mm));
        assert_eq!(accesses(&mt), accesses(&tm));
        let psum = |r: &ModeReport| r.pes.iter().map(|p| p.psum_cycles).sum::<f64>();
        assert!(psum(&tm) > psum(&mt));
        assert!(tm.runtime_cycles() > mt.runtime_cycles());
    }

    #[test]
    fn budget_never_changes_the_report() {
        // threads and chunk size are host knobs: any combination must
        // reproduce the single-threaded default-chunk report bit for bit
        let t = gen::random(&[512, 256, 256], 30_000, 29);
        let cfg = small_cfg();
        let view = ModeView::build(&t, 0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let base = simulate_kernel_mode_with_view_budget(
            kernel,
            &t,
            &view,
            0,
            &cfg,
            &tech("o-sram"),
            SimBudget::single_threaded(),
        );
        for budget in [
            SimBudget::with_threads(2),
            SimBudget::with_threads(0),
            SimBudget { threads: 3, chunk_nnz: 777, ..SimBudget::default() },
            SimBudget { threads: 1, chunk_nnz: 1, ..SimBudget::default() },
        ] {
            let r = simulate_kernel_mode_with_view_budget(
                kernel,
                &t,
                &view,
                0,
                &cfg,
                &tech("o-sram"),
                budget,
            );
            let (x, y) = (base.runtime_cycles(), r.runtime_cycles());
            assert_eq!(x.to_bits(), y.to_bits(), "{budget:?}");
            for (a, b) in base.pes.iter().zip(&r.pes) {
                assert_eq!(a.nnz, b.nnz, "{budget:?}");
                assert_eq!(a.dram_cycles.to_bits(), b.dram_cycles.to_bits(), "{budget:?}");
                assert_eq!(a.psum_cycles.to_bits(), b.psum_cycles.to_bits(), "{budget:?}");
                assert_eq!(a.cache_stats.hits, b.cache_stats.hits, "{budget:?}");
                assert_eq!(a.cache_words, b.cache_words, "{budget:?}");
            }
        }
    }

    #[test]
    fn spmm_on_a_matrix_equals_spmttkrp() {
        // the degenerate-case contract, end to end through the engine
        let t = gen::random(&[512, 512], 30_000, 5);
        let cfg = small_cfg();
        for mode in 0..2 {
            let mtt = KernelKind::Spmttkrp.kernel();
            let mm = KernelKind::Spmm.kernel();
            let a = simulate_kernel_mode(mtt, &t, mode, &cfg, &tech("e-sram"));
            let b = simulate_kernel_mode(mm, &t, mode, &cfg, &tech("e-sram"));
            assert_eq!(a.runtime_cycles().to_bits(), b.runtime_cycles().to_bits());
            assert_eq!(a.hit_rate(), b.hit_rate());
            assert_eq!(a.total_dram_bytes(), b.total_dram_bytes());
        }
    }
}
