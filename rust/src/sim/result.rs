//! Simulation results: per-PE and per-mode reports.

use crate::cache::cache::CacheStats;
use crate::mem::hierarchy::{merge_level_reports, LevelReport};
use crate::mem::tech::MemTechnology;

/// Named resources a PE can bottleneck on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resource {
    /// External DRAM channel (stream + random combined).
    Dram,
    /// The busiest of the PE's caches.
    Cache,
    /// Partial-sum buffer ports.
    Psum,
    /// Execution pipelines.
    Pipelines,
    /// Stream-DMA staging buffer.
    StreamDma,
    /// Element-wise DMA staging buffer.
    ElementDma,
    /// The busiest level of the configured memory-hierarchy stack
    /// (only a candidate when `--levels` is non-degenerate and the
    /// stack saw traffic).
    Hierarchy,
}

impl Resource {
    pub fn name(&self) -> &'static str {
        match self {
            Resource::Dram => "dram",
            Resource::Cache => "cache",
            Resource::Psum => "psum",
            Resource::Pipelines => "pipelines",
            Resource::StreamDma => "stream-dma",
            Resource::ElementDma => "element-dma",
            Resource::Hierarchy => "hierarchy",
        }
    }
}

/// Result of simulating one PE's share of one output mode.
#[derive(Clone, Debug)]
pub struct PeReport {
    pub pe: usize,
    pub nnz: u64,
    pub slices: u64,
    /// Busy cycles per resource (fabric cycles).
    pub dram_cycles: f64,
    pub cache_cycles: Vec<f64>,
    pub psum_cycles: f64,
    pub pipeline_cycles: f64,
    pub stream_dma_cycles: f64,
    pub element_dma_cycles: f64,
    /// Fixed latency overhead not hidden by pipelining (startup / drain).
    pub latency_overhead_cycles: f64,
    /// Contention stall measured by the event engine (bank-conflict
    /// serialization, DRAM-channel queueing, decoupling-window
    /// back-pressure) **on top of** the bottleneck-resource time. The
    /// analytic engine assumes perfect overlap and always reports `0.0`;
    /// see [`crate::sim::event`] for how the event replay measures it.
    pub stall_cycles: f64,
    /// Standard error of [`Self::stall_cycles`] when the event replay
    /// sampled the stream ([`crate::sim::SampleSpec`] below rate 1.0):
    /// per-chunk stall variance scaled to full-stream extrapolation.
    /// `0.0` for exact replay and for the analytic engine (the estimate
    /// is then not an estimate).
    pub stall_stderr_cycles: f64,
    /// Nonzeros whose event timing was actually replayed for the stall
    /// figure. Equals [`Self::nnz`] for exact replay and for the
    /// analytic engine; below that, `stall_cycles` is a sampled
    /// extrapolation. Functional accounting (traffic, hits, words)
    /// always covers all `nnz`.
    pub sampled_nnz: u64,
    /// Functional cache statistics (summed over the PE's caches).
    pub cache_stats: CacheStats,
    /// DRAM traffic.
    pub dram_stream_bytes: u64,
    pub dram_random_bytes: u64,
    pub dram_random_accesses: u64,
    /// Active 32-bit words moved through each on-chip component
    /// (Eq. 3 `S_active` feeders).
    pub cache_words: u64,
    pub psum_words: u64,
    pub dma_words: u64,
    /// Per-level hierarchy accounting, in `AcceleratorConfig::levels`
    /// stack order (outermost first). Empty for the degenerate
    /// single-level configuration.
    pub levels: Vec<LevelReport>,
}

impl PeReport {
    /// The PE finishes when its most-loaded resource drains, plus any
    /// contention stall an event-driven replay measured on top (zero for
    /// the analytic engine, so both engines report through one type).
    pub fn runtime_cycles(&self) -> f64 {
        let cache_max = self.cache_cycles.iter().cloned().fold(0.0f64, f64::max);
        let level_max = self.level_max_cycles();
        self.dram_cycles
            .max(cache_max)
            .max(level_max)
            .max(self.psum_cycles)
            .max(self.pipeline_cycles)
            .max(self.stream_dma_cycles)
            .max(self.element_dma_cycles)
            + self.latency_overhead_cycles
            + self.stall_cycles
    }

    /// Busy cycles of the most-loaded hierarchy level (`0.0` for the
    /// degenerate configuration — folding an empty stack is then a
    /// no-op in [`Self::runtime_cycles`], keeping it bit-identical).
    pub fn level_max_cycles(&self) -> f64 {
        self.levels.iter().map(|l| l.busy_cycles).fold(0.0f64, f64::max)
    }

    /// Which resource bound this PE.
    pub fn bottleneck(&self) -> Resource {
        let cache_max = self.cache_cycles.iter().cloned().fold(0.0f64, f64::max);
        let level_max = self.level_max_cycles();
        let mut candidates = vec![
            (self.dram_cycles, Resource::Dram),
            (cache_max, Resource::Cache),
            (self.psum_cycles, Resource::Psum),
            (self.pipeline_cycles, Resource::Pipelines),
            (self.stream_dma_cycles, Resource::StreamDma),
            (self.element_dma_cycles, Resource::ElementDma),
        ];
        // only a loaded hierarchy competes: a zero-busy stack (or the
        // degenerate config) must not perturb the existing tie-breaks
        if level_max > 0.0 {
            candidates.push((level_max, Resource::Hierarchy));
        }
        candidates
            .iter()
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .map(|&(_, r)| r)
            .unwrap()
    }

    /// Total active on-chip words (cache + psum + DMA buffers + every
    /// hierarchy level).
    pub fn onchip_words(&self) -> u64 {
        self.cache_words
            + self.psum_words
            + self.dma_words
            + self.levels.iter().map(|l| l.words).sum::<u64>()
    }

    /// Fraction of this PE's nonzeros that were event-timed (1.0 =
    /// exact replay; empty PEs count as exact).
    pub fn sampled_frac(&self) -> f64 {
        if self.nnz == 0 {
            1.0
        } else {
            self.sampled_nnz as f64 / self.nnz as f64
        }
    }
}

/// Result of simulating one full output mode across all PEs.
#[derive(Clone, Debug)]
pub struct ModeReport {
    pub tensor: String,
    /// Name of the [`crate::kernel::SparseKernel`] that generated the
    /// access stream (`spmttkrp` for every legacy entry point).
    pub kernel: String,
    pub mode: usize,
    /// The resolved (and config-tuned) technology this mode ran on. The
    /// energy model reads its Table III constants straight from here, so
    /// a report is self-describing even for config-file technologies.
    pub tech: MemTechnology,
    pub rank: usize,
    pub fabric_hz: f64,
    pub pes: Vec<PeReport>,
}

impl ModeReport {
    /// Mode runtime = slowest PE (they run concurrently).
    pub fn runtime_cycles(&self) -> f64 {
        self.pes.iter().map(|p| p.runtime_cycles()).fold(0.0, f64::max)
    }

    pub fn runtime_s(&self) -> f64 {
        self.runtime_cycles() / self.fabric_hz
    }

    pub fn total_nnz(&self) -> u64 {
        self.pes.iter().map(|p| p.nnz).sum()
    }

    /// Aggregate cache hit rate over all PEs.
    pub fn hit_rate(&self) -> f64 {
        let (mut h, mut a) = (0u64, 0u64);
        for p in &self.pes {
            h += p.cache_stats.hits;
            a += p.cache_stats.accesses();
        }
        if a == 0 {
            0.0
        } else {
            h as f64 / a as f64
        }
    }

    /// Standard error of the mode runtime under sampled replay: the
    /// slowest PE determines the runtime, so its stall band is the
    /// mode's band. `0.0` for exact replay.
    pub fn stall_stderr_cycles(&self) -> f64 {
        self.pes
            .iter()
            .max_by(|a, b| a.runtime_cycles().partial_cmp(&b.runtime_cycles()).unwrap())
            .map(|p| p.stall_stderr_cycles)
            .unwrap_or(0.0)
    }

    /// Fraction of the mode's nonzeros that were event-timed (1.0 =
    /// exact replay).
    pub fn sampled_frac(&self) -> f64 {
        let nnz = self.total_nnz();
        if nnz == 0 {
            1.0
        } else {
            self.pes.iter().map(|p| p.sampled_nnz).sum::<u64>() as f64 / nnz as f64
        }
    }

    /// Bottleneck of the slowest PE.
    pub fn bottleneck(&self) -> Resource {
        self.pes
            .iter()
            .max_by(|a, b| a.runtime_cycles().partial_cmp(&b.runtime_cycles()).unwrap())
            .map(|p| p.bottleneck())
            .unwrap_or(Resource::Dram)
    }

    /// Aggregates for the energy model.
    pub fn total_dram_bytes(&self) -> u64 {
        self.pes.iter().map(|p| p.dram_stream_bytes + p.dram_random_bytes).sum()
    }
    pub fn total_dram_random_accesses(&self) -> u64 {
        self.pes.iter().map(|p| p.dram_random_accesses).sum()
    }
    pub fn total_onchip_words(&self) -> u64 {
        self.pes.iter().map(|p| p.onchip_words()).sum()
    }

    /// Hierarchy rollup across the mode's PEs: counters sum, busy takes
    /// the per-level max (PEs run concurrently, mirroring
    /// [`Self::runtime_cycles`]). Empty for the degenerate config.
    pub fn levels(&self) -> Vec<LevelReport> {
        let mut acc = Vec::new();
        for p in &self.pes {
            merge_level_reports(&mut acc, &p.levels, true);
        }
        acc
    }

    /// PE load imbalance: max/mean nnz ratio (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        if self.pes.is_empty() {
            return 1.0;
        }
        let max = self.pes.iter().map(|p| p.nnz).max().unwrap() as f64;
        let mean = self.total_nnz() as f64 / self.pes.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// All modes of one tensor on one technology.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub tensor: String,
    /// Name of the kernel every mode ran (reports are kernel-uniform).
    pub kernel: String,
    pub tech: MemTechnology,
    pub modes: Vec<ModeReport>,
}

impl SimReport {
    /// Total spMTTKRP time: the paper's experiments execute all modes in
    /// sequence (M0..M_{N−1} on the Fig. 7 x-axis).
    pub fn total_runtime_s(&self) -> f64 {
        self.modes.iter().map(|m| m.runtime_s()).sum()
    }

    pub fn total_runtime_cycles(&self) -> f64 {
        self.modes.iter().map(|m| m.runtime_cycles()).sum()
    }

    /// Root-sum-square standard error of the total runtime in cycles:
    /// per-mode sampled-stall estimates are independent (disjoint chunk
    /// populations, independent admission coordinates), so their
    /// variances add. `0.0` for exact replay.
    pub fn total_stall_stderr_cycles(&self) -> f64 {
        self.modes.iter().map(|m| m.stall_stderr_cycles().powi(2)).sum::<f64>().sqrt()
    }

    /// [`Self::total_stall_stderr_cycles`] converted to seconds via each
    /// mode's own fabric clock.
    pub fn total_runtime_stderr_s(&self) -> f64 {
        self.modes
            .iter()
            .map(|m| (m.stall_stderr_cycles() / m.fabric_hz).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    /// Hierarchy rollup across modes: counters *and* busy cycles sum
    /// (modes execute sequentially, mirroring
    /// [`Self::total_runtime_cycles`]). Empty for the degenerate config.
    pub fn levels(&self) -> Vec<LevelReport> {
        let mut acc = Vec::new();
        for m in &self.modes {
            merge_level_reports(&mut acc, &m.levels(), false);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;

    fn pe(dram: f64, cache: f64, psum: f64) -> PeReport {
        PeReport {
            pe: 0,
            nnz: 100,
            slices: 10,
            dram_cycles: dram,
            cache_cycles: vec![cache, cache / 2.0],
            psum_cycles: psum,
            pipeline_cycles: 1.0,
            stream_dma_cycles: 0.5,
            element_dma_cycles: 0.0,
            latency_overhead_cycles: 2.0,
            stall_cycles: 0.0,
            stall_stderr_cycles: 0.0,
            sampled_nnz: 100,
            cache_stats: CacheStats { hits: 80, misses: 20, evictions: 5, writebacks: 0 },
            dram_stream_bytes: 1000,
            dram_random_bytes: 640,
            dram_random_accesses: 10,
            cache_words: 100,
            psum_words: 50,
            dma_words: 25,
            levels: vec![],
        }
    }

    #[test]
    fn runtime_is_max_resource_plus_latency() {
        let p = pe(10.0, 20.0, 5.0);
        assert_eq!(p.runtime_cycles(), 22.0);
        assert_eq!(p.bottleneck(), Resource::Cache);
        let p2 = pe(30.0, 20.0, 5.0);
        assert_eq!(p2.bottleneck(), Resource::Dram);
        assert_eq!(p2.runtime_cycles(), 32.0);
    }

    #[test]
    fn stall_extends_runtime_without_moving_the_bottleneck() {
        // the event engine reports contention as stall on top of the
        // bottleneck max; the bottleneck attribution must not change
        let mut p = pe(10.0, 20.0, 5.0);
        p.stall_cycles = 7.5;
        assert_eq!(p.runtime_cycles(), 29.5);
        assert_eq!(p.bottleneck(), Resource::Cache);
    }

    #[test]
    fn mode_runtime_is_slowest_pe() {
        let m = ModeReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            mode: 0,
            tech: esram(),
            rank: 16,
            fabric_hz: 500e6,
            pes: vec![pe(10.0, 5.0, 1.0), pe(40.0, 5.0, 1.0)],
        };
        assert_eq!(m.runtime_cycles(), 42.0);
        assert!((m.runtime_s() - 42.0 / 500e6).abs() < 1e-18);
        assert_eq!(m.total_nnz(), 200);
        assert!((m.hit_rate() - 0.8).abs() < 1e-12);
        assert_eq!(m.bottleneck(), Resource::Dram);
        assert_eq!(m.total_dram_bytes(), 2 * 1640);
        assert_eq!(m.total_onchip_words(), 2 * 175);
        assert!((m.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sim_report_sums_modes() {
        let m = ModeReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            mode: 0,
            tech: osram(),
            rank: 16,
            fabric_hz: 500e6,
            pes: vec![pe(10.0, 5.0, 1.0)],
        };
        let r = SimReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            tech: osram(),
            modes: vec![m.clone(), m],
        };
        assert_eq!(r.total_runtime_cycles(), 24.0);
    }

    #[test]
    fn stall_band_follows_the_slowest_pe_and_sums_in_quadrature() {
        let mut fast = pe(10.0, 5.0, 1.0);
        let mut slow = pe(40.0, 5.0, 1.0);
        fast.stall_stderr_cycles = 9.0; // not the runtime-determining PE
        slow.stall_stderr_cycles = 3.0;
        slow.sampled_nnz = 25;
        let m = ModeReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            mode: 0,
            tech: esram(),
            rank: 16,
            fabric_hz: 500e6,
            pes: vec![fast, slow],
        };
        assert_eq!(m.stall_stderr_cycles(), 3.0);
        assert!((m.sampled_frac() - 125.0 / 200.0).abs() < 1e-12);
        let r = SimReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            tech: esram(),
            modes: vec![m.clone(), m],
        };
        // two modes with stderr 3.0 each → sqrt(9 + 9)
        assert!((r.total_stall_stderr_cycles() - 18.0f64.sqrt()).abs() < 1e-12);
        assert!((r.total_runtime_stderr_s() - 18.0f64.sqrt() / 500e6).abs() < 1e-18);
        // exact reports carry a zero band by construction
        assert_eq!(pe(1.0, 1.0, 1.0).stall_stderr_cycles, 0.0);
        assert!((pe(1.0, 1.0, 1.0).sampled_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hierarchy_levels_roll_up_parallel_then_serial() {
        let level = LevelReport {
            name: "sram".into(),
            capacity_bytes: 256 * 1024,
            line_bytes: 64,
            double_buffer: false,
            accesses: 10,
            hits: 7,
            misses: 3,
            traffic_bytes: 640,
            words: 100,
            busy_cycles: 4.0,
        };
        let mut a = pe(10.0, 5.0, 1.0);
        let mut b = pe(10.0, 5.0, 1.0);
        a.levels = vec![level.clone()];
        let mut bl = level.clone();
        bl.busy_cycles = 9.0;
        b.levels = vec![bl];
        let m = ModeReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            mode: 0,
            tech: esram(),
            rank: 16,
            fabric_hz: 500e6,
            pes: vec![a, b],
        };
        let ml = m.levels();
        assert_eq!(ml.len(), 1);
        assert_eq!(ml[0].accesses, 20, "PE counters sum");
        assert_eq!(ml[0].busy_cycles, 9.0, "PE busy is a max (concurrent)");
        let r = SimReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            tech: esram(),
            modes: vec![m.clone(), m],
        };
        let rl = r.levels();
        assert_eq!(rl[0].accesses, 40, "mode counters sum");
        assert_eq!(rl[0].busy_cycles, 18.0, "mode busy sums (sequential)");
        // level words feed the Eq. 3 active-bits aggregate
        assert_eq!(r.modes[0].total_onchip_words(), 2 * (175 + 100));
    }

    #[test]
    fn hierarchy_competes_for_bottleneck_only_when_loaded() {
        let mut p = pe(10.0, 20.0, 5.0);
        p.levels = vec![LevelReport { busy_cycles: 0.0, ..Default::default() }];
        assert_eq!(p.bottleneck(), Resource::Cache, "zero-busy stack must not perturb ties");
        assert_eq!(p.runtime_cycles(), 22.0);
        p.levels[0].busy_cycles = 30.0;
        assert_eq!(p.bottleneck(), Resource::Hierarchy);
        assert_eq!(p.runtime_cycles(), 32.0);
        assert_eq!(Resource::Hierarchy.name(), "hierarchy");
    }

    #[test]
    fn imbalance_detects_skew() {
        let mut a = pe(1.0, 1.0, 1.0);
        let mut b = pe(1.0, 1.0, 1.0);
        a.nnz = 300;
        b.nnz = 100;
        let m = ModeReport {
            tensor: "t".into(),
            kernel: "spmttkrp".into(),
            mode: 0,
            tech: esram(),
            rank: 16,
            fabric_hz: 500e6,
            pes: vec![a, b],
        };
        assert!((m.imbalance() - 1.5).abs() < 1e-12);
    }
}
