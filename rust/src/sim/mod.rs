//! The simulation engines: pluggable workloads, two timing models.
//!
//! * [`result`] — [`result::SimReport`] / [`result::ModeReport`]: per-PE
//!   resource busy times, cache statistics, traffic and active-word
//!   counters, bottleneck identification, contention stall.
//! * [`engine`] — the **analytic** streaming bottleneck engine: walks a
//!   kernel's chunked access-stream IR through the memory controller /
//!   exec-unit timing models and prices a mode as its busiest resource's
//!   total occupancy (the paper's own roofline abstraction). O(nnz) per
//!   mode, O(chunk) memory.
//! * [`event`] — the **event-driven** contention engine: replays the
//!   identical access stream through bank-arbitrated caches, a FIFO DRAM
//!   channel and windowed execution slots, measuring the queueing and
//!   bank-conflict stalls the analytic engine hides. Same functional
//!   model, same traffic, `runtime ≥ analytic` by construction.
//! * [`sweep`] — the parallel design-space sweep: a deterministic
//!   {tensor × mode × technology × scale} cartesian product fanned across
//!   OS threads, on either engine, for any kernel.
//! * [`par`] — the deterministic slot-ordered parallel map shared by the
//!   sweep and by both engines' per-PE inner loops; [`SimBudget`] is the
//!   thread/chunk knob the two levels compose under.
//! * [`profile`] — the single-pass reuse-distance profiler: one decode
//!   traversal per `(tensor, mode, kernel)` builds per-set LRU
//!   stack-distance histograms that answer the analytic engine's
//!   functional counters for a **whole geometry sub-grid** at once
//!   ([`profile::profile_geometries`]); [`profile::price_report`] then
//!   reproduces the analytic [`result::SimReport`] bit-for-bit per
//!   `(tech, pricing knobs)` — the functional/timing split the explore
//!   screen runs on.
//!
//! The *workload* axis is just as open as the technology axis: both
//! backends consume the [`crate::kernel::SparseKernel`] access-stream IR
//! (`--kernel spmttkrp|spttm|spmm` on the CLI) and default to the paper's
//! spMTTKRP. Both backends implement the [`SimEngine`] trait and are
//! selected by [`EngineKind`] (`--engine analytic|event`). Use the
//! analytic engine for large sweeps (it is the paper's model and ~2×
//! faster); use the event engine to bound the analytic model's error on a
//! workload — the delta between the two is exactly the contention the
//! roofline abstraction cannot see (see EXPERIMENTS.md
//! §Cross-validation).

pub mod engine;
pub mod event;
pub mod par;
pub mod profile;
pub mod result;
pub mod sweep;

use crate::accel::config::AcceleratorConfig;
use crate::kernel::{KernelKind, SparseKernel, DEFAULT_CHUNK_NNZ};
use crate::mem::tech::MemTechnology;
use crate::sim::result::{ModeReport, SimReport};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

/// Seeded chunk-sampling policy for the event engine's contention
/// replay.
///
/// At `rate = 1.0` (the default, [`SampleSpec::exact`]) every
/// access-stream chunk is replayed and the event engine behaves exactly
/// as before — bit for bit. Below 1.0 the engine still walks **every**
/// chunk functionally (hit rates, traffic and active words stay exact),
/// but replays the contention timing only for a deterministic, seeded
/// subset of chunks and extrapolates `stall_cycles` to full-stream
/// scale, attaching a standard error
/// ([`result::PeReport::stall_stderr_cycles`]) derived from the
/// per-chunk stall variance. Chunk admission depends only on
/// `(seed, mode, pe, chunk index)` — never on thread scheduling — so a
/// sampled report is identical at any thread count and across runs.
///
/// The analytic engine ignores the spec entirely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleSpec {
    /// Fraction of access-stream chunks whose event timing is replayed,
    /// in `(0, 1]` (`--sample-rate` on the CLI).
    pub rate: f64,
    /// Seed of the chunk-admission hash (`--sample-seed` on the CLI);
    /// irrelevant at `rate = 1.0`.
    pub seed: u64,
}

// `rate` is validated finite and inside (0, 1] before use, so it is
// never NaN and the reflexivity Eq promises actually holds.
impl Eq for SampleSpec {}

impl Default for SampleSpec {
    fn default() -> Self {
        SampleSpec::exact()
    }
}

impl SampleSpec {
    /// Full replay: every chunk timed, the pre-sampling behaviour.
    pub const fn exact() -> Self {
        SampleSpec { rate: 1.0, seed: 0 }
    }

    /// A validated spec, or the same range error [`Self::validate`]
    /// reports.
    pub fn new(rate: f64, seed: u64) -> Result<Self, String> {
        let s = SampleSpec { rate, seed };
        s.validate()?;
        Ok(s)
    }

    /// True when every chunk is timed and the replay is bit-identical
    /// to the pre-sampling engine.
    pub fn is_exact(&self) -> bool {
        self.rate >= 1.0
    }

    /// Reject rates outside `(0, 1]`; the message names the valid range
    /// so the CLI can surface it verbatim.
    pub fn validate(&self) -> Result<(), String> {
        if !self.rate.is_finite() || self.rate <= 0.0 || self.rate > 1.0 {
            return Err(format!("sample rate {} outside (0, 1]", self.rate));
        }
        Ok(())
    }

    /// Deterministic chunk admission: does the event replay time chunk
    /// `chunk_idx` of PE `pe` in output mode `mode`? Chunk 0 of every PE
    /// is always admitted (at least one stall sample per PE); the rest
    /// pass a stateless SplitMix64-style hash of the coordinates against
    /// the rate threshold, so the same chunks are timed at any thread
    /// count.
    pub fn admits(&self, mode: usize, pe: usize, chunk_idx: u64) -> bool {
        if self.is_exact() || chunk_idx == 0 {
            return true;
        }
        let mut z = self
            .seed
            .wrapping_add((mode as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((pe as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
            .wrapping_add(chunk_idx.wrapping_mul(0x8CB9_2BA7_2F3D_8DD7));
        // SplitMix64 finalizer: avalanche the combined coordinates.
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        // 53-bit uniform in [0, 1), same construction as util::rng.
        ((z >> 11) as f64) < self.rate * (1u64 << 53) as f64
    }
}

/// Host-execution knobs for one simulation. `threads` and `chunk_nnz`
/// change how fast the simulator runs, **never** what it computes —
/// any thread count and any chunk size reproduce identical reports
/// (pinned by `rust/tests/parallel_determinism.rs`). `sample` is the
/// one deliberate exception: below `rate = 1.0` the event engine's
/// `stall_cycles` becomes a seeded statistical estimate (still
/// deterministic for a fixed seed, and chunk-granular — so a sampled
/// estimate legitimately depends on `chunk_nnz`). This struct lives
/// apart from [`AcceleratorConfig`], which describes the *modeled*
/// hardware.
///
/// **Thread-budget rule.** `threads` is a *budget*, shared between the
/// two parallelism levels so they compose without oversubscription: the
/// sweep engine fans scenarios across `min(budget, scenarios)` workers
/// and hands each simulation the left-over `budget / workers` threads
/// (≥ 1) for its per-PE inner loop. A saturated sweep therefore runs
/// each point single-threaded exactly as before, while a single
/// `simulate` run gives the whole budget to the PE loop — which is what
/// makes the paper's one-point Fig. 7/8 workflow use every core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimBudget {
    /// OS threads the per-PE inner loop may use; 0 = all available
    /// cores (`--threads` on the CLI).
    pub threads: usize,
    /// Nonzeros per access-stream chunk (`--chunk-nnz` on the CLI);
    /// bounds per-PE live memory, see [`crate::kernel::ir`].
    pub chunk_nnz: usize,
    /// Event-replay chunk sampling (`--sample-rate` / `--sample-seed`);
    /// [`SampleSpec::exact`] by default.
    pub sample: SampleSpec,
}

impl Default for SimBudget {
    fn default() -> Self {
        SimBudget { threads: 0, chunk_nnz: DEFAULT_CHUNK_NNZ, sample: SampleSpec::exact() }
    }
}

impl SimBudget {
    /// A budget of exactly `threads` threads, default chunking.
    pub fn with_threads(threads: usize) -> Self {
        SimBudget { threads, ..SimBudget::default() }
    }

    /// The sequential budget (the pre-parallel engine behaviour).
    pub fn single_threaded() -> Self {
        SimBudget::with_threads(1)
    }

    /// This budget with a different sampling policy.
    pub fn with_sample(self, sample: SampleSpec) -> Self {
        SimBudget { sample, ..self }
    }

    /// Threads the per-PE loop actually uses for `n_pes` PEs: the
    /// resolved budget, capped by the PE count (a PE is the unit of
    /// independent work).
    pub fn pe_threads(&self, n_pes: usize) -> usize {
        par::effective_threads(self.threads).min(n_pes.max(1))
    }

    /// Chunk granularity. Panics on zero: the CLI and [`crate::sim::sweep`]
    /// reject it with a proper error first, so a zero reaching here is a
    /// library-caller bug (e.g. truncated integer arithmetic) that must
    /// fail loudly rather than silently degrade into 1-nonzero chunks.
    pub fn chunk(&self) -> usize {
        assert!(self.chunk_nnz > 0, "SimBudget::chunk_nnz must be positive");
        self.chunk_nnz
    }
}

/// A simulation backend: prices one output mode of a sparse kernel on
/// one registry-resolved memory technology.
///
/// Both implementations share the functional model (caches, traffic,
/// active words), the kernel access-stream IR and the
/// [`engine::partition_slices`] work split; they differ only in how
/// per-request timing composes into a runtime. Any [`ModeReport`] they
/// return feeds the energy/area models identically.
pub trait SimEngine: Send + Sync {
    /// Short stable name (`analytic`, `event`) used by the CLI and
    /// report headers.
    fn name(&self) -> &'static str;

    /// Simulate one mode of `kernel` with a caller-supplied mode view
    /// (`view` must be `ModeView::build(tensor, mode)` for the same
    /// tensor and mode) under an explicit host-execution budget. The one
    /// required method — everything else derives from it.
    #[allow(clippy::too_many_arguments)]
    fn simulate_kernel_mode_with_view_budget(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport;

    /// [`Self::simulate_kernel_mode_with_view_budget`] under the default
    /// budget (all cores, default chunking) — budget choice never changes
    /// the report, only how fast it is produced.
    fn simulate_kernel_mode_with_view(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.simulate_kernel_mode_with_view_budget(
            kernel,
            tensor,
            view,
            mode,
            cfg,
            tech,
            SimBudget::default(),
        )
    }

    /// Simulate one mode of `kernel` under an explicit budget (builds
    /// the view itself).
    fn simulate_kernel_mode_budget(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport {
        let view = ModeView::build(tensor, mode);
        self.simulate_kernel_mode_with_view_budget(kernel, tensor, &view, mode, cfg, tech, budget)
    }

    /// Simulate one mode of `kernel` (builds the view itself).
    fn simulate_kernel_mode(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        let view = ModeView::build(tensor, mode);
        self.simulate_kernel_mode_with_view(kernel, tensor, &view, mode, cfg, tech)
    }

    /// Simulate every listed `(mode, view)` of `kernel` from prebuilt,
    /// memoized views under an explicit budget — the multi-mode
    /// primitive, and the **single** place a [`SimReport`] is assembled,
    /// so the memoized driver/sweep paths can never drift from the
    /// build-it-yourself paths.
    fn simulate_kernel_all_modes_with_views_budget(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        views: &[(usize, ModeView)],
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> SimReport {
        let modes = views
            .iter()
            .map(|(m, view)| {
                self.simulate_kernel_mode_with_view_budget(
                    kernel, tensor, view, *m, cfg, tech, budget,
                )
            })
            .collect();
        SimReport {
            tensor: tensor.name.clone(),
            kernel: kernel.name().to_string(),
            tech: cfg.tuned_tech(tech),
            modes,
        }
    }

    /// Simulate every output mode of `kernel` (builds the views itself).
    fn simulate_kernel_all_modes(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> SimReport {
        let views: Vec<(usize, ModeView)> =
            (0..tensor.n_modes()).map(|m| (m, ModeView::build(tensor, m))).collect();
        self.simulate_kernel_all_modes_with_views_budget(
            kernel,
            tensor,
            &views,
            cfg,
            tech,
            SimBudget::default(),
        )
    }

    /// [`Self::simulate_kernel_mode_with_view`] on the default spMTTKRP
    /// kernel.
    fn simulate_mode_with_view(
        &self,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.simulate_kernel_mode_with_view(
            KernelKind::Spmttkrp.kernel(),
            tensor,
            view,
            mode,
            cfg,
            tech,
        )
    }

    /// Simulate one spMTTKRP mode (builds the view itself).
    fn simulate_mode(
        &self,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.simulate_kernel_mode(KernelKind::Spmttkrp.kernel(), tensor, mode, cfg, tech)
    }

    /// Simulate every output mode of spMTTKRP (the full Fig. 7 x-axis).
    fn simulate_all_modes(
        &self,
        tensor: &SparseTensor,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> SimReport {
        self.simulate_kernel_all_modes(KernelKind::Spmttkrp.kernel(), tensor, cfg, tech)
    }
}

/// The analytic bottleneck backend ([`engine`]).
struct AnalyticEngine;

impl SimEngine for AnalyticEngine {
    fn name(&self) -> &'static str {
        "analytic"
    }
    fn simulate_kernel_mode_with_view_budget(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport {
        engine::simulate_kernel_mode_with_view_budget(kernel, tensor, view, mode, cfg, tech, budget)
    }
}

/// The event-driven contention backend ([`event`]).
struct EventEngine;

impl SimEngine for EventEngine {
    fn name(&self) -> &'static str {
        "event"
    }
    fn simulate_kernel_mode_with_view_budget(
        &self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport {
        event::simulate_kernel_mode_event_with_view_budget(
            kernel,
            tensor,
            view,
            mode,
            cfg,
            tech,
            budget,
        )
    }
}

/// Engine selector: every registered simulation backend, by name.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// The paper's bottleneck/roofline model ([`engine`]) — the default.
    #[default]
    Analytic,
    /// The cycle-level contention replay ([`event`]).
    Event,
}

impl EngineKind {
    /// Every registered backend, in CLI listing order.
    pub const ALL: [EngineKind; 2] = [EngineKind::Analytic, EngineKind::Event];

    /// The stable CLI/report name.
    pub fn name(self) -> &'static str {
        self.engine().name()
    }

    /// The backend implementation this selector names.
    pub fn engine(self) -> &'static dyn SimEngine {
        match self {
            EngineKind::Analytic => &AnalyticEngine,
            EngineKind::Event => &EventEngine,
        }
    }

    /// Parse a CLI spelling; the error lists the valid options.
    pub fn parse(s: &str) -> Result<Self, String> {
        Self::ALL
            .into_iter()
            .find(|k| k.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
                format!("unknown engine `{s}` (expected one of: {})", names.join(", "))
            })
    }

    /// [`SimEngine::simulate_mode`] on the selected backend.
    pub fn simulate_mode(
        self,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.engine().simulate_mode(tensor, mode, cfg, tech)
    }

    /// [`SimEngine::simulate_mode_with_view`] on the selected backend.
    pub fn simulate_mode_with_view(
        self,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.engine().simulate_mode_with_view(tensor, view, mode, cfg, tech)
    }

    /// [`SimEngine::simulate_all_modes`] on the selected backend.
    pub fn simulate_all_modes(
        self,
        tensor: &SparseTensor,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> SimReport {
        self.engine().simulate_all_modes(tensor, cfg, tech)
    }

    /// [`SimEngine::simulate_kernel_mode`] on the selected backend.
    pub fn simulate_kernel_mode(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.engine().simulate_kernel_mode(kernel, tensor, mode, cfg, tech)
    }

    /// [`SimEngine::simulate_kernel_mode_with_view`] on the selected
    /// backend.
    pub fn simulate_kernel_mode_with_view(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> ModeReport {
        self.engine().simulate_kernel_mode_with_view(kernel, tensor, view, mode, cfg, tech)
    }

    /// [`SimEngine::simulate_kernel_mode_with_view_budget`] on the
    /// selected backend.
    #[allow(clippy::too_many_arguments)]
    pub fn simulate_kernel_mode_with_view_budget(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        view: &ModeView,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport {
        self.engine()
            .simulate_kernel_mode_with_view_budget(kernel, tensor, view, mode, cfg, tech, budget)
    }

    /// [`SimEngine::simulate_kernel_mode_budget`] on the selected
    /// backend.
    pub fn simulate_kernel_mode_budget(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        mode: usize,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> ModeReport {
        self.engine().simulate_kernel_mode_budget(kernel, tensor, mode, cfg, tech, budget)
    }

    /// [`SimEngine::simulate_kernel_all_modes`] on the selected backend.
    pub fn simulate_kernel_all_modes(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
    ) -> SimReport {
        self.engine().simulate_kernel_all_modes(kernel, tensor, cfg, tech)
    }

    /// [`SimEngine::simulate_kernel_all_modes_with_views_budget`] on the
    /// selected backend.
    pub fn simulate_kernel_all_modes_with_views_budget(
        self,
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        views: &[(usize, ModeView)],
        cfg: &AcceleratorConfig,
        tech: &MemTechnology,
        budget: SimBudget,
    ) -> SimReport {
        self.engine()
            .simulate_kernel_all_modes_with_views_budget(kernel, tensor, views, cfg, tech, budget)
    }
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::tensor::gen;

    #[test]
    fn engine_kinds_parse_and_display() {
        assert_eq!(EngineKind::parse("analytic"), Ok(EngineKind::Analytic));
        assert_eq!(EngineKind::parse("event"), Ok(EngineKind::Event));
        assert_eq!("event".parse::<EngineKind>(), Ok(EngineKind::Event));
        let err = EngineKind::parse("roofline").unwrap_err();
        assert!(err.contains("analytic") && err.contains("event"), "{err}");
        assert_eq!(EngineKind::default(), EngineKind::Analytic);
        assert_eq!(EngineKind::Event.to_string(), "event");
    }

    #[test]
    fn sim_budget_resolves_threads_and_rejects_zero_chunk() {
        assert!(SimBudget::default().chunk() >= 1);
        assert!(SimBudget::default().pe_threads(4) >= 1);
        assert_eq!(SimBudget::single_threaded().pe_threads(8), 1);
        // the budget is capped by the PE count — the unit of work
        assert_eq!(SimBudget::with_threads(16).pe_threads(4), 4);
        assert_eq!(SimBudget::with_threads(2).pe_threads(4), 2);
        // a zero chunk is a caller bug and fails loudly, never silently
        let z = SimBudget { threads: 1, chunk_nnz: 0, ..SimBudget::default() };
        assert!(std::panic::catch_unwind(move || z.chunk()).is_err());
    }

    #[test]
    fn sample_spec_validates_the_rate_range() {
        assert!(SampleSpec::exact().validate().is_ok());
        assert!(SampleSpec::new(0.25, 7).is_ok());
        assert!(SampleSpec::new(1.0, 0).is_ok());
        for bad in [0.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            let err = SampleSpec::new(bad, 0).unwrap_err();
            assert!(err.contains("(0, 1]"), "{err}");
        }
        assert!(SampleSpec::exact().is_exact());
        assert!(!SampleSpec { rate: 0.5, seed: 0 }.is_exact());
        assert_eq!(SimBudget::default().sample, SampleSpec::exact());
    }

    #[test]
    fn sample_admission_is_deterministic_and_near_the_rate() {
        let s = SampleSpec { rate: 0.25, seed: 42 };
        // chunk 0 is always admitted: at least one stall sample per PE
        assert!(s.admits(0, 0, 0) && s.admits(2, 7, 0));
        // pure function of the coordinates — same answer on every call
        for c in 0..256u64 {
            assert_eq!(s.admits(1, 3, c), s.admits(1, 3, c));
        }
        // admitted fraction tracks the rate over a long chunk sequence
        let n = 20_000u64;
        let hits = (0..n).filter(|&c| s.admits(0, 0, c)).count() as f64;
        let frac = hits / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
        // a different seed selects a different subset
        let t = SampleSpec { rate: 0.25, seed: 43 };
        assert!((1..n).any(|c| s.admits(0, 0, c) != t.admits(0, 0, c)));
        // exact specs admit everything regardless of seed
        assert!((0..n).all(|c| SampleSpec::exact().admits(0, 0, c)));
    }

    #[test]
    fn trait_dispatch_matches_direct_calls() {
        let t = gen::random(&[64, 64, 64], 3_000, 2);
        let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        let a1 = EngineKind::Analytic.simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let a2 = engine::simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        assert_eq!(a1.runtime_cycles().to_bits(), a2.runtime_cycles().to_bits());
        let e1 = EngineKind::Event.simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let e2 = event::simulate_mode_event(&t, 0, &cfg, &tech("o-sram"));
        assert_eq!(e1.runtime_cycles().to_bits(), e2.runtime_cycles().to_bits());
    }

    #[test]
    fn default_kernel_is_spmttkrp_on_both_backends() {
        // the legacy entry points and the kernel-aware ones must be the
        // same simulation, bit for bit
        let t = gen::random(&[48, 48, 48], 2_000, 6);
        let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        let kernel = KernelKind::Spmttkrp.kernel();
        for kind in EngineKind::ALL {
            let legacy = kind.simulate_mode(&t, 0, &cfg, &tech("o-sram"));
            let explicit = kind.simulate_kernel_mode(kernel, &t, 0, &cfg, &tech("o-sram"));
            assert_eq!(
                legacy.runtime_cycles().to_bits(),
                explicit.runtime_cycles().to_bits(),
                "{kind}"
            );
            assert_eq!(legacy.kernel, "spmttkrp");
        }
    }

    #[test]
    fn all_modes_via_trait_has_full_shape() {
        let t = gen::random(&[32, 32, 32], 1_000, 4);
        let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        for kind in EngineKind::ALL {
            let r = kind.simulate_all_modes(&t, &cfg, &tech("e-sram"));
            assert_eq!(r.modes.len(), 3, "{kind}");
            assert_eq!(r.tech.name, "e-sram");
            assert_eq!(r.kernel, "spmttkrp");
        }
    }

    #[test]
    fn kernel_all_modes_via_trait_carries_the_kernel_name() {
        let t = gen::random(&[32, 32, 32], 1_000, 4);
        let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        for kernel in KernelKind::ALL {
            for kind in EngineKind::ALL {
                let r = kind.simulate_kernel_all_modes(kernel.kernel(), &t, &cfg, &tech("e-sram"));
                assert_eq!(r.modes.len(), 3, "{kernel}/{kind}");
                assert_eq!(r.kernel, kernel.name());
                for m in &r.modes {
                    assert_eq!(m.kernel, kernel.name());
                }
            }
        }
    }
}
