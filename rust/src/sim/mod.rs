//! The cycle-approximate simulation engine.
//!
//! * [`result`] — [`result::SimReport`] / [`result::ModeReport`]: per-PE
//!   resource busy times, cache statistics, traffic and active-word
//!   counters, bottleneck identification.
//! * [`engine`] — the streaming bottleneck engine: walks the mode-sorted
//!   nonzero stream through the memory controller / exec-unit timing
//!   models, O(nnz) per mode, for any registry-resolved technology.
//! * [`sweep`] — the parallel design-space sweep: a deterministic
//!   {tensor × mode × technology × scale} cartesian product fanned across
//!   OS threads.

pub mod engine;
pub mod result;
pub mod sweep;
