//! Single-pass reuse-distance profiling: the **functional** half of the
//! explore screen, computed for a whole geometry sub-grid in one stream
//! walk.
//!
//! The analytic engine's per-candidate work splits cleanly in two
//! (see [`crate::controller::mc`] §Functional/timing split):
//!
//! 1. a **functional pass** — hit/miss/traffic/active-word counters, a
//!    pure function of `{tensor, mode, kernel, cache geometry, level
//!    stack}` and *nothing else* (no technology, no `n_pes`-independent
//!    knob, no rank);
//! 2. a **pricing pass** — multiply those integer counters by hoisted
//!    per-technology occupancy constants and assemble the report
//!    ([`MemoryController::load_counts`] + the shared
//!    [`crate::sim::engine`] pricing helpers).
//!
//! [`profile_geometries`] runs pass 1 for *every* distinct geometry of
//! an explore grid in **one decode traversal per mode**: empty-stack
//! geometries are answered by per-set Mattson LRU stack-distance
//! histograms ([`crate::cache::lru::StackDistance`]) over the coarsened
//! row keys — the inclusion property means one truncated recency stack
//! per set answers hit/miss/eviction counts for every associativity at
//! once — while leveled geometries (the `sram_kib`/`local_kib` axes)
//! ride the same walk on real functional controllers. Per-PE boundaries
//! ([`partition_slices`]) finalize and reset the state, so every
//! `n_pes` value of the grid shares the walk too.
//!
//! [`price_report`] is pass 2: it reproduces the analytic engine's
//! [`SimReport`] **bit for bit** from a profile (pinned by the parity
//! tests below and `rust/tests/profile_parity.rs`), which is what lets
//! [`crate::explore::search`] screen a grid of G candidates with O(1)
//! stream walks instead of O(G).

use crate::accel::config::AcceleratorConfig;
use crate::cache::cache::{mix_key, row_key, CacheStats};
use crate::cache::lru::StackDistance;
use crate::cache::pipeline::ArrayTiming;
use crate::controller::mc::{FunctionalCounts, MemoryController};
use crate::kernel::{AccessChunk, SparseKernel};
use crate::mem::tech::MemTechnology;
use crate::obs::Span;
use crate::pe::exec::ExecUnit;
use crate::sim::engine::{
    assemble_pe_report, charge_streams, nnz_item_bytes, partition_slices, price_exec,
    startup_latency,
};
use crate::sim::result::{ModeReport, SimReport};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

/// Per-PE functional result of a profiled walk: exactly what the
/// pricing pass needs to reproduce the analytic engine's per-PE report
/// (work counters + the controller's [`FunctionalCounts`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PeProfile {
    /// Nonzeros the PE's slice range retires.
    pub nnz: u64,
    /// Slices in the range (= psum drains = output rows streamed out).
    pub slices: u64,
    /// The PE controller's functional counters after the walk.
    pub counts: FunctionalCounts,
}

/// One geometry's functional profile across every requested mode:
/// `modes[i]` holds the per-PE profiles for `views[i]`, in PE order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GeometryProfile {
    pub modes: Vec<Vec<PeProfile>>,
}

/// The §IV-A type-3 bypass routing decision, per input slot — must
/// mirror [`MemoryController::new`] exactly (the signature partitions
/// the stream between the stack-distance path and the element-DMA
/// counter, and it depends on `cache_lines`, so it is part of a stack
/// group's identity).
fn bypass_signature(cfg: &AcceleratorConfig, matrix_rows: &[u64]) -> Vec<bool> {
    let capacity_lines = cfg.cache_lines as u64;
    matrix_rows
        .iter()
        .map(|&rows| match cfg.cache_bypass_factor {
            Some(f) => rows > capacity_lines * f as u64,
            None => false,
        })
        .collect()
}

/// One shared stack-distance state: every empty-stack geometry with the
/// same bypass signature, cache count and set count reads its exact
/// per-associativity [`CacheStats`] out of this group.
struct StackGroup {
    sig: Vec<bool>,
    n_caches: usize,
    sets: usize,
    /// Largest associativity any member needs (`StackDistance` cap).
    cap: usize,
    /// One truncated recency stack per cache (same routing as the
    /// controller: slot % n_caches).
    stacks: Vec<StackDistance>,
    /// Bypassed loads since the last PE boundary (element-DMA count).
    bypassed: u64,
    /// Geometry indices (into the caller's cfg list) answered here.
    members: Vec<usize>,
}

/// All profiling state for one `n_pes` value: the slice partition, the
/// walk cursor, and the stack groups / leveled controllers that reset
/// at this partition's PE boundaries.
struct Bucket {
    n_pes: usize,
    parts: Vec<(usize, usize)>,
    /// Current PE (index into `parts`).
    p: usize,
    /// Nonzeros since the last PE boundary.
    pe_nnz: u64,
    groups: Vec<StackGroup>,
    /// `(geometry index, controller)` for leveled geometries — they
    /// ride the same walk on real functional controllers.
    leveled: Vec<(usize, MemoryController)>,
}

/// Close out bucket PE `b.p`: derive every member geometry's
/// [`FunctionalCounts`] for this PE, reset the functional state cold
/// (the next PE owns a fresh controller in the engines), advance.
fn finalize_pe(
    b: &mut Bucket,
    cfgs: &[&AcceleratorConfig],
    walk_tech: &MemTechnology,
    matrix_rows: &[u64],
    vi: usize,
    out: &mut [GeometryProfile],
) {
    let (lo, hi) = b.parts[b.p];
    let slices = (hi - lo) as u64;
    for g in &mut b.groups {
        for &gi in &g.members {
            let assoc = cfgs[gi].cache_assoc;
            let cache_stats: Vec<CacheStats> =
                g.stacks.iter().map(|sd| sd.stats_at(assoc)).collect();
            // factor streams are read-only, so writebacks are always 0
            // and every DRAM line access is a bypass load or a miss fill
            let misses: u64 = cache_stats.iter().map(|s| s.misses + s.writebacks).sum();
            let counts = FunctionalCounts {
                cache_stats,
                element_accesses: g.bypassed,
                dram_line_accesses: g.bypassed + misses,
                dram_hier_accesses: 0,
                levels: Vec::new(),
            };
            out[gi].modes[vi].push(PeProfile { nnz: b.pe_nnz, slices, counts });
        }
        g.bypassed = 0;
        for sd in &mut g.stacks {
            sd.reset();
        }
    }
    for (gi, mc) in &mut b.leveled {
        out[*gi].modes[vi].push(PeProfile { nnz: b.pe_nnz, slices, counts: mc.counts() });
        *mc = MemoryController::new(cfgs[*gi], walk_tech, matrix_rows);
    }
    b.pe_nnz = 0;
    b.p += 1;
}

/// Profile every geometry in `cfgs` over every `(mode, view)` of
/// `views` with **one decode traversal per mode** — the functional
/// pass of the explore screen. Entry `i` of the result corresponds to
/// `cfgs[i]`; only the functional-geometry fields of each config are
/// consulted (`n_pes`, cache counts/lines/assoc, line bytes, the
/// bypass factor and the level stack — see
/// [`crate::explore::key::canonical_geometry`]), so one representative
/// config per distinct geometry is enough.
///
/// The derived counts are **bit-identical** to walking each geometry
/// directly through [`MemoryController::factor_row_load`]; `chunk_nnz`
/// bounds decode scratch memory and never changes the counts.
pub fn profile_geometries(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    views: &[(usize, ModeView)],
    cfgs: &[&AcceleratorConfig],
    chunk_nnz: usize,
) -> Vec<GeometryProfile> {
    // Any technology works for the walk controllers: functional counts
    // are technology-independent by the controller's own split.
    let walk_tech = crate::mem::esram::esram();
    let mut out: Vec<GeometryProfile> = cfgs
        .iter()
        .map(|_| GeometryProfile { modes: vec![Vec::new(); views.len()] })
        .collect();
    let mut scratch = AccessChunk::default();
    for (vi, (mode, view)) in views.iter().enumerate() {
        // one span per decode traversal (inert unless recording is on)
        let _walk = Span::enter("profile.walk", "profile");
        let read_modes = kernel.read_modes(tensor, *mode);
        let rpn = read_modes.len();
        let matrix_rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();

        // Group the geometries: one bucket per n_pes value, one stack
        // group per (bypass signature, cache count, set count), one
        // live controller per leveled geometry.
        let mut buckets: Vec<Bucket> = Vec::new();
        for (gi, cfg) in cfgs.iter().enumerate() {
            let bi = match buckets.iter().position(|b| b.n_pes == cfg.n_pes) {
                Some(bi) => bi,
                None => {
                    buckets.push(Bucket {
                        n_pes: cfg.n_pes,
                        parts: partition_slices(view, cfg.n_pes),
                        p: 0,
                        pe_nnz: 0,
                        groups: Vec::new(),
                        leveled: Vec::new(),
                    });
                    buckets.len() - 1
                }
            };
            let b = &mut buckets[bi];
            if cfg.levels.is_empty() {
                let sig = bypass_signature(cfg, &matrix_rows);
                let sets = cfg.cache_sets();
                match b
                    .groups
                    .iter_mut()
                    .find(|g| g.sig == sig && g.n_caches == cfg.n_caches && g.sets == sets)
                {
                    Some(g) => {
                        g.cap = g.cap.max(cfg.cache_assoc);
                        g.members.push(gi);
                    }
                    None => b.groups.push(StackGroup {
                        sig,
                        n_caches: cfg.n_caches,
                        sets,
                        cap: cfg.cache_assoc,
                        stacks: Vec::new(),
                        bypassed: 0,
                        members: vec![gi],
                    }),
                }
            } else {
                b.leveled.push((gi, MemoryController::new(cfg, &walk_tech, &matrix_rows)));
            }
        }
        // caps are final only after every member registered
        for b in &mut buckets {
            for g in &mut b.groups {
                g.stacks = (0..g.n_caches).map(|_| StackDistance::new(g.sets, g.cap)).collect();
            }
        }

        // The single decode traversal: every bucket consumes the same
        // op sequence, finalizing at its own PE boundaries.
        let mut stream = kernel.stream(tensor, view, (0, view.n_slices()), chunk_nnz);
        let mut slice = 0usize;
        while stream.fill(&mut scratch) {
            let mut se = 0usize;
            for i in 0..scratch.n_nnz {
                let reads = &scratch.reads[i * rpn..(i + 1) * rpn];
                for b in &mut buckets {
                    while slice >= b.parts[b.p].1 {
                        finalize_pe(b, cfgs, &walk_tech, &matrix_rows, vi, &mut out);
                    }
                    b.pe_nnz += 1;
                    for read in reads {
                        let slot = read.slot() as usize;
                        for g in &mut b.groups {
                            if g.sig[slot] {
                                g.bypassed += 1;
                            } else {
                                let key = row_key(slot, read.row());
                                let set = (mix_key(key) as usize) & (g.sets - 1);
                                g.stacks[slot % g.n_caches].access(set, key);
                            }
                        }
                        for (_, mc) in &mut b.leveled {
                            let _ = mc.factor_row_load(slot, read.row());
                        }
                    }
                }
                if se < scratch.slice_ends.len() && scratch.slice_ends[se] as usize == i {
                    slice += 1;
                    se += 1;
                }
            }
        }
        // tail PEs (including valid empty ranges when n_pes > slices)
        for b in &mut buckets {
            while b.p < b.n_pes {
                finalize_pe(b, cfgs, &walk_tech, &matrix_rows, vi, &mut out);
            }
        }
    }
    out
}

/// Price one mode from its per-PE profiles: fresh controller per PE,
/// [`MemoryController::load_counts`], the verbatim stream replay, and
/// the same shared pricing helpers the walked engines use — so the
/// report is bit-identical to [`crate::sim::engine`]'s.
fn price_mode(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    t: &MemTechnology,
    pes_profile: &[PeProfile],
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    if let Err(e) = kernel.validate(tensor, mode) {
        panic!("kernel `{}` rejected the workload: {e}", kernel.name());
    }
    assert_eq!(pes_profile.len(), cfg.n_pes, "profile PE count mismatch");
    let read_modes = kernel.read_modes(tensor, mode);
    let matrix_rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();
    let banks = cfg.bank_factor(t);
    let psum_timing = ArrayTiming::new(t, cfg.fabric_hz, banks);
    let psum_banks = (cfg.n_pipelines / 10).max(1);
    let exec = ExecUnit::new(cfg.n_pipelines, cfg.rank, psum_timing, psum_banks);
    let per_nnz = kernel.nnz_exec(&exec, tensor.n_modes());
    let per_drain = kernel.drain_exec(&exec, tensor.n_modes());
    let item_bytes = nnz_item_bytes(tensor.n_modes());
    let row_bytes = kernel.out_row_bytes(cfg.rank, tensor.n_modes());
    let pes = pes_profile
        .iter()
        .enumerate()
        .map(|(pe_idx, p)| {
            let mut mc = MemoryController::new(cfg, t, &matrix_rows);
            mc.load_counts(&p.counts);
            charge_streams(&mut mc, p.nnz, p.slices, item_bytes, row_bytes);
            let latency_overhead = startup_latency(cfg, &mc);
            let (pipeline_cycles, psum_cycles, psum_words) =
                price_exec(&per_nnz, &per_drain, p.nnz, p.slices);
            assemble_pe_report(
                &mc,
                pe_idx,
                p.nnz,
                p.slices,
                pipeline_cycles,
                psum_cycles,
                psum_words,
                latency_overhead,
            )
        })
        .collect();
    ModeReport {
        tensor: tensor.name.clone(),
        kernel: kernel.name().to_string(),
        mode,
        tech: t.clone(),
        rank: cfg.rank,
        fabric_hz: cfg.fabric_hz,
        pes,
    }
}

/// The pricing pass: turn one geometry's [`GeometryProfile`] into the
/// full [`SimReport`] the analytic engine would produce for
/// `(cfg, tech)` — **bit-identical** to
/// [`crate::sim::SimEngine::simulate_kernel_all_modes_with_views_budget`]
/// on [`EngineKind::Analytic`](crate::sim::EngineKind), at any budget
/// (pinned by the parity tests). `views` must be the same list the
/// profile was built over.
pub fn price_report(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    views: &[(usize, ModeView)],
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    profile: &GeometryProfile,
) -> SimReport {
    assert_eq!(profile.modes.len(), views.len(), "profile/view mode count mismatch");
    cfg.validate().expect("invalid accelerator config");
    let t = cfg.tuned_tech(tech);
    let modes: Vec<ModeReport> = views
        .iter()
        .zip(&profile.modes)
        .map(|((mode, _view), pes)| price_mode(kernel, tensor, *mode, cfg, &t, pes))
        .collect();
    SimReport { tensor: tensor.name.clone(), kernel: kernel.name().to_string(), tech: t, modes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelKind;
    use crate::mem::registry::tech;
    use crate::sim::{engine, SimBudget};
    use crate::tensor::gen;

    /// A small grid spanning every profiling path: shared-stack
    /// geometries (n_pes × lines × assoc), a bypassing one, a leveled
    /// one.
    fn geometries() -> Vec<AcceleratorConfig> {
        let base = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        let mut out = Vec::new();
        for n_pes in [2usize, 4] {
            for lines_mul in [1usize, 2] {
                for assoc in [2usize, 4] {
                    let mut c = base.clone();
                    c.n_pes = n_pes;
                    c.cache_lines = base.cache_lines * lines_mul;
                    c.cache_assoc = assoc;
                    c.validate().unwrap();
                    out.push(c);
                }
            }
        }
        let mut bypass = base.clone();
        bypass.cache_bypass_factor = Some(1);
        bypass.validate().unwrap();
        out.push(bypass);
        let mut leveled = base.clone();
        leveled.levels =
            crate::mem::hierarchy::parse_levels("outer:64KiB:line256,inner:4KiB").unwrap();
        leveled.validate().unwrap();
        out.push(leveled);
        out
    }

    /// The reference: walk one geometry directly, a fresh controller
    /// per PE, exactly like the analytic engine's functional loop.
    fn direct_profiles(
        kernel: &dyn SparseKernel,
        tensor: &SparseTensor,
        views: &[(usize, ModeView)],
        cfg: &AcceleratorConfig,
    ) -> GeometryProfile {
        let walk_tech = crate::mem::esram::esram();
        let mut gp = GeometryProfile::default();
        for (mode, view) in views {
            let read_modes = kernel.read_modes(tensor, *mode);
            let rpn = read_modes.len();
            let rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();
            let mut pes = Vec::new();
            for (slo, shi) in engine::partition_slices(view, cfg.n_pes) {
                let mut mc = MemoryController::new(cfg, &walk_tech, &rows);
                let mut nnz = 0u64;
                for chunk in kernel.stream(tensor, view, (slo, shi), 777) {
                    nnz += chunk.n_nnz as u64;
                    for read in &chunk.reads[..chunk.n_nnz * rpn] {
                        let _ = mc.factor_row_load(read.slot() as usize, read.row());
                    }
                }
                pes.push(PeProfile { nnz, slices: (shi - slo) as u64, counts: mc.counts() });
            }
            gp.modes.push(pes);
        }
        gp
    }

    #[test]
    fn profiled_counts_match_direct_simulation_on_every_kernel() {
        let t = gen::random(&[96, 64, 80], 6_000, 17);
        let views: Vec<(usize, ModeView)> =
            (0..3).map(|m| (m, ModeView::build(&t, m))).collect();
        let geoms = geometries();
        let refs: Vec<&AcceleratorConfig> = geoms.iter().collect();
        for kind in KernelKind::ALL {
            let kernel = kind.kernel();
            let profiled = profile_geometries(kernel, &t, &views, &refs, 513);
            assert_eq!(profiled.len(), geoms.len());
            for (cfg, got) in geoms.iter().zip(&profiled) {
                let want = direct_profiles(kernel, &t, &views, cfg);
                assert_eq!(
                    got, &want,
                    "{kind}: pes={} lines={} assoc={} bypass={:?} levels={}",
                    cfg.n_pes,
                    cfg.cache_lines,
                    cfg.cache_assoc,
                    cfg.cache_bypass_factor,
                    cfg.levels.len()
                );
            }
        }
    }

    #[test]
    fn chunk_size_never_changes_a_profile() {
        let t = gen::random(&[64, 48, 48], 3_000, 5);
        let views: Vec<(usize, ModeView)> =
            (0..3).map(|m| (m, ModeView::build(&t, m))).collect();
        let geoms = geometries();
        let refs: Vec<&AcceleratorConfig> = geoms.iter().collect();
        let kernel = KernelKind::Spmttkrp.kernel();
        let a = profile_geometries(kernel, &t, &views, &refs, 1);
        let b = profile_geometries(kernel, &t, &views, &refs, 100_000);
        assert_eq!(a, b);
    }

    #[test]
    fn priced_report_is_bit_identical_to_the_analytic_engine() {
        let t = gen::random(&[128, 96, 64], 8_000, 23);
        let views: Vec<(usize, ModeView)> =
            (0..3).map(|m| (m, ModeView::build(&t, m))).collect();
        let geoms = geometries();
        let refs: Vec<&AcceleratorConfig> = geoms.iter().collect();
        let kernel = KernelKind::Spmttkrp.kernel();
        let profiled = profile_geometries(kernel, &t, &views, &refs, 4096);
        for (cfg, gp) in geoms.iter().zip(&profiled) {
            for tname in ["e-sram", "o-sram"] {
                let want = crate::sim::EngineKind::Analytic
                    .simulate_kernel_all_modes_with_views_budget(
                        kernel,
                        &t,
                        &views,
                        cfg,
                        &tech(tname),
                        SimBudget::single_threaded(),
                    );
                let got = price_report(kernel, &t, &views, cfg, &tech(tname), gp);
                assert_reports_identical(&want, &got, &format!("{tname} pes={}", cfg.n_pes));
            }
        }
    }

    #[test]
    fn empty_tensor_profiles_and_prices_cleanly() {
        let t = SparseTensor::new("empty", vec![10, 10]);
        let views: Vec<(usize, ModeView)> =
            (0..2).map(|m| (m, ModeView::build(&t, m))).collect();
        let base = AcceleratorConfig::paper_default().scaled(1.0 / 64.0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let profiled = profile_geometries(kernel, &t, &views, &[&base], 64);
        assert_eq!(profiled[0].modes.len(), 2);
        for pes in &profiled[0].modes {
            assert_eq!(pes.len(), base.n_pes);
            for p in pes {
                assert_eq!((p.nnz, p.slices), (0, 0));
                assert_eq!(p.counts.total_cache_stats().accesses(), 0);
            }
        }
        let want = crate::sim::EngineKind::Analytic.simulate_kernel_all_modes_with_views_budget(
            kernel,
            &t,
            &views,
            &base,
            &tech("o-sram"),
            SimBudget::single_threaded(),
        );
        let got = price_report(kernel, &t, &views, &base, &tech("o-sram"), &profiled[0]);
        assert_reports_identical(&want, &got, "empty");
    }

    fn assert_reports_identical(a: &SimReport, b: &SimReport, ctx: &str) {
        assert_eq!(a.tensor, b.tensor, "{ctx}");
        assert_eq!(a.kernel, b.kernel, "{ctx}");
        assert_eq!(a.tech.name, b.tech.name, "{ctx}");
        assert_eq!(a.modes.len(), b.modes.len(), "{ctx}");
        assert_eq!(a.total_runtime_s().to_bits(), b.total_runtime_s().to_bits(), "{ctx}");
        for (ma, mb) in a.modes.iter().zip(&b.modes) {
            assert_eq!(ma.mode, mb.mode, "{ctx}");
            assert_eq!(ma.rank, mb.rank, "{ctx}");
            assert_eq!(ma.runtime_cycles().to_bits(), mb.runtime_cycles().to_bits(), "{ctx}");
            assert_eq!(ma.pes.len(), mb.pes.len(), "{ctx}");
            for (pa, pb) in ma.pes.iter().zip(&mb.pes) {
                let m = format!("{ctx} mode {} pe {}", ma.mode, pa.pe);
                assert_eq!(pa.nnz, pb.nnz, "{m}");
                assert_eq!(pa.slices, pb.slices, "{m}");
                assert_eq!(pa.sampled_nnz, pb.sampled_nnz, "{m}");
                assert_eq!(pa.dram_cycles.to_bits(), pb.dram_cycles.to_bits(), "{m}");
                assert_eq!(pa.cache_cycles.len(), pb.cache_cycles.len(), "{m}");
                for (x, y) in pa.cache_cycles.iter().zip(&pb.cache_cycles) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{m}");
                }
                assert_eq!(pa.psum_cycles.to_bits(), pb.psum_cycles.to_bits(), "{m}");
                assert_eq!(pa.pipeline_cycles.to_bits(), pb.pipeline_cycles.to_bits(), "{m}");
                assert_eq!(
                    pa.stream_dma_cycles.to_bits(),
                    pb.stream_dma_cycles.to_bits(),
                    "{m}"
                );
                assert_eq!(
                    pa.element_dma_cycles.to_bits(),
                    pb.element_dma_cycles.to_bits(),
                    "{m}"
                );
                assert_eq!(
                    pa.latency_overhead_cycles.to_bits(),
                    pb.latency_overhead_cycles.to_bits(),
                    "{m}"
                );
                assert_eq!(pa.stall_cycles.to_bits(), pb.stall_cycles.to_bits(), "{m}");
                assert_eq!(pa.cache_stats, pb.cache_stats, "{m}");
                assert_eq!(pa.dram_stream_bytes, pb.dram_stream_bytes, "{m}");
                assert_eq!(pa.dram_random_bytes, pb.dram_random_bytes, "{m}");
                assert_eq!(pa.dram_random_accesses, pb.dram_random_accesses, "{m}");
                assert_eq!(pa.cache_words, pb.cache_words, "{m}");
                assert_eq!(pa.psum_words, pb.psum_words, "{m}");
                assert_eq!(pa.dma_words, pb.dma_words, "{m}");
                assert_eq!(pa.levels.len(), pb.levels.len(), "{m}");
                for (la, lb) in pa.levels.iter().zip(&pb.levels) {
                    assert_eq!(la.name, lb.name, "{m}");
                    assert_eq!(la.accesses, lb.accesses, "{m}");
                    assert_eq!(la.traffic_bytes, lb.traffic_bytes, "{m}");
                    assert_eq!(la.hits, lb.hits, "{m}");
                    assert_eq!(la.misses, lb.misses, "{m}");
                    assert_eq!(la.words, lb.words, "{m}");
                    assert_eq!(la.busy_cycles.to_bits(), lb.busy_cycles.to_bits(), "{m}");
                }
            }
        }
    }
}
