//! The parallel design-space sweep engine.
//!
//! The single-pair reproduction answers "is O-SRAM faster on this
//! tensor?"; the sweep engine answers the N-dimensional question the open
//! registry makes possible: the cartesian product of
//! **{tensor × mode × technology × configuration scale}**, fanned across
//! OS threads with scoped `std::thread` (no external dependencies) and
//! returned in a deterministic order — point `i` of the result vector is
//! always the same scenario with bit-identical numbers regardless of the
//! thread count (each point is computed independently from shared
//! immutable inputs, so no floating-point reduction order varies).
//!
//! Work is split in two parallel phases:
//!
//! 1. **Workload preparation** — one job per (tensor, scale): generate the
//!    tensor, apply the §IV-A degree remap, scale the accelerator config
//!    and build its energy model. Shared by every (tech, mode) point so
//!    generation cost is paid once, not `|techs| × |modes|` times.
//! 2. **Simulation** — one job per (workload, tech, mode): run the
//!    selected kernel ([`SweepSpec::kernel`]: any access-stream-IR
//!    builtin) on the selected backend ([`SweepSpec::engine`]: analytic
//!    bottleneck or event-driven contention replay) and price the run
//!    through Eq. 2–3.
//!
//! Parallelism composes across two levels under one thread budget (the
//! rule documented on [`crate::sim::SimBudget`]): the sweep claims
//! `min(threads, scenarios)` point workers and hands each simulation the
//! left-over threads for its per-PE inner loop — a saturated grid runs
//! points single-threaded exactly as before, while a sparse grid (or a
//! single giant point) pushes the budget down into the engines instead
//! of idling cores. Throughput notes live in EXPERIMENTS.md §Perf. The
//! CLI front-end is `photon-mttkrp sweep`.
//!
//! The sweep's grid varies the *workload* (tensor, scale, mode), so each
//! point genuinely needs its own stream walk. Grids that vary only
//! *hardware* knobs over a fixed workload are the explore screen's
//! domain, where the reuse-distance profiler ([`crate::sim::profile`])
//! prices the whole cache-geometry sub-grid from one walk.

use crate::accel::config::AcceleratorConfig;
use crate::energy::model::{EnergyBreakdown, EnergyModel};
use crate::kernel::{KernelKind, DEFAULT_CHUNK_NNZ};
use crate::mem::tech::MemTechnology;
use crate::sim::par::parallel_map;
use crate::sim::result::ModeReport;
use crate::sim::{EngineKind, SampleSpec, SimBudget};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;
use crate::tensor::gen::TensorSpec;
use crate::tensor::remap;
use crate::util::table::{Align, Table};

pub use crate::sim::par::effective_threads;

/// One sweep request: the axes of the cartesian product plus execution
/// knobs.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Tensor fingerprints to generate (axis 1).
    pub tensors: Vec<TensorSpec>,
    /// Workload/accelerator scale factors (axis 2); each scales both the
    /// tensor spec and the accelerator config coherently, like the paper
    /// reproduction does.
    pub scales: Vec<f64>,
    /// Registry-resolved technologies (axis 3).
    pub techs: Vec<MemTechnology>,
    /// Output modes to simulate (axis 4); `None` = every mode of each
    /// tensor (modes beyond a tensor's arity are skipped, so mixed-arity
    /// suites sweep cleanly).
    pub modes: Option<Vec<usize>>,
    /// Unscaled base accelerator configuration.
    pub base_cfg: AcceleratorConfig,
    /// Generator seed (one seed ⇒ one deterministic result set).
    pub seed: u64,
    /// OS threads to fan across; 0 = all available cores.
    pub threads: usize,
    /// Apply the §IV-A memory mapping before simulating (the driver-path
    /// behaviour; `false` is the raw-engine ablation).
    pub remap: bool,
    /// Simulation backend every point runs on (axis-uniform so speedup
    /// columns compare like with like); default [`EngineKind::Analytic`].
    pub engine: EngineKind,
    /// Sparse kernel every point runs (axis-uniform like the engine);
    /// default [`KernelKind::Spmttkrp`], the paper's workload.
    pub kernel: KernelKind,
    /// Access-stream chunk granularity handed to every simulation
    /// ([`SimBudget::chunk_nnz`]); bit-transparent, bounds per-PE live
    /// memory. Default [`DEFAULT_CHUNK_NNZ`].
    pub chunk_nnz: usize,
    /// Event-replay chunk sampling handed to every simulation
    /// ([`SimBudget::sample`]; `--sample-rate`/`--sample-seed` on the
    /// CLI). Ignored by the analytic engine; exact by default.
    pub sample: SampleSpec,
}

impl SweepSpec {
    /// A sweep over the given tensors/scales/techs with driver-path
    /// defaults: all modes, paper-default config, seed 42, all cores.
    pub fn new(tensors: Vec<TensorSpec>, scales: Vec<f64>, techs: Vec<MemTechnology>) -> Self {
        SweepSpec {
            tensors,
            scales,
            techs,
            modes: None,
            base_cfg: AcceleratorConfig::paper_default(),
            seed: 42,
            threads: 0,
            remap: true,
            engine: EngineKind::Analytic,
            kernel: KernelKind::Spmttkrp,
            chunk_nnz: DEFAULT_CHUNK_NNZ,
            sample: SampleSpec::exact(),
        }
    }

    /// Number of cartesian points this spec expands to.
    pub fn n_points(&self) -> usize {
        let modes_of = |spec: &TensorSpec| match &self.modes {
            None => spec.dims.len(),
            Some(ms) => ms.iter().filter(|&&m| m < spec.dims.len()).count(),
        };
        self.tensors.iter().map(|t| modes_of(t) * self.scales.len() * self.techs.len()).sum()
    }

    fn validate(&self) -> Result<(), String> {
        if self.tensors.is_empty() || self.scales.is_empty() || self.techs.is_empty() {
            return Err("sweep needs at least one tensor, scale and technology".into());
        }
        for &s in &self.scales {
            if !(s > 0.0 && s <= 1.0) {
                return Err(format!("sweep scale {s} outside (0, 1]"));
            }
        }
        if self.chunk_nnz == 0 {
            return Err("chunk_nnz must be positive".into());
        }
        self.sample.validate()?;
        let mut seen: Vec<&str> = Vec::new();
        for t in &self.techs {
            if seen.contains(&t.name.as_str()) {
                return Err(format!("technology `{}` listed twice", t.name));
            }
            seen.push(&t.name);
        }
        // duplicate tensor names would collide in per-scenario grouping
        // (e.g. the summary table's baseline lookup) and silently pair
        // rows with the wrong baseline
        let mut seen_tensors: Vec<&str> = Vec::new();
        for t in &self.tensors {
            if seen_tensors.contains(&t.name.as_str()) {
                return Err(format!("tensor `{}` listed twice", t.name));
            }
            seen_tensors.push(&t.name);
        }
        // a typo'd --mode must not masquerade as a successful empty run
        if self.n_points() == 0 {
            return Err(format!(
                "sweep expands to zero scenarios: mode filter {:?} matches no tensor arity",
                self.modes.as_deref().unwrap_or(&[])
            ));
        }
        Ok(())
    }
}

/// One evaluated scenario of the sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Stable index in the cartesian enumeration (== position in the
    /// result vector).
    pub index: usize,
    pub tensor: String,
    /// Name of the kernel this point ran ([`SweepSpec::kernel`]).
    pub kernel: String,
    pub scale: f64,
    pub tech: String,
    pub mode: usize,
    pub nnz: u64,
    /// The full per-PE report (timing, traffic, cache stats).
    pub report: ModeReport,
    /// Eq. 2–3 energy of this mode.
    pub energy: EnergyBreakdown,
}

impl SweepPoint {
    pub fn runtime_s(&self) -> f64 {
        self.report.runtime_s()
    }
    pub fn runtime_cycles(&self) -> f64 {
        self.report.runtime_cycles()
    }
    pub fn hit_rate(&self) -> f64 {
        self.report.hit_rate()
    }
    /// Energy-delay product of this scenario (J·s) — the same ranking
    /// accessor as [`crate::explore::Objectives::edp`], so sweep rows and
    /// explore candidates order identically under the EDP objective.
    pub fn edp(&self) -> f64 {
        self.energy.total_j() * self.runtime_s()
    }
}

/// A prepared (tensor × scale) workload shared by all its points: the
/// generated (and remapped) tensor, its scaled config/energy model, and
/// the prebuilt per-mode CSF views, so none of that O(nnz) work repeats
/// per technology.
struct Workload {
    tensor: SparseTensor,
    tensor_name: String,
    scale: f64,
    cfg: AcceleratorConfig,
    energy: EnergyModel,
    /// `(mode, view)` for every mode this sweep will simulate.
    views: Vec<(usize, ModeView)>,
}

/// The modes the spec simulates for a tensor of the given arity.
fn modes_for(spec: &SweepSpec, arity: usize) -> Vec<usize> {
    match &spec.modes {
        None => (0..arity).collect(),
        Some(ms) => ms.iter().copied().filter(|&m| m < arity).collect(),
    }
}

/// Run the sweep. Returns one [`SweepPoint`] per cartesian scenario, in
/// deterministic enumeration order (tensor-major, then scale, then tech,
/// then mode) regardless of `spec.threads`. (The parallel-map plumbing
/// lives in [`crate::sim::par`], shared with the engines' per-PE loops.)
pub fn run_sweep(spec: &SweepSpec) -> Result<Vec<SweepPoint>, String> {
    spec.validate()?;
    let threads = effective_threads(spec.threads);

    // Phase 1: prepare workloads (tensor × scale), in parallel.
    let wl_jobs: Vec<(usize, usize)> = (0..spec.tensors.len())
        .flat_map(|ti| (0..spec.scales.len()).map(move |si| (ti, si)))
        .collect();
    let workloads: Vec<Workload> = parallel_map(&wl_jobs, threads, |&(ti, si)| {
        let scale = spec.scales[si];
        let tspec = spec.tensors[ti].clone().scaled(scale);
        let mut tensor = tspec.generate(spec.seed);
        if spec.remap {
            let remaps = remap::degree_remaps(&tensor);
            remap::apply(&mut tensor, &remaps);
        }
        let cfg = spec.base_cfg.clone().scaled(scale);
        let energy = EnergyModel::new(&cfg);
        let views = modes_for(spec, tensor.n_modes())
            .into_iter()
            .map(|m| (m, ModeView::build(&tensor, m)))
            .collect();
        // group points under the *base* spec name; the scale is its own
        // axis (the scaled spec renames itself to e.g. `nell-2@1e-3`)
        Workload { tensor_name: spec.tensors[ti].name.clone(), tensor, scale, cfg, energy, views }
    });

    // Phase 2: enumerate and evaluate the cartesian points.
    let jobs: Vec<(usize, usize, usize)> = wl_jobs
        .iter()
        .enumerate()
        .flat_map(|(wi, &(ti, _))| {
            let modes = modes_for(spec, spec.tensors[ti].dims.len());
            spec.techs
                .iter()
                .enumerate()
                .flat_map(move |(xi, _)| modes.clone().into_iter().map(move |m| (wi, xi, m)))
                .collect::<Vec<_>>()
        })
        .collect();

    // Thread-budget rule (see `SimBudget`): the point fan-out claims
    // min(threads, jobs) workers; each simulation gets the left-over
    // threads for its per-PE inner loop. Saturated grid ⇒ pe_threads = 1
    // (identical to the pre-parallel-engine behaviour); small grid on a
    // big machine ⇒ the spare cores sink into the PE loops instead of
    // idling. Level products never exceed the requested budget.
    let point_workers = threads.min(jobs.len().max(1));
    let budget = SimBudget {
        threads: (threads / point_workers).max(1),
        chunk_nnz: spec.chunk_nnz,
        sample: spec.sample,
    };

    let points = parallel_map(&jobs, threads, |&(wi, xi, mode)| {
        let wl = &workloads[wi];
        let (_, view) = wl
            .views
            .iter()
            .find(|(m, _)| *m == mode)
            .expect("view prepared for every enumerated mode");
        let report = spec.engine.simulate_kernel_mode_with_view_budget(
            spec.kernel.kernel(),
            &wl.tensor,
            view,
            mode,
            &wl.cfg,
            &spec.techs[xi],
            budget,
        );
        let energy = wl.energy.mode_energy(&report);
        SweepPoint {
            index: 0, // fixed up below (enumeration order == job order)
            tensor: wl.tensor_name.clone(),
            kernel: spec.kernel.name().to_string(),
            scale: wl.scale,
            tech: spec.techs[xi].name.clone(),
            mode,
            nnz: report.total_nnz(),
            report,
            energy,
        }
    });
    let mut points = points;
    for (i, p) in points.iter_mut().enumerate() {
        p.index = i;
    }
    Ok(points)
}

/// Render the sweep as a table: one row per point, with each point's
/// speedup over the same scenario on the sweep's first (baseline)
/// technology.
pub fn summary_table(spec: &SweepSpec, points: &[SweepPoint]) -> Table {
    let base_tech = spec.techs.first().map(|t| t.name.clone()).unwrap_or_default();
    // baseline runtimes by scenario, so rendering stays O(n) for the
    // thousands-of-points grids the parallel engine makes cheap to run
    let baselines: std::collections::HashMap<(&str, u64, usize), f64> = points
        .iter()
        .filter(|q| q.tech == base_tech)
        .map(|q| ((q.tensor.as_str(), q.scale.to_bits(), q.mode), q.runtime_cycles()))
        .collect();
    let mut t = Table::new(
        &format!(
            "sweep: {} points, baseline {base_tech}, engine {}, kernel {}",
            points.len(),
            spec.engine.name(),
            spec.kernel.name()
        ),
        &[
            "tensor", "kernel", "scale", "mode", "tech", "runtime", "hit", "bottleneck",
            "energy", "edp", "speedup",
        ],
    )
    .align(0, Align::Left)
    .align(1, Align::Left)
    .align(4, Align::Left)
    .align(7, Align::Left);
    for p in points {
        let base = baselines
            .get(&(p.tensor.as_str(), p.scale.to_bits(), p.mode))
            .copied()
            .unwrap_or(f64::NAN);
        t.row(vec![
            p.tensor.clone(),
            p.kernel.clone(),
            format!("{:.1e}", p.scale),
            format!("M{}", p.mode),
            p.tech.clone(),
            format!("{:.3e} s", p.runtime_s()),
            format!("{:.1}%", p.hit_rate() * 100.0),
            p.report.bottleneck().name().to_string(),
            format!("{:.3e} J", p.energy.total_j()),
            format!("{:.3e}", p.edp()),
            format!("{:.2}x", base / p.runtime_cycles()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::tensor::gen::TensorSpec;

    fn tiny_spec(threads: usize) -> SweepSpec {
        let mut s = SweepSpec::new(
            vec![
                TensorSpec::custom("hot", vec![48, 48, 48], 8_000, 1.1),
                TensorSpec::custom("cold", vec![9_000, 8_000, 7_000], 6_000, 0.2),
            ],
            vec![1.0 / 64.0],
            vec![tech("e-sram"), tech("o-sram"), tech("o-sram-imc")],
        );
        s.threads = threads;
        s
    }

    #[test]
    fn point_count_matches_the_cartesian_product() {
        let s = tiny_spec(1);
        // 2 tensors × 1 scale × 3 techs × 3 modes
        assert_eq!(s.n_points(), 18);
        let points = run_sweep(&s).unwrap();
        assert_eq!(points.len(), 18);
        for (i, p) in points.iter().enumerate() {
            assert_eq!(p.index, i);
            assert!(p.runtime_cycles() > 0.0);
            // nnz scales with the workload: 8000/64 = 125, 6000/64 ≈ 94
            assert_eq!(p.nnz, if p.tensor == "hot" { 125 } else { 94 });
        }
    }

    #[test]
    fn enumeration_order_is_tensor_scale_tech_mode() {
        let points = run_sweep(&tiny_spec(1)).unwrap();
        assert_eq!(points[0].tensor, "hot");
        assert_eq!((points[0].tech.as_str(), points[0].mode), ("e-sram", 0));
        assert_eq!((points[1].tech.as_str(), points[1].mode), ("e-sram", 1));
        assert_eq!((points[3].tech.as_str(), points[3].mode), ("o-sram", 0));
        assert_eq!(points[9].tensor, "cold");
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let base = run_sweep(&tiny_spec(1)).unwrap();
        for threads in [2, 4, 8] {
            let other = run_sweep(&tiny_spec(threads)).unwrap();
            assert_eq!(base.len(), other.len(), "threads={threads}");
            for (a, b) in base.iter().zip(&other) {
                assert_eq!(a.tensor, b.tensor);
                assert_eq!(a.tech, b.tech);
                assert_eq!(a.mode, b.mode);
                // bit-identical, not approximately equal
                assert_eq!(
                    a.runtime_cycles().to_bits(),
                    b.runtime_cycles().to_bits(),
                    "threads={threads} point {}",
                    a.index
                );
                assert_eq!(
                    a.energy.total_j().to_bits(),
                    b.energy.total_j().to_bits()
                );
            }
        }
    }

    #[test]
    fn event_engine_sweep_is_deterministic_and_never_faster() {
        let a_points = run_sweep(&tiny_spec(1)).unwrap();
        let mut es = tiny_spec(1);
        es.engine = EngineKind::Event;
        let e_points = run_sweep(&es).unwrap();
        assert_eq!(a_points.len(), e_points.len());
        for (a, e) in a_points.iter().zip(&e_points) {
            assert_eq!((a.tensor.as_str(), a.tech.as_str(), a.mode), (
                e.tensor.as_str(),
                e.tech.as_str(),
                e.mode
            ));
            // contention can only add time, and traffic is shared
            assert!(e.runtime_cycles() >= a.runtime_cycles(), "point {}", a.index);
            assert_eq!(a.hit_rate(), e.hit_rate());
        }
        // the event replay is as deterministic across threads as analytic
        let mut es8 = tiny_spec(8);
        es8.engine = EngineKind::Event;
        let e8 = run_sweep(&es8).unwrap();
        for (x, y) in e_points.iter().zip(&e8) {
            assert_eq!(x.runtime_cycles().to_bits(), y.runtime_cycles().to_bits());
        }
        // and the summary table says which engine produced it
        let table = summary_table(&es, &e_points).render_ascii();
        assert!(table.contains("engine event"), "{table}");
    }

    #[test]
    fn sampled_event_sweep_is_deterministic_and_rate_one_is_exact() {
        // rate 1.0 through the sweep plumbing is the exact replay bit
        // for bit, whatever the seed; below 1.0 the grid stays
        // deterministic across thread counts and never undercuts the
        // analytic floor
        let mut exact = tiny_spec(2);
        exact.engine = EngineKind::Event;
        let base = run_sweep(&exact).unwrap();
        let mut s = tiny_spec(2);
        s.engine = EngineKind::Event;
        s.sample = SampleSpec { rate: 1.0, seed: 777 };
        for (a, b) in base.iter().zip(&run_sweep(&s).unwrap()) {
            assert_eq!(a.runtime_cycles().to_bits(), b.runtime_cycles().to_bits());
        }
        let analytic = run_sweep(&tiny_spec(2)).unwrap();
        let mut s1 = tiny_spec(1);
        s1.engine = EngineKind::Event;
        s1.sample = SampleSpec { rate: 0.25, seed: 5 };
        s1.chunk_nnz = 61; // many chunks, so sampling actually skips some
        let sampled = run_sweep(&s1).unwrap();
        for (a, e) in analytic.iter().zip(&sampled) {
            assert!(e.runtime_cycles() >= a.runtime_cycles(), "point {}", a.index);
            assert_eq!(a.hit_rate(), e.hit_rate(), "point {}", a.index);
        }
        let mut s8 = s1.clone();
        s8.threads = 8;
        for (x, y) in sampled.iter().zip(&run_sweep(&s8).unwrap()) {
            assert_eq!(x.runtime_cycles().to_bits(), y.runtime_cycles().to_bits());
        }
        // out-of-range rates are rejected up front with the range named
        let mut bad = tiny_spec(1);
        bad.sample = SampleSpec { rate: 1.5, seed: 0 };
        let e = run_sweep(&bad).unwrap_err();
        assert!(e.contains("(0, 1]"), "{e}");
    }

    #[test]
    fn chunk_size_is_bit_transparent() {
        // chunk_nnz is a host knob: any granularity reproduces the same
        // points bit for bit, and zero is rejected up front
        let base = run_sweep(&tiny_spec(2)).unwrap();
        let mut s = tiny_spec(2);
        s.chunk_nnz = 37;
        let other = run_sweep(&s).unwrap();
        assert_eq!(base.len(), other.len());
        for (a, b) in base.iter().zip(&other) {
            assert_eq!(a.runtime_cycles().to_bits(), b.runtime_cycles().to_bits());
            assert_eq!(a.hit_rate(), b.hit_rate());
        }
        let mut s = tiny_spec(1);
        s.chunk_nnz = 0;
        let e = run_sweep(&s).unwrap_err();
        assert!(e.contains("chunk_nnz"), "{e}");
    }

    #[test]
    fn kernel_axis_flows_through_the_sweep() {
        let mut s = tiny_spec(2);
        s.kernel = KernelKind::Spttm;
        let points = run_sweep(&s).unwrap();
        assert_eq!(points.len(), 18);
        for p in &points {
            assert_eq!(p.kernel, "spttm");
            assert_eq!(p.report.kernel, "spttm");
            assert!(p.runtime_cycles() > 0.0);
        }
        // the summary table names the kernel in its title and rows
        let table = summary_table(&s, &points).render_ascii();
        assert!(table.contains("kernel spttm"), "{table}");
        // the default kernel is the paper's workload
        let base = run_sweep(&tiny_spec(1)).unwrap();
        for p in &base {
            assert_eq!(p.kernel, "spmttkrp");
        }
        // TTMc's wider output makes every scenario strictly slower than
        // its MTTKRP twin on the same axes
        for (m, t) in base.iter().zip(&points) {
            assert!(t.runtime_cycles() > m.runtime_cycles(), "point {}", m.index);
        }
    }

    #[test]
    fn mixed_arity_suites_skip_missing_modes() {
        let mut s = SweepSpec::new(
            vec![
                TensorSpec::custom("three", vec![32, 32, 32], 2_000, 1.0),
                TensorSpec::custom("four", vec![32, 32, 32, 32], 2_000, 1.0),
            ],
            vec![1.0 / 64.0],
            vec![tech("o-sram")],
        );
        s.modes = Some(vec![0, 3]);
        // mode 3 exists only for the 4-way tensor
        assert_eq!(s.n_points(), 3);
        let points = run_sweep(&s).unwrap();
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].mode, 0);
        assert_eq!(points[1].mode, 0);
        assert_eq!(points[2].mode, 3);
    }

    #[test]
    fn sweep_matches_single_runs_exactly() {
        // a sweep point must be bit-identical to the same scenario run
        // through the driver path by hand
        let s = tiny_spec(4);
        let points = run_sweep(&s).unwrap();
        let scale = s.scales[0];
        let cfg = s.base_cfg.clone().scaled(scale);
        let tensor = s.tensors[0].clone().scaled(scale).generate(s.seed);
        let direct =
            crate::coordinator::driver::simulate_mode(&tensor, 1, &cfg, &tech("o-sram"));
        let p = points
            .iter()
            .find(|p| p.tensor == "hot" && p.tech == "o-sram" && p.mode == 1)
            .unwrap();
        assert_eq!(p.runtime_cycles().to_bits(), direct.runtime_cycles().to_bits());
        assert_eq!(p.hit_rate(), direct.hit_rate());
    }

    #[test]
    fn invalid_specs_are_rejected() {
        let mut s = tiny_spec(1);
        s.scales = vec![2.0];
        assert!(run_sweep(&s).is_err());
        let mut s = tiny_spec(1);
        s.techs.push(tech("e-sram"));
        assert!(run_sweep(&s).is_err());
        let mut s = tiny_spec(1);
        s.techs.clear();
        assert!(run_sweep(&s).is_err());
        // duplicate tensor names would mispair summary-table baselines
        let mut s = tiny_spec(1);
        s.tensors.push(TensorSpec::custom("hot", vec![8, 8, 8], 10, 0.0));
        assert!(run_sweep(&s).is_err());
        // a mode filter matching no tensor arity must error, not return
        // an empty success
        let mut s = tiny_spec(1);
        s.modes = Some(vec![9]);
        let e = run_sweep(&s).unwrap_err();
        assert!(e.contains("zero scenarios"), "{e}");
    }

    #[test]
    fn summary_table_has_one_row_per_point() {
        let s = tiny_spec(2);
        let points = run_sweep(&s).unwrap();
        let t = summary_table(&s, &points);
        assert_eq!(t.n_rows(), points.len());
        let rendered = t.render_ascii();
        assert!(rendered.contains("o-sram-imc"));
        // baseline rows compare against themselves at exactly 1.00x
        assert!(rendered.contains("1.00x"));
        // the EDP objective column rides along for every point
        assert!(rendered.contains("edp"), "{rendered}");
    }

    #[test]
    fn edp_is_the_runtime_energy_product() {
        let points = run_sweep(&tiny_spec(1)).unwrap();
        for p in &points {
            assert_eq!(p.edp().to_bits(), (p.energy.total_j() * p.runtime_s()).to_bits());
            assert!(p.edp() > 0.0);
        }
    }
}
