//! The event-driven (cycle-level) contention engine.
//!
//! The analytic engine ([`crate::sim::engine`]) prices a mode as the
//! busiest resource's *total* occupancy — the classic bottleneck/roofline
//! abstraction, which silently assumes every resource overlaps perfectly
//! with every other and that requests never queue. "Towards Programmable
//! Memory Controller for Tensor Decomposition" (arXiv:2207.08298) shows
//! that assumption breaking for spMTTKRP: bank conflicts and DRAM-channel
//! queueing put real stall time on top of the roofline. This module
//! replays the **same chunked access-stream IR** (identical
//! [`crate::kernel::SparseKernel`] chunks, identical functional caches,
//! identical traffic, identical [`partition_slices`] work split) through
//! *arbitrated* resources to measure that stall:
//!
//! * **Bank-arbitrated caches** — each cache array is split into
//!   [`AcceleratorConfig::bank_factor`] independently addressable banks
//!   (the electrical port-widening cascade; 1 for optical-class arrays).
//!   Each bank serves one request at a time at `bank_factor ×` the
//!   aggregate per-request occupancy, so two accesses hashing to the same
//!   bank serialize — the aggregate bandwidth matches the analytic model
//!   only when the stream spreads evenly. Bank assignment is the cache's
//!   own [`mix_key`][crate::cache::cache::mix_key] folding
//!   ([`bank_of`][crate::cache::cache::bank_of]), so hot lines collide
//!   here exactly when they collide in the functional sets.
//! * **A FIFO DRAM channel** — cache misses, write-backs, bypass accesses
//!   and the sequential tensor/output streams share one in-order channel
//!   per PE whose per-request service times are the *same* constants the
//!   analytic engine charges (bank-level parallelism stays folded into
//!   the service time), so total channel occupancy is identical and only
//!   queueing delay differs.
//! * **Hierarchy levels** — when [`AcceleratorConfig::levels`] is
//!   non-empty, every stack level is one banked-throughput FIFO: a
//!   single busy-until clock whose per-request service times come from
//!   the level's own [`ArrayTiming`] (bank count folded into the rate,
//!   exactly like the DRAM channel). A PE-cache miss served at depth `d`
//!   queues on level `d`'s clock; the fetched line then back-fills the
//!   missed inner levels, occupying each one's clock — but extending the
//!   request's completion time only through levels *without*
//!   `double_buffer`. A double-buffered level overlaps its fill with the
//!   drain of the line it is already serving, so flipping `db` on can
//!   only shorten the event timeline (never the functional accounting,
//!   which is fill-count-identical either way). An empty stack leaves
//!   this path unreachable and the replay byte-identical to the
//!   single-level engine.
//! * **PE execution slots** — the kernel's pipeline and psum charges
//!   issue against busy-until clocks instead of plain accumulators, and a
//!   finite decoupling window ([`DECOUPLE_WINDOW_PER_PIPELINE`] nonzeros
//!   per pipeline ≈ MSHR + psum depth) back-pressures the front end when
//!   too many nonzeros are in flight.
//!
//! ## The SoA replay core
//!
//! [`replay_pe`] processes each chunk in struct-of-arrays batches rather
//! than dispatching per [`crate::kernel::ir::FactorRead`]:
//!
//! 1. **Functional pass** — one sequential sweep of the shared
//!    [`MemoryController`] over the chunk's reads, recording each serve
//!    outcome (hit / miss / miss+writeback / bypass, plus the serving
//!    cache id) as a one-byte code into a reusable batch. This pass owns
//!    every stateful decision; hit rates, traffic and active words are
//!    decided here exactly as in the analytic engine.
//! 2. **Bank batch** — the bank index of every read in the chunk,
//!    computed in one branch-free sweep over the packed u64 words (pure
//!    integer mixing, no controller state) that the compiler can
//!    vectorize.
//! 3. **Timing pass** — the arbitration replay consumes the two batches:
//!    same-bank collisions serialize on the busy-until clocks, misses
//!    queue for FIFO DRAM admission, execution slots close the window.
//!    The float operations are issued in exactly the order of the old
//!    fused per-event loop, so the restructure is bit-identical (pinned
//!    against the retained reference loop, see below).
//!
//! The pre-SoA fused loop is kept as [`replay_pe_reference`] behind
//! `cfg(any(test, feature = "replay-reference"))` and a test pins the two
//! paths bit-for-bit.
//!
//! ## Sampled replay
//!
//! [`SampleSpec`] (threaded through [`SimBudget::sample`]) trades stall
//! precision for wall-clock: below `rate = 1.0` the engine still walks
//! **every** chunk through the functional pass (cache state is
//! sequential; traffic, hits and active words stay exact), but runs the
//! timing pass only for a deterministic, seeded subset of chunks. Each
//! timed chunk yields one stall sample — the event-frontier advance over
//! the chunk minus the chunk's own roofline time — and the mean sample,
//! scaled to the full chunk count, extrapolates
//! [`PeReport::stall_cycles`] to full-stream scale with a standard error
//! ([`PeReport::stall_stderr_cycles`]) from the per-chunk variance. Chunk
//! admission hashes `(seed, mode, pe, chunk index)` only, so a sampled
//! report is bit-identical at any thread count; `rate = 1.0` takes the
//! exact path and is bit-identical to the pre-sampling engine.
//!
//! ## Invariants vs the analytic engine
//!
//! The functional model is *shared*, not re-implemented: the event engine
//! drives the same [`MemoryController`] over the same IR chunks, so hit
//! rates, DRAM traffic, active-word counters — everything the energy
//! model (Eq. 2–3) consumes — are bit-identical between the two backends
//! at **any** sampling rate. The measured contention is reported as
//! [`PeReport::stall_cycles`] *on top of* the analytic bottleneck time,
//! clamped non-negative per chunk sample as well, so `event runtime ≥
//! analytic runtime` always holds and the delta is exactly the roofline
//! model's blind spot.
//!
//! On conflict-light streams (uniform row access, ≥ a few hundred distinct
//! rows per factor matrix) the two engines agree within
//! [`EVENT_AGREEMENT_TOLERANCE`]; a single-hot-row stream on a banked
//! electrical cache inflates runtime by up to `bank_factor ×` — the
//! regression the golden tests pin (`rust/tests/engine_agreement.rs`).
//!
//! Complexity is O(nnz × reads-per-nonzero) per mode, same order as the
//! analytic engine with a constant-factor overhead for the busy-until
//! bookkeeping; per-PE live memory is O(chunk), never the full trace.
//! Like the analytic engine, the replay streams chunks through the
//! zero-allocation fill API and fans its independent per-PE timelines
//! across the [`crate::sim::SimBudget`] thread budget — bit-identical at
//! any thread count.
//!
//! [`PeReport::stall_cycles`]: crate::sim::result::PeReport::stall_cycles
//! [`PeReport::stall_stderr_cycles`]: crate::sim::result::PeReport::stall_stderr_cycles

use crate::accel::config::AcceleratorConfig;
use crate::cache::cache::{bank_of, row_key};
use crate::cache::pipeline::ArrayTiming;
use crate::controller::mc::{MemoryController, Served};
use crate::kernel::{AccessChunk, KernelKind, SparseKernel};
use crate::mem::tech::MemTechnology;
use crate::obs::{metrics, Span};
use crate::pe::exec::ExecUnit;
use crate::sim::engine::{
    assemble_pe_report, charge_streams, nnz_item_bytes, partition_slices, price_exec,
    startup_latency,
};
use crate::sim::par::parallel_map_init;
use crate::sim::result::{ModeReport, PeReport, SimReport};
use crate::sim::{SampleSpec, SimBudget};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;
use crate::util::stats::Summary;

/// Documented agreement band of the two engines on conflict-light
/// deterministic tensors: `event / analytic ∈ [1.0, 1.30]`. The lower
/// bound is structural (stall is clamped non-negative over identical
/// busy accounting); the upper bound covers residual bank-hash imbalance,
/// queueing tails and the un-overlapped last-access latency.
pub const EVENT_AGREEMENT_TOLERANCE: f64 = 1.30;

/// Decoupling window, in in-flight nonzeros per pipeline: how far the
/// front end may run ahead of completion before it stalls (models the
/// miss-status registers + psum-row reservation depth of the Fig. 4 PE).
pub const DECOUPLE_WINDOW_PER_PIPELINE: usize = 4;

// Serve codes recorded by the functional pass for the timing pass: the
// outcome kind in the low two bits, the serving cache id above them
// (bypasses carry no cache).
const SERVE_HIT: u8 = 0;
const SERVE_MISS: u8 = 1;
const SERVE_MISS_WB: u8 = 2;
const SERVE_BYPASS: u8 = 3;
const SERVE_KIND_MASK: u8 = 3;
const SERVE_CACHE_SHIFT: u8 = 2;

/// Per-worker scratch for the SoA replay: the reusable chunk buffer plus
/// the struct-of-arrays serve/bank batches and the cache-busy snapshot
/// the sampled estimator diffs against. All capacity is retained across
/// chunks and across simulations on the same worker — the replay stays
/// allocation-free after warm-up.
#[derive(Default)]
struct ReplayScratch {
    chunk: AccessChunk,
    /// Serve code per read of the current chunk (functional pass out).
    serve: Vec<u8>,
    /// Bank index per read of the current chunk (batch bank pass out).
    bank: Vec<u32>,
    /// Hierarchy fill depth per read (functional pass out; filled only
    /// when the config carries a level stack, consulted only on misses).
    depth: Vec<u8>,
    /// Per-cache busy snapshot at chunk entry (sampling only).
    cache_snap: Vec<f64>,
    /// Per-level busy snapshot at chunk entry (sampling only).
    level_snap: Vec<f64>,
}

/// Immutable inputs shared by every PE of one event-mode replay, so the
/// per-PE worker ([`replay_pe`]) can fan across threads borrowing one
/// context instead of a dozen loose captures.
struct ReplayCtx<'a> {
    kernel: &'a dyn SparseKernel,
    tensor: &'a SparseTensor,
    view: &'a ModeView,
    cfg: &'a AcceleratorConfig,
    tech: &'a MemTechnology,
    matrix_rows: &'a [u64],
    rpn: usize,
    banks: usize,
    psum_timing: &'a ArrayTiming,
    psum_banks: usize,
    item_bytes: u64,
    row_bytes: u64,
    window: usize,
    chunk_nnz: usize,
    /// Output mode being replayed — a chunk-admission coordinate.
    mode: usize,
    /// Chunk-sampling policy ([`SimBudget::sample`]).
    sample: SampleSpec,
}

/// The event timeline's current frontier: the furthest busy-until clock
/// across every arbitrated resource (the hierarchy level clocks fold in
/// as an empty — hence inert — slice on the degenerate configuration).
#[inline]
fn frontier(
    finish: f64,
    dram_free: f64,
    pipe_free: f64,
    psum_free: f64,
    bank_free: &[f64],
    level_free: &[f64],
) -> f64 {
    let bank_max = bank_free.iter().cloned().fold(0.0f64, f64::max);
    let level_max = level_free.iter().cloned().fold(0.0f64, f64::max);
    finish.max(dram_free).max(pipe_free).max(psum_free).max(bank_max).max(level_max)
}

/// Completion time of a PE-cache miss walking a **non-empty** hierarchy
/// stack (event timing only — the functional fill already happened
/// inside the controller). `request` is the miss's arbitration-ready
/// instant (`start + hit_latency`); `d` is the controller's
/// [`MemoryController::last_fill_depth`] for this serve: the
/// innermost-first index of the level that granted the line, or
/// `level_consts.len()` when the fetch fell through to DRAM. The
/// granted line then back-fills every missed inner level `j < d`,
/// occupying its busy-until clock; a level *without* double buffering
/// also extends the request's completion to its fill-drain end, while a
/// double-buffered level overlaps the fill with the drain of the line
/// it already holds (so enabling `db` can only shorten the timeline).
/// A dirty PE-cache victim posts its write-back straight onto the DRAM
/// channel — same direct path the functional model charges — without
/// the requesting read waiting on it.
///
/// Shared verbatim by [`replay_pe`] and [`replay_pe_reference`] so the
/// two loops stay bit-identical on hierarchy configs by construction.
#[inline]
fn hierarchy_complete(
    request: f64,
    d: usize,
    writeback: bool,
    level_consts: &[(f64, f64, f64, bool)],
    level_free: &mut [f64],
    dram_free: &mut f64,
    hier_miss_occ: f64,
    wb_occ: f64,
    miss_latency: f64,
) -> f64 {
    let mut t = if d == level_consts.len() {
        // missed every level: one outermost-line fetch from DRAM
        let grant = request.max(*dram_free);
        *dram_free = grant + hier_miss_occ;
        *dram_free + miss_latency
    } else {
        // served by level d: queue on its banked-throughput clock
        let (serve_occ, _, latency, _) = level_consts[d];
        let grant = request.max(level_free[d]);
        level_free[d] = grant + serve_occ;
        level_free[d] + latency
    };
    // back-fill the missed levels outside-in (level d-1 first, the
    // innermost level last)
    for j in (0..d).rev() {
        let (_, fill_occ, _, double_buffer) = level_consts[j];
        let start = t.max(level_free[j]);
        level_free[j] = start + fill_occ;
        if !double_buffer {
            t = level_free[j];
        }
    }
    if writeback {
        *dram_free += wb_occ;
    }
    t
}

/// Replay one PE's slice range through the arbitrated resources. All
/// mutable state (controller, busy-until clocks, decoupling ring, SoA
/// batches) is PE-private, so PEs replay concurrently with bit-identical
/// results.
fn replay_pe(
    ctx: &ReplayCtx<'_>,
    pe_idx: usize,
    slices: (usize, usize),
    scratch: &mut ReplayScratch,
) -> PeReport {
    let (slo, shi) = slices;
    let cfg = ctx.cfg;
    let banks = ctx.banks;
    let mut mc = MemoryController::new(cfg, ctx.tech, ctx.matrix_rows);
    let exec = ExecUnit::new(cfg.n_pipelines, cfg.rank, ctx.psum_timing.clone(), ctx.psum_banks);

    let per_nnz = ctx.kernel.nnz_exec(&exec, ctx.tensor.n_modes());
    let per_drain = ctx.kernel.drain_exec(&exec, ctx.tensor.n_modes());

    // --- event constants (per-request service times; the bank-level
    // constants are the aggregate occupancies scaled to one bank) ---
    let hit_occ = mc.cache_timing.hit_occupancy();
    let fill_occ = mc.cache_timing.fill_occupancy();
    let bank_hit = hit_occ * banks as f64;
    let bank_fill = fill_occ * banks as f64;
    let hit_latency = mc.cache_timing.hit_latency();
    let miss_occ = mc.dram_cfg.random_access_cycles(cfg.line_bytes as u64);
    let miss_latency = mc.dram_cfg.row_miss_ns * 1e-9 * cfg.fabric_hz;
    let stream_per_nnz = mc.dram_cfg.stream_cycles(ctx.item_bytes);
    // hierarchy constants, innermost-first (the order a miss walks the
    // stack); empty on the degenerate configuration
    let level_consts = mc.level_event_constants();
    let n_levels = level_consts.len();
    let has_levels = n_levels != 0;
    let hier_miss_occ = mc.hier_miss_dram_cycles();

    // --- event state: busy-until clocks, in fabric cycles ---
    let n_caches = mc.caches.len();
    debug_assert!(n_caches < 64, "serve codes pack the cache id in 6 bits");
    let mut bank_free = vec![0.0f64; n_caches * banks];
    let mut level_free = vec![0.0f64; n_levels];
    let mut dram_free = 0.0f64;
    let mut pipe_free = 0.0f64;
    let mut psum_free = 0.0f64;
    // ring[k % window] holds the completion time of nonzero k - window
    let mut ring = vec![0.0f64; ctx.window];
    let mut processed = 0usize;
    let mut finish = 0.0f64;

    // --- analytic-identical exec counters: the report's pipeline/psum
    // figures are priced from these at report time as count × constant
    // (the shared `price_exec` helper), exactly like the analytic
    // engine ---
    let mut pe_nnz = 0u64;
    let mut drains = 0u64;

    // --- sampling state: one stall sample per timed chunk ---
    let sampling = !ctx.sample.is_exact();
    let mut stalls = Summary::new();
    let mut sampled_nnz = 0u64;
    let mut n_chunks = 0u64;

    let ReplayScratch { chunk, serve, bank, depth, cache_snap, level_snap } = scratch;
    let mut stream = ctx.kernel.stream(ctx.tensor, ctx.view, (slo, shi), ctx.chunk_nnz);
    while stream.fill(chunk) {
        let timed = ctx.sample.admits(ctx.mode, pe_idx, n_chunks);
        n_chunks += 1;

        if !timed {
            // Functional-only walk: the shared controller still sees
            // every read in stream order (hit rates, traffic and busy
            // sums stay exact — the cache state is sequential and may
            // never skip), and the exec work is captured by the chunk's
            // nonzero/drain counts, priced at report time; only the
            // event clocks stand still.
            pe_nnz += chunk.n_nnz as u64;
            drains += chunk.slice_ends.len() as u64;
            for read in &chunk.reads[..chunk.n_nnz * ctx.rpn] {
                let _ = mc.factor_row_load(read.slot() as usize, read.row());
            }
            continue;
        }

        // chunk-entry baselines for the per-chunk stall sample (exec
        // counters snapshot before this chunk's work lands)
        let (frontier0, dram_busy0, nnz0, drains0) = if sampling {
            cache_snap.clear();
            cache_snap.extend((0..n_caches).map(|i| mc.cache_busy(i)));
            level_snap.clear();
            level_snap.extend((0..n_levels).map(|i| mc.level_busy(i)));
            (
                frontier(finish, dram_free, pipe_free, psum_free, &bank_free, &level_free),
                mc.dram_busy(),
                pe_nnz,
                drains,
            )
        } else {
            (0.0, 0.0, 0, 0)
        };
        pe_nnz += chunk.n_nnz as u64;
        drains += chunk.slice_ends.len() as u64;

        let n_reads = chunk.n_nnz * ctx.rpn;

        // --- functional pass: one sequential sweep of the shared
        // controller, serve outcomes recorded into the SoA batch ---
        serve.clear();
        serve.reserve(n_reads);
        if has_levels {
            // misses also need the level depth that granted the fill —
            // a parallel batch (the serve code has no spare bits), read
            // back from the controller before the next serve overwrites
            // it; hit/bypass slots hold stale bytes nothing consults
            depth.clear();
            depth.reserve(n_reads);
        }
        for read in &chunk.reads[..n_reads] {
            let code = match mc.factor_row_load(read.slot() as usize, read.row()) {
                Served::CacheHit { cache } => ((cache as u8) << SERVE_CACHE_SHIFT) | SERVE_HIT,
                Served::CacheMiss { cache, writeback } => {
                    ((cache as u8) << SERVE_CACHE_SHIFT)
                        | if writeback { SERVE_MISS_WB } else { SERVE_MISS }
                }
                Served::Bypass => SERVE_BYPASS,
            };
            serve.push(code);
            if has_levels {
                depth.push(mc.last_fill_depth());
            }
        }

        // --- bank batch: every read's bank index in one branch-free
        // sweep over the packed words — pure integer mixing (shared
        // with the cache's set index), no controller state, so the
        // compiler can vectorize it ---
        bank.clear();
        bank.reserve(n_reads);
        bank.extend(
            chunk.reads[..n_reads]
                .iter()
                .map(|read| bank_of(row_key(read.slot() as usize, read.row()), banks) as u32),
        );

        // --- timing pass: arbitration replay from the precomputed
        // batches; float operations in exactly the fused-loop order,
        // so rate 1.0 stays bit-identical to the reference path ---
        let mut se = 0usize;
        for i in 0..chunk.n_nnz {
            // decoupling-window back-pressure: this nonzero may not
            // issue before nonzero (processed - window) completed
            let slot = processed % ctx.window;
            let issue = ring[slot];
            // the nonzero itself (coordinates + value) streams in
            // through the DRAM channel ahead of processing
            dram_free += stream_per_nnz;

            let mut ready = issue;
            for r in i * ctx.rpn..(i + 1) * ctx.rpn {
                let (code, bk) = (serve[r], bank[r]);
                let complete = match code & SERVE_KIND_MASK {
                    SERVE_HIT => {
                        let b = (code >> SERVE_CACHE_SHIFT) as usize * banks + bk as usize;
                        let start = issue.max(bank_free[b]);
                        bank_free[b] = start + bank_hit;
                        bank_free[b] + hit_latency
                    }
                    SERVE_MISS | SERVE_MISS_WB => {
                        let writeback = code & SERVE_KIND_MASK == SERVE_MISS_WB;
                        let b = (code >> SERVE_CACHE_SHIFT) as usize * banks + bk as usize;
                        let start = issue.max(bank_free[b]);
                        // probe + line-fill write (+ victim read-out)
                        let occ = bank_hit + bank_fill + if writeback { bank_fill } else { 0.0 };
                        bank_free[b] = start + occ;
                        if !has_levels {
                            let grant = (start + hit_latency).max(dram_free);
                            dram_free = grant + miss_occ + if writeback { miss_occ } else { 0.0 };
                            dram_free + miss_latency
                        } else {
                            hierarchy_complete(
                                start + hit_latency,
                                depth[r] as usize,
                                writeback,
                                &level_consts,
                                &mut level_free,
                                &mut dram_free,
                                hier_miss_occ,
                                miss_occ,
                                miss_latency,
                            )
                        }
                    }
                    _ => {
                        let grant = issue.max(dram_free);
                        dram_free = grant + miss_occ;
                        dram_free + miss_latency
                    }
                };
                ready = ready.max(complete);
            }

            // execution slots: pipelines then psum, in dependence order
            let estart = ready.max(pipe_free);
            pipe_free = estart + per_nnz.pipeline_cycles;
            let pstart = estart.max(psum_free);
            psum_free = pstart + per_nnz.psum_cycles;
            let done = pipe_free.max(psum_free);
            ring[slot] = done;
            processed += 1;
            finish = finish.max(done);

            if se < chunk.slice_ends.len() && chunk.slice_ends[se] == i as u32 {
                // slice complete: drain psum row toward the store path
                psum_free += per_drain.psum_cycles;
                finish = finish.max(psum_free);
                se += 1;
            }
        }

        if sampling {
            sampled_nnz += chunk.n_nnz as u64;
            // The chunk's stall sample: event-frontier advance minus
            // the chunk's own roofline time — the busiest resource's
            // busy added during the chunk, including the nonzero
            // stream's channel share that the functional model charges
            // in bulk at stream end. Clamped non-negative so the
            // extrapolated stall keeps `event ≥ analytic`.
            let f1 = frontier(finish, dram_free, pipe_free, psum_free, &bank_free, &level_free);
            let d_dram = (mc.dram_busy() - dram_busy0) + chunk.n_nnz as f64 * stream_per_nnz;
            let (d_pipe, d_psum, _) =
                price_exec(&per_nnz, &per_drain, pe_nnz - nnz0, drains - drains0);
            let mut ideal = d_dram.max(d_pipe).max(d_psum);
            for (i, &before) in cache_snap.iter().enumerate() {
                ideal = ideal.max(mc.cache_busy(i) - before);
            }
            for (i, &before) in level_snap.iter().enumerate() {
                ideal = ideal.max(mc.level_busy(i) - before);
            }
            stalls.push((f1 - frontier0 - ideal).max(0.0));
        }
    }

    // read-beside accounting: relaxed counter adds on the registry,
    // off the result path entirely (a sampled run counts the nnz that
    // actually went through the timing pass; an exact run times all)
    let m = metrics::global();
    m.counter("sim_event_chunks_total").add(n_chunks);
    m.counter("sim_event_timed_chunks_total")
        .add(if sampling { stalls.count() } else { n_chunks });
    m.counter("sim_event_nnz_total").add(pe_nnz);
    m.counter("sim_event_sampled_nnz_total").add(if sampling { sampled_nnz } else { pe_nnz });

    // Bulk functional stream accounting — the shared helper issues the
    // identical calls in identical order to the analytic engine, so
    // the *reported* busy/traffic fields stay bit-identical across
    // engines. (The per-nonzero `stream_per_nnz` charges above feed
    // only the event timeline and sum to the same total up to f64
    // rounding.)
    let n_slices_pe = (shi - slo) as u64;
    charge_streams(&mut mc, pe_nnz, n_slices_pe, ctx.item_bytes, ctx.row_bytes);
    // the output rows drain through the channel after compute
    dram_free += mc.dram_cfg.stream_cycles(n_slices_pe * ctx.row_bytes);

    let latency_overhead = startup_latency(cfg, &mc);

    let (pipeline_cycles, psum_cycles, psum_words) =
        price_exec(&per_nnz, &per_drain, pe_nnz, drains);
    let mut report = assemble_pe_report(
        &mc,
        pe_idx,
        pe_nnz,
        n_slices_pe,
        pipeline_cycles,
        psum_cycles,
        psum_words,
        latency_overhead,
    );
    if sampling {
        report.sampled_nnz = sampled_nnz;
        // extrapolate: mean per-chunk stall × total chunk count, with a
        // standard error from the per-chunk sample variance scaled the
        // same way (zero band when fewer than two samples exist)
        if stalls.count() > 0 {
            report.stall_cycles = stalls.mean() * n_chunks as f64;
            if stalls.count() >= 2 {
                report.stall_stderr_cycles =
                    stalls.std() / (stalls.count() as f64).sqrt() * n_chunks as f64;
            }
        }
    } else {
        // contention = measured event finish beyond the perfect-overlap
        // bound; clamped so the event engine never under-reports the
        // analytic model (their busy accounting is bit-identical)
        let event_end = frontier(finish, dram_free, pipe_free, psum_free, &bank_free, &level_free);
        report.stall_cycles = (event_end - report.runtime_cycles()).max(0.0);
    }
    report
}

/// The pre-SoA fused per-event loop, retained verbatim (exact replay
/// only) so the batch restructure stays pinned bit-for-bit against the
/// original arbitration semantics. Compiled for tests and under the
/// `replay-reference` feature for external A/B benchmarking.
#[cfg(any(test, feature = "replay-reference"))]
fn replay_pe_reference(
    ctx: &ReplayCtx<'_>,
    pe_idx: usize,
    slices: (usize, usize),
    scratch: &mut AccessChunk,
) -> PeReport {
    let (slo, shi) = slices;
    let cfg = ctx.cfg;
    let banks = ctx.banks;
    let mut mc = MemoryController::new(cfg, ctx.tech, ctx.matrix_rows);
    let exec = ExecUnit::new(cfg.n_pipelines, cfg.rank, ctx.psum_timing.clone(), ctx.psum_banks);

    let per_nnz = ctx.kernel.nnz_exec(&exec, ctx.tensor.n_modes());
    let per_drain = ctx.kernel.drain_exec(&exec, ctx.tensor.n_modes());

    let hit_occ = mc.cache_timing.hit_occupancy();
    let fill_occ = mc.cache_timing.fill_occupancy();
    let bank_hit = hit_occ * banks as f64;
    let bank_fill = fill_occ * banks as f64;
    let hit_latency = mc.cache_timing.hit_latency();
    let miss_occ = mc.dram_cfg.random_access_cycles(cfg.line_bytes as u64);
    let miss_latency = mc.dram_cfg.row_miss_ns * 1e-9 * cfg.fabric_hz;
    let stream_per_nnz = mc.dram_cfg.stream_cycles(ctx.item_bytes);
    let level_consts = mc.level_event_constants();
    let has_levels = !level_consts.is_empty();
    let hier_miss_occ = mc.hier_miss_dram_cycles();

    let n_caches = mc.caches.len();
    let mut bank_free = vec![0.0f64; n_caches * banks];
    let mut level_free = vec![0.0f64; level_consts.len()];
    let mut dram_free = 0.0f64;
    let mut pipe_free = 0.0f64;
    let mut psum_free = 0.0f64;
    let mut ring = vec![0.0f64; ctx.window];
    let mut processed = 0usize;
    let mut finish = 0.0f64;

    let mut pe_nnz = 0u64;
    let mut drains = 0u64;

    let mut stream = ctx.kernel.stream(ctx.tensor, ctx.view, (slo, shi), ctx.chunk_nnz);
    while stream.fill(scratch) {
        let chunk = &*scratch;
        pe_nnz += chunk.n_nnz as u64;
        drains += chunk.slice_ends.len() as u64;
        let mut se = 0usize;
        for i in 0..chunk.n_nnz {
            let slot = processed % ctx.window;
            let issue = ring[slot];
            dram_free += stream_per_nnz;

            let mut ready = issue;
            for read in &chunk.reads[i * ctx.rpn..(i + 1) * ctx.rpn] {
                let (j, row) = (read.slot() as usize, read.row());
                let complete = match mc.factor_row_load(j, row) {
                    Served::CacheHit { cache } => {
                        let b = cache * banks + bank_of(row_key(j, row), banks);
                        let start = issue.max(bank_free[b]);
                        bank_free[b] = start + bank_hit;
                        bank_free[b] + hit_latency
                    }
                    Served::CacheMiss { cache, writeback } => {
                        let b = cache * banks + bank_of(row_key(j, row), banks);
                        let start = issue.max(bank_free[b]);
                        let occ = bank_hit + bank_fill + if writeback { bank_fill } else { 0.0 };
                        bank_free[b] = start + occ;
                        if !has_levels {
                            let grant = (start + hit_latency).max(dram_free);
                            dram_free = grant + miss_occ + if writeback { miss_occ } else { 0.0 };
                            dram_free + miss_latency
                        } else {
                            hierarchy_complete(
                                start + hit_latency,
                                mc.last_fill_depth() as usize,
                                writeback,
                                &level_consts,
                                &mut level_free,
                                &mut dram_free,
                                hier_miss_occ,
                                miss_occ,
                                miss_latency,
                            )
                        }
                    }
                    Served::Bypass => {
                        let grant = issue.max(dram_free);
                        dram_free = grant + miss_occ;
                        dram_free + miss_latency
                    }
                };
                ready = ready.max(complete);
            }

            let estart = ready.max(pipe_free);
            pipe_free = estart + per_nnz.pipeline_cycles;
            let pstart = estart.max(psum_free);
            psum_free = pstart + per_nnz.psum_cycles;
            let done = pipe_free.max(psum_free);
            ring[slot] = done;
            processed += 1;
            finish = finish.max(done);

            if se < chunk.slice_ends.len() && chunk.slice_ends[se] == i as u32 {
                psum_free += per_drain.psum_cycles;
                finish = finish.max(psum_free);
                se += 1;
            }
        }
    }

    let n_slices_pe = (shi - slo) as u64;
    charge_streams(&mut mc, pe_nnz, n_slices_pe, ctx.item_bytes, ctx.row_bytes);
    dram_free += mc.dram_cfg.stream_cycles(n_slices_pe * ctx.row_bytes);

    let latency_overhead = startup_latency(cfg, &mc);
    let event_end = frontier(finish, dram_free, pipe_free, psum_free, &bank_free, &level_free);

    let (pipeline_cycles, psum_cycles, psum_words) =
        price_exec(&per_nnz, &per_drain, pe_nnz, drains);
    let mut report = assemble_pe_report(
        &mc,
        pe_idx,
        pe_nnz,
        n_slices_pe,
        pipeline_cycles,
        psum_cycles,
        psum_words,
        latency_overhead,
    );
    report.stall_cycles = (event_end - report.runtime_cycles()).max(0.0);
    report
}

/// Event-driven simulation of one output mode of `kernel` (builds the
/// mode view itself; see [`simulate_kernel_mode_event_with_view`]).
pub fn simulate_kernel_mode_event(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    let view = ModeView::build(tensor, mode);
    simulate_kernel_mode_event_with_view(kernel, tensor, &view, mode, cfg, tech)
}

/// Event-driven simulation of one output mode of `kernel` with a
/// caller-supplied mode view (the [`crate::sim::sweep`] fast path).
/// `view` must be `ModeView::build(tensor, mode)` for the same tensor
/// and mode.
pub fn simulate_kernel_mode_event_with_view(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode_event_with_view_budget(
        kernel,
        tensor,
        view,
        mode,
        cfg,
        tech,
        SimBudget::default(),
    )
}

/// [`simulate_kernel_mode_event_with_view`] under an explicit
/// host-execution [`SimBudget`]: the independent per-PE replays fan
/// across `budget.pe_threads(cfg.n_pes)` OS threads, each worker reusing
/// one scratch buffer set through the zero-allocation fill loop. Reports
/// land in fixed PE order and chunk admission hashes fixed coordinates,
/// so the result is bit-identical for any thread count — and, at
/// `budget.sample` rate 1.0, for any chunk size too (same contract as
/// the analytic engine, pinned by `rust/tests/parallel_determinism.rs`;
/// sampled estimates are chunk-granular and pinned by
/// `rust/tests/sampled_replay.rs`).
pub fn simulate_kernel_mode_event_with_view_budget(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    budget: SimBudget,
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    if let Err(e) = kernel.validate(tensor, mode) {
        panic!("kernel `{}` rejected the workload: {e}", kernel.name());
    }
    cfg.validate().expect("invalid accelerator config");
    // the CLI and the sweep/explore specs reject bad rates with a proper
    // error first, so a bad spec reaching here is a library-caller bug
    budget.sample.validate().expect("invalid SimBudget::sample");
    // inert unless a front-end enabled recording; the per-PE replays
    // below record into slot-ordered buffers (see crate::sim::par)
    let _span = Span::enter("engine.event.mode", "engine");
    // shared-path invariant: identical work split to the analytic engine
    let parts = partition_slices(view, cfg.n_pes);

    let read_modes = kernel.read_modes(tensor, mode);
    let matrix_rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();

    let t = cfg.tuned_tech(tech);
    let banks = cfg.bank_factor(&t);
    let psum_timing = ArrayTiming::new(&t, cfg.fabric_hz, banks);
    let ctx = ReplayCtx {
        kernel,
        tensor,
        view,
        cfg,
        tech: &t,
        matrix_rows: &matrix_rows,
        rpn: read_modes.len(),
        banks,
        psum_timing: &psum_timing,
        psum_banks: (cfg.n_pipelines / 10).max(1),
        item_bytes: nnz_item_bytes(tensor.n_modes()),
        row_bytes: kernel.out_row_bytes(cfg.rank, tensor.n_modes()),
        window: (cfg.n_pipelines * DECOUPLE_WINDOW_PER_PIPELINE).max(8),
        chunk_nnz: budget.chunk(),
        mode,
        sample: budget.sample,
    };

    let pes = parallel_map_init(
        &parts,
        budget.pe_threads(cfg.n_pes),
        ReplayScratch::default,
        |scratch, pe_idx, &range| replay_pe(&ctx, pe_idx, range, scratch),
    );

    ModeReport {
        tensor: tensor.name.clone(),
        kernel: kernel.name().to_string(),
        mode,
        tech: t,
        rank: cfg.rank,
        fabric_hz: cfg.fabric_hz,
        pes,
    }
}

/// [`simulate_kernel_mode_event_with_view_budget`] through the retained
/// pre-SoA fused loop ([`replay_pe_reference`], exact replay only) — the
/// bit-identity oracle for the batch restructure. Test/`replay-reference`
/// builds only.
#[cfg(any(test, feature = "replay-reference"))]
pub fn simulate_kernel_mode_event_reference(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    budget: SimBudget,
) -> ModeReport {
    assert!(mode < tensor.n_modes(), "mode {mode} out of range");
    assert!(budget.sample.is_exact(), "the reference loop only replays exact streams");
    if let Err(e) = kernel.validate(tensor, mode) {
        panic!("kernel `{}` rejected the workload: {e}", kernel.name());
    }
    cfg.validate().expect("invalid accelerator config");
    let parts = partition_slices(view, cfg.n_pes);

    let read_modes = kernel.read_modes(tensor, mode);
    let matrix_rows: Vec<u64> = read_modes.iter().map(|&m| tensor.dims[m]).collect();

    let t = cfg.tuned_tech(tech);
    let banks = cfg.bank_factor(&t);
    let psum_timing = ArrayTiming::new(&t, cfg.fabric_hz, banks);
    let ctx = ReplayCtx {
        kernel,
        tensor,
        view,
        cfg,
        tech: &t,
        matrix_rows: &matrix_rows,
        rpn: read_modes.len(),
        banks,
        psum_timing: &psum_timing,
        psum_banks: (cfg.n_pipelines / 10).max(1),
        item_bytes: nnz_item_bytes(tensor.n_modes()),
        row_bytes: kernel.out_row_bytes(cfg.rank, tensor.n_modes()),
        window: (cfg.n_pipelines * DECOUPLE_WINDOW_PER_PIPELINE).max(8),
        chunk_nnz: budget.chunk(),
        mode,
        sample: SampleSpec::exact(),
    };

    let pes = parallel_map_init(
        &parts,
        budget.pe_threads(cfg.n_pes),
        AccessChunk::default,
        |scratch, pe_idx, &range| replay_pe_reference(&ctx, pe_idx, range, scratch),
    );

    ModeReport {
        tensor: tensor.name.clone(),
        kernel: kernel.name().to_string(),
        mode,
        tech: t,
        rank: cfg.rank,
        fabric_hz: cfg.fabric_hz,
        pes,
    }
}

/// Event-driven simulation of one output mode of the default spMTTKRP
/// kernel (the pre-kernel-IR entry point, preserved verbatim).
pub fn simulate_mode_event(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode_event(KernelKind::Spmttkrp.kernel(), tensor, mode, cfg, tech)
}

/// [`simulate_mode_event`] with a caller-supplied mode view.
pub fn simulate_mode_event_with_view(
    tensor: &SparseTensor,
    view: &ModeView,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_kernel_mode_event_with_view(
        KernelKind::Spmttkrp.kernel(),
        tensor,
        view,
        mode,
        cfg,
        tech,
    )
}

/// Event-driven simulation of every output mode of `kernel` (report
/// assembly owned by the [`crate::sim::SimEngine`] trait default).
pub fn simulate_kernel_all_modes_event(
    kernel: &dyn SparseKernel,
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    crate::sim::EngineKind::Event.simulate_kernel_all_modes(kernel, tensor, cfg, tech)
}

/// Event-driven simulation of every output mode (spMTTKRP).
pub fn simulate_all_modes_event(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    simulate_kernel_all_modes_event(KernelKind::Spmttkrp.kernel(), tensor, cfg, tech)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::sim::engine;
    use crate::tensor::gen;

    fn small_cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    #[test]
    fn event_is_deterministic() {
        let t = gen::random(&[512, 512, 512], 20_000, 3);
        let cfg = small_cfg();
        let a = simulate_mode_event(&t, 0, &cfg, &tech("e-sram"));
        let b = simulate_mode_event(&t, 0, &cfg, &tech("e-sram"));
        assert_eq!(a.runtime_cycles().to_bits(), b.runtime_cycles().to_bits());
        for (pa, pb) in a.pes.iter().zip(&b.pes) {
            assert_eq!(pa.stall_cycles.to_bits(), pb.stall_cycles.to_bits());
        }
    }

    #[test]
    fn event_budget_never_changes_the_report() {
        // host knobs (threads, chunk size) are bit-transparent on the
        // replay too: stall_cycles included
        let t = gen::random(&[512, 512, 512], 20_000, 23);
        let cfg = small_cfg();
        let view = ModeView::build(&t, 0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let base = simulate_kernel_mode_event_with_view_budget(
            kernel,
            &t,
            &view,
            0,
            &cfg,
            &tech("e-sram"),
            SimBudget::single_threaded(),
        );
        for budget in [
            SimBudget::with_threads(0),
            SimBudget::with_threads(3),
            SimBudget { threads: 2, chunk_nnz: 999, ..SimBudget::default() },
            // at rate 1.0 the sample seed must be fully inert
            SimBudget::default().with_sample(SampleSpec { rate: 1.0, seed: 12345 }),
        ] {
            let r = simulate_kernel_mode_event_with_view_budget(
                kernel,
                &t,
                &view,
                0,
                &cfg,
                &tech("e-sram"),
                budget,
            );
            let (x, y) = (base.runtime_cycles(), r.runtime_cycles());
            assert_eq!(x.to_bits(), y.to_bits(), "{budget:?}");
            for (a, b) in base.pes.iter().zip(&r.pes) {
                assert_eq!(a.stall_cycles.to_bits(), b.stall_cycles.to_bits(), "{budget:?}");
                assert_eq!(a.cache_stats.hits, b.cache_stats.hits, "{budget:?}");
                assert_eq!(b.stall_stderr_cycles, 0.0, "{budget:?}");
                assert_eq!(b.sampled_nnz, b.nnz, "{budget:?}");
            }
        }
    }

    #[test]
    fn soa_replay_is_bit_identical_to_the_reference_loop() {
        // the batch restructure may reorder *code*, never arithmetic:
        // every report field must match the retained fused loop bit for
        // bit, on both cache classes and a non-default chunk size
        let t = gen::random(&[512, 512, 512], 20_000, 31);
        let view = ModeView::build(&t, 0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let budgets = [
            SimBudget::default(),
            SimBudget { threads: 2, chunk_nnz: 777, ..SimBudget::default() },
        ];
        // degenerate and hierarchy configs: both loops route misses
        // through the shared hierarchy_complete, so the stack must stay
        // as bit-pinned as the classic path
        let mut hier_cfg = small_cfg();
        hier_cfg.levels = crate::mem::hierarchy::parse_levels("sram:32KiB,local:4KiB:db").unwrap();
        hier_cfg.validate().unwrap();
        for cfg in [small_cfg(), hier_cfg] {
            for name in ["e-sram", "o-sram"] {
                for budget in budgets {
                    let soa = simulate_kernel_mode_event_with_view_budget(
                        kernel,
                        &t,
                        &view,
                        0,
                        &cfg,
                        &tech(name),
                        budget,
                    );
                    let reference = simulate_kernel_mode_event_reference(
                        kernel,
                        &t,
                        &view,
                        0,
                        &cfg,
                        &tech(name),
                        budget,
                    );
                    assert_eq!(
                        soa.runtime_cycles().to_bits(),
                        reference.runtime_cycles().to_bits(),
                        "{name}"
                    );
                    for (s, r) in soa.pes.iter().zip(&reference.pes) {
                        assert_eq!(s.stall_cycles.to_bits(), r.stall_cycles.to_bits(), "{name}");
                        assert_eq!(s.dram_cycles.to_bits(), r.dram_cycles.to_bits(), "{name}");
                        assert_eq!(s.cache_cycles, r.cache_cycles, "{name}");
                        assert_eq!(s.cache_stats, r.cache_stats, "{name}");
                        assert_eq!(s.dram_stream_bytes, r.dram_stream_bytes, "{name}");
                        assert_eq!(s.sampled_nnz, r.sampled_nnz, "{name}");
                        assert_eq!(s.levels, r.levels, "{name}");
                    }
                }
            }
        }
    }

    #[test]
    fn sampled_replay_keeps_functional_accounting_exact() {
        // sampling skips timing, never the shared functional model: hit
        // rates, traffic and busy sums are bit-identical at every rate
        let t = gen::random(&[512, 512, 512], 20_000, 11);
        let cfg = small_cfg();
        let view = ModeView::build(&t, 0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let small_chunks = SimBudget { chunk_nnz: 509, ..SimBudget::default() };
        let exact = simulate_kernel_mode_event_with_view_budget(
            kernel,
            &t,
            &view,
            0,
            &cfg,
            &tech("e-sram"),
            small_chunks,
        );
        for rate in [0.1, 0.25, 0.5] {
            let budget = small_chunks.with_sample(SampleSpec { rate, seed: 9 });
            let s = simulate_kernel_mode_event_with_view_budget(
                kernel,
                &t,
                &view,
                0,
                &cfg,
                &tech("e-sram"),
                budget,
            );
            assert_eq!(exact.hit_rate(), s.hit_rate(), "rate {rate}");
            assert_eq!(exact.total_dram_bytes(), s.total_dram_bytes(), "rate {rate}");
            assert_eq!(exact.total_onchip_words(), s.total_onchip_words(), "rate {rate}");
            for (e, p) in exact.pes.iter().zip(&s.pes) {
                assert_eq!(e.dram_cycles.to_bits(), p.dram_cycles.to_bits(), "rate {rate}");
                assert_eq!(e.cache_cycles, p.cache_cycles, "rate {rate}");
                assert_eq!(e.pipeline_cycles.to_bits(), p.pipeline_cycles.to_bits());
                assert_eq!(e.psum_cycles.to_bits(), p.psum_cycles.to_bits());
                // the stall became an estimate — non-negative, partial
                // coverage, with a band attached
                assert!(p.stall_cycles >= 0.0);
                assert!(p.sampled_nnz <= p.nnz);
                assert!(p.stall_stderr_cycles >= 0.0);
            }
            assert!(s.sampled_frac() < 1.0, "rate {rate} sampled everything");
            assert!(s.runtime_cycles() > 0.0);
        }
    }

    #[test]
    fn functional_accounting_is_bit_identical_to_analytic() {
        // same MemoryController drive ⇒ same hits, traffic, busy sums —
        // the engines may only differ in stall_cycles
        let t = gen::random(&[512, 512, 512], 20_000, 5);
        let cfg = small_cfg();
        for name in ["e-sram", "o-sram"] {
            let a = engine::simulate_mode(&t, 0, &cfg, &tech(name));
            let e = simulate_mode_event(&t, 0, &cfg, &tech(name));
            assert_eq!(a.hit_rate(), e.hit_rate(), "{name}");
            assert_eq!(a.total_dram_bytes(), e.total_dram_bytes(), "{name}");
            assert_eq!(a.total_onchip_words(), e.total_onchip_words(), "{name}");
            for (pa, pe) in a.pes.iter().zip(&e.pes) {
                assert_eq!(pa.nnz, pe.nnz);
                assert_eq!(pa.dram_cycles.to_bits(), pe.dram_cycles.to_bits());
                assert_eq!(pa.cache_cycles, pe.cache_cycles);
                assert_eq!(pa.stall_cycles, 0.0);
                assert!(pe.stall_cycles >= 0.0);
            }
        }
    }

    #[test]
    fn event_never_faster_than_analytic() {
        let cfg = small_cfg();
        for (dims, nnz) in [([512u64, 512, 512], 20_000), ([100_000, 90_000, 80_000], 10_000)] {
            let t = gen::random(&dims, nnz, 7);
            for name in crate::mem::registry::names() {
                for mode in 0..3 {
                    let a = engine::simulate_mode(&t, mode, &cfg, &tech(&name));
                    let e = simulate_mode_event(&t, mode, &cfg, &tech(&name));
                    assert!(
                        e.runtime_cycles() >= a.runtime_cycles(),
                        "{name} mode {mode}: event {} < analytic {}",
                        e.runtime_cycles(),
                        a.runtime_cycles()
                    );
                }
            }
        }
    }

    #[test]
    fn event_never_faster_than_analytic_on_every_kernel() {
        // the contention contract is kernel-agnostic: the replay may only
        // add time, whatever the workload shape
        let t = gen::random(&[600, 500, 400], 12_000, 19);
        let cfg = small_cfg();
        for kind in KernelKind::ALL {
            for name in ["e-sram", "o-sram"] {
                let a = engine::simulate_kernel_mode(kind.kernel(), &t, 1, &cfg, &tech(name));
                let e = simulate_kernel_mode_event(kind.kernel(), &t, 1, &cfg, &tech(name));
                assert!(
                    e.runtime_cycles() >= a.runtime_cycles(),
                    "{kind} on {name}: event {} < analytic {}",
                    e.runtime_cycles(),
                    a.runtime_cycles()
                );
                assert_eq!(a.hit_rate(), e.hit_rate(), "{kind} on {name}");
                assert_eq!(a.total_dram_bytes(), e.total_dram_bytes(), "{kind} on {name}");
                assert_eq!(e.kernel, kind.name());
            }
        }
    }

    // NOTE: the bank-conflict regression (single hot row ⇒ event strictly
    // slower on banked electrical caches) lives in the golden integration
    // suite, rust/tests/engine_agreement.rs — one fixture, one owner.
    // Sampled-replay coverage (rate-1.0 bit-identity across presets,
    // band coverage, thread determinism) lives in
    // rust/tests/sampled_replay.rs.

    #[test]
    fn empty_tensor_event_matches_analytic() {
        let t = SparseTensor::new("empty", vec![10, 10]);
        let cfg = small_cfg();
        let a = engine::simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let e = simulate_mode_event(&t, 0, &cfg, &tech("o-sram"));
        assert_eq!(e.total_nnz(), 0);
        assert_eq!(a.runtime_cycles().to_bits(), e.runtime_cycles().to_bits());
    }

    #[test]
    fn empty_tensor_sampled_report_is_well_formed() {
        // zero chunks ⇒ zero samples: stall and band must come out 0.0,
        // not NaN, and sampled_frac must read as exact
        let t = SparseTensor::new("empty", vec![10, 10]);
        let cfg = small_cfg();
        let view = ModeView::build(&t, 0);
        let kernel = KernelKind::Spmttkrp.kernel();
        let budget = SimBudget::default().with_sample(SampleSpec { rate: 0.25, seed: 1 });
        let r = simulate_kernel_mode_event_with_view_budget(
            kernel,
            &t,
            &view,
            0,
            &cfg,
            &tech("o-sram"),
            budget,
        );
        for p in &r.pes {
            assert_eq!(p.stall_cycles, 0.0);
            assert_eq!(p.stall_stderr_cycles, 0.0);
            assert!((p.sampled_frac() - 1.0).abs() < 1e-12);
        }
        assert!(r.runtime_cycles().is_finite());
    }

    #[test]
    fn every_registered_technology_event_simulates() {
        let t = gen::random(&[64, 64, 64], 5_000, 21);
        let cfg = small_cfg();
        for tname in crate::mem::registry::names() {
            let r = simulate_mode_event(&t, 0, &cfg, &tech(&tname));
            assert_eq!(r.total_nnz(), 5_000, "{tname}");
            assert!(r.runtime_cycles() > 0.0, "{tname}");
            assert_eq!(r.tech.name, tname);
        }
    }

    #[test]
    fn all_modes_event_covers_every_mode() {
        let t = gen::random(&[64, 64, 64, 64], 4_000, 9);
        let r = simulate_all_modes_event(&t, &small_cfg(), &tech("o-sram"));
        assert_eq!(r.modes.len(), 4);
        for (i, m) in r.modes.iter().enumerate() {
            assert_eq!(m.mode, i);
            assert_eq!(m.total_nnz(), 4_000);
        }
        assert_eq!(r.kernel, "spmttkrp");
        assert!(r.total_runtime_s() > 0.0);
    }
}
