//! Deterministic parallel-map plumbing, shared by every host-parallel
//! layer of the simulator.
//!
//! One primitive serves both parallelism levels: the design-space sweep
//! ([`crate::sim::sweep`]) fans *scenarios* across OS threads with it,
//! and both simulation engines ([`crate::sim::engine`],
//! [`crate::sim::event`]) fan their *per-PE inner loops* across it. The
//! output is slot-indexed — result `i` is always `f(&items[i])` — and
//! every item is computed independently from shared immutable inputs, so
//! no floating-point reduction order ever depends on the thread count:
//! any thread budget reproduces bit-identical numbers.
//!
//! How the two levels share one budget without oversubscription is the
//! thread-budget rule documented on [`crate::sim::SimBudget`] and
//! implemented in [`crate::sim::sweep::run_sweep`].
//!
//! **Span recording.** When [`crate::obs`] recording is active, worker
//! threads capture their span events into per-item buffers
//! ([`crate::obs::span::capture`]) and the map appends them to the
//! caller's sink **in slot order** after the join — trace content is a
//! pure function of the item list, never of thread scheduling, and the
//! recording-off path is exactly the code below.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::obs::span::{capture, recording_active, sink_append, SpanEvent};

/// Threads a requested budget resolves to (0 ⇒ all available cores).
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Deterministic-order parallel map: spawns up to `threads` scoped OS
/// threads that claim indices from an atomic counter; slot `i` of the
/// output always holds `f(&items[i])`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_init(items, threads, || (), |_, _, item| f(item))
}

/// [`parallel_map`] with per-worker scratch state: each worker thread
/// calls `init()` once and threads the resulting value mutably through
/// every item it claims. This is how the engines reuse one
/// [`crate::kernel::AccessChunk`] across every chunk *and* every PE a
/// worker processes — the zero-allocation steady state. The callback
/// also receives the item's index (== its output slot), so callers
/// never need to materialize an enumerated copy of their item list.
///
/// With an effective budget of one thread the map runs inline on the
/// caller's thread (no spawn); results are identical either way.
pub fn parallel_map_init<T, R, S, I, F>(items: &[T], threads: usize, init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n_threads = threads.clamp(1, items.len().max(1));
    if n_threads == 1 {
        // inline on the caller's thread: spans flow to the caller's own
        // sink in natural (slot) order already
        let mut scratch = init();
        return items.iter().enumerate().map(|(i, item)| f(&mut scratch, i, item)).collect();
    }
    if recording_active() {
        return parallel_map_traced(items, n_threads, init, f);
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(&mut scratch, i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_map slot filled"))
        .collect()
}

/// The recording-active threaded path: identical claim/slot scheme, but
/// each item's span events are captured into a per-slot buffer and
/// appended to the caller's sink in slot order after every worker has
/// joined — so the recorded trace never depends on thread interleaving,
/// and recording can never reorder or perturb the computation itself.
fn parallel_map_traced<T, R, S, I, F>(items: &[T], n_threads: usize, init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(R, Vec<SpanEvent>)>>> =
        items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let pair = capture(|| f(&mut scratch, i, &items[i]));
                    *slots[i].lock().unwrap() = Some(pair);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            let (r, events) = m.into_inner().unwrap().expect("parallel_map slot filled");
            sink_append(events);
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolves_zero_to_cores() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn results_are_slot_ordered_for_any_thread_count() {
        let items: Vec<usize> = (0..257).collect();
        let expect: Vec<usize> = items.iter().map(|&i| i * i).collect();
        for threads in [1, 2, 5, 64] {
            let got = parallel_map(&items, threads, |&i| i * i);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_input_maps_to_empty_output() {
        let items: Vec<u32> = Vec::new();
        assert!(parallel_map(&items, 8, |&i| i).is_empty());
    }

    #[test]
    fn per_worker_scratch_and_slot_index_are_threaded_through() {
        // each worker's scratch counts the items it processed, and the
        // callback's index always names the output slot; single-threaded,
        // one scratch sees everything in order
        let items: Vec<usize> = (0..100).collect();
        let got = parallel_map_init(
            &items,
            1,
            || 0usize,
            |seen, idx, &v| {
                *seen += 1;
                (idx, v, *seen)
            },
        );
        for (k, &(idx, v, seen)) in got.iter().enumerate() {
            assert_eq!(idx, k, "callback index == output slot");
            assert_eq!(v, k);
            assert_eq!(seen, k + 1, "one inline scratch visits items in order");
        }
        // multi-threaded: scratches partition the items exactly and the
        // index still matches the item
        let got = parallel_map_init(&items, 4, || 0usize, |seen, idx, &v| {
            *seen += 1;
            idx + v
        });
        let expect: Vec<usize> = items.iter().map(|&v| 2 * v).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn traced_map_merges_span_events_in_slot_order() {
        use crate::obs::span::{capture, Span};
        // item i emits i % 3 child spans inside one "item" span; after
        // the merge, the child-count sequence between "item" events must
        // be exactly [0 % 3, 1 % 3, 2 % 3, ...] — slot order, whatever
        // the thread interleaving was
        let items: Vec<usize> = (0..61).collect();
        let (got, evs) = capture(|| {
            parallel_map(&items, 8, |&i| {
                let _outer = Span::enter("item", "test");
                for _ in 0..(i % 3) {
                    let _c = Span::enter("child", "test");
                }
                i * 2
            })
        });
        let expect: Vec<usize> = items.iter().map(|&i| i * 2).collect();
        assert_eq!(got, expect, "tracing never changes results");
        let mut children_seen = 0usize;
        let mut item_idx = 0usize;
        for ev in &evs {
            match ev.name {
                "child" => children_seen += 1,
                "item" => {
                    assert_eq!(children_seen, item_idx % 3, "slot {item_idx}");
                    children_seen = 0;
                    item_idx += 1;
                }
                other => panic!("unexpected span {other}"),
            }
        }
        assert_eq!(item_idx, items.len(), "one span per item");
    }
}
