//! Deterministic parallel-map plumbing, shared by every host-parallel
//! layer of the simulator.
//!
//! One primitive serves both parallelism levels: the design-space sweep
//! ([`crate::sim::sweep`]) fans *scenarios* across OS threads with it,
//! and both simulation engines ([`crate::sim::engine`],
//! [`crate::sim::event`]) fan their *per-PE inner loops* across it. The
//! output is slot-indexed — result `i` is always `f(&items[i])` — and
//! every item is computed independently from shared immutable inputs, so
//! no floating-point reduction order ever depends on the thread count:
//! any thread budget reproduces bit-identical numbers.
//!
//! How the two levels share one budget without oversubscription is the
//! thread-budget rule documented on [`crate::sim::SimBudget`] and
//! implemented in [`crate::sim::sweep::run_sweep`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Threads a requested budget resolves to (0 ⇒ all available cores).
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Deterministic-order parallel map: spawns up to `threads` scoped OS
/// threads that claim indices from an atomic counter; slot `i` of the
/// output always holds `f(&items[i])`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_init(items, threads, || (), |_, _, item| f(item))
}

/// [`parallel_map`] with per-worker scratch state: each worker thread
/// calls `init()` once and threads the resulting value mutably through
/// every item it claims. This is how the engines reuse one
/// [`crate::kernel::AccessChunk`] across every chunk *and* every PE a
/// worker processes — the zero-allocation steady state. The callback
/// also receives the item's index (== its output slot), so callers
/// never need to materialize an enumerated copy of their item list.
///
/// With an effective budget of one thread the map runs inline on the
/// caller's thread (no spawn); results are identical either way.
pub fn parallel_map_init<T, R, S, I, F>(items: &[T], threads: usize, init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n_threads = threads.clamp(1, items.len().max(1));
    if n_threads == 1 {
        let mut scratch = init();
        return items.iter().enumerate().map(|(i, item)| f(&mut scratch, i, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(&mut scratch, i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_map slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolves_zero_to_cores() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }

    #[test]
    fn results_are_slot_ordered_for_any_thread_count() {
        let items: Vec<usize> = (0..257).collect();
        let expect: Vec<usize> = items.iter().map(|&i| i * i).collect();
        for threads in [1, 2, 5, 64] {
            let got = parallel_map(&items, threads, |&i| i * i);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_input_maps_to_empty_output() {
        let items: Vec<u32> = Vec::new();
        assert!(parallel_map(&items, 8, |&i| i).is_empty());
    }

    #[test]
    fn per_worker_scratch_and_slot_index_are_threaded_through() {
        // each worker's scratch counts the items it processed, and the
        // callback's index always names the output slot; single-threaded,
        // one scratch sees everything in order
        let items: Vec<usize> = (0..100).collect();
        let got = parallel_map_init(
            &items,
            1,
            || 0usize,
            |seen, idx, &v| {
                *seen += 1;
                (idx, v, *seen)
            },
        );
        for (k, &(idx, v, seen)) in got.iter().enumerate() {
            assert_eq!(idx, k, "callback index == output slot");
            assert_eq!(v, k);
            assert_eq!(seen, k + 1, "one inline scratch visits items in order");
        }
        // multi-threaded: scratches partition the items exactly and the
        // index still matches the item
        let got = parallel_map_init(&items, 4, || 0usize, |seen, idx, &v| {
            *seen += 1;
            idx + v
        });
        let expect: Vec<usize> = items.iter().map(|&v| 2 * v).collect();
        assert_eq!(got, expect);
    }
}
