//! Accelerator configuration (Table I) and on-chip resource budgeting.

pub mod config;
pub mod design;
