//! Accelerator configuration — Table I of the paper, plus the platform
//! constants of §V-A, all overridable from a config file or CLI.

use crate::mem::dram::DramConfig;
use crate::mem::hierarchy::{parse_levels, MemLevelSpec};
use crate::util::configfile::Config;

/// Full accelerator + platform configuration.
///
/// Defaults reproduce Table I and §V-A exactly:
///
/// | module             | configuration                          |
/// |--------------------|----------------------------------------|
/// | PE                 | 4 PEs (= number of DRAM channels)      |
/// | parallel pipelines | 80 per PE, psum buffer 1024 elements   |
/// | cache subsystem    | 3 caches, 4-way, 4096 lines × 64 B     |
/// | DMAs               | 6 buffers × 64 KB                      |
/// | rank R             | 16                                     |
/// | fabric clock       | 500 MHz                                |
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Processing elements; the paper keeps this equal to the number of
    /// attached DRAM channels (Fig. 4).
    pub n_pes: usize,
    /// Parallel rank pipelines per PE.
    pub n_pipelines: usize,
    /// Partial-sum buffer capacity per pipeline, in f32 elements.
    pub psum_elements: usize,
    /// Caches per PE memory controller.
    pub n_caches: usize,
    /// Cache associativity.
    pub cache_assoc: usize,
    /// Total cache lines per cache (sets = lines / assoc).
    pub cache_lines: usize,
    /// Cache line width in bytes.
    pub line_bytes: usize,
    /// DMA buffers per PE.
    pub n_dma_buffers: usize,
    /// Bytes per DMA buffer.
    pub dma_buffer_bytes: usize,
    /// CP decomposition rank R.
    pub rank: usize,
    /// Fabric (electrical mesh) clock in Hz.
    pub fabric_hz: f64,
    /// External memory channel model (one channel per PE).
    pub dram: DramConfig,
    /// Data-array interleaving factor for *electrical* on-chip arrays:
    /// how many BRAM banks a cache data array / psum buffer cascades to
    /// widen its port (standard FPGA cache construction). The optical
    /// array needs no banking — Eq. 1 already yields 200 words/cycle.
    pub esram_bank_factor: usize,
    /// Compute (LUT/DSP mesh) power draw in watts while the design is
    /// active — identical across the two memory technologies, used by
    /// Eq. 2's `P_compute × t_runtime`. Default sized for the Table I
    /// design's ~1.3K DSP-equivalent FMA pipelines at 12 nm / 500 MHz
    /// (~0.3 mW each), not the whole card.
    pub compute_power_w: f64,
    /// Optional §IV-A type-3 routing: factor matrices with more rows than
    /// `cache_lines × factor` bypass the caches to the element-wise DMA.
    /// `None` (the default) routes every factor matrix through the cache
    /// subsystem, which is the paper's configuration; the ablation bench
    /// sweeps this knob.
    pub cache_bypass_factor: Option<usize>,
    /// Override the WDM wavelength count λ of any optical-class (fast,
    /// multi-wavelength) technology — the builtin O-SRAM's 5, a derived
    /// variant's, etc. Eq. 1 ablation knob — changes concurrency, not
    /// the device energies. See [`tuned_tech`](Self::tuned_tech).
    pub osram_lambda_override: Option<u32>,
    /// Multi-level on-chip memory stack between the PE caches and DRAM,
    /// outermost (DRAM-side) first. Empty (the default and the paper's
    /// configuration) is the *degenerate* single-level model: every
    /// PE-cache miss goes straight to the DRAM channel, bit-identical
    /// to the pre-hierarchy output. Set via `--levels`, the
    /// `hierarchy.levels` config key, or programmatically; see
    /// [`crate::mem::hierarchy`].
    pub levels: Vec<MemLevelSpec>,

    // --- platform resource budget (§V-A, Alveo U250-class) ---
    /// Total on-chip memory replaced by O-SRAM, bytes (54 MB).
    pub onchip_bytes: u64,
    pub luts: u64,
    pub flipflops: u64,
    pub dsps: u64,
}

impl AcceleratorConfig {
    /// Table I / §V-A defaults.
    pub fn paper_default() -> Self {
        AcceleratorConfig {
            n_pes: 4,
            n_pipelines: 80,
            psum_elements: 1024,
            n_caches: 3,
            cache_assoc: 4,
            cache_lines: 4096,
            line_bytes: 64,
            n_dma_buffers: 6,
            dma_buffer_bytes: 64 * 1024,
            rank: 16,
            fabric_hz: crate::mem::tech::FABRIC_HZ,
            dram: DramConfig::default(),
            esram_bank_factor: 4,
            compute_power_w: 0.4,
            cache_bypass_factor: None,
            osram_lambda_override: None,
            levels: Vec::new(),
            onchip_bytes: 54 * 1024 * 1024,
            luts: 6_433_000,
            flipflops: 8_474_000,
            dsps: 31_000,
        }
    }

    /// Scale the on-chip working-set capacity with a scaled workload (see
    /// `tensor::gen`). A tensor scaled by `s` shrinks each mode dimension —
    /// and hence each factor matrix's row working set — by `s^(1/N)`, so
    /// the cache/DMA capacities scale by the same `s^(1/3)` (N = 3, the
    /// dominant arity of Table II) to preserve the working-set-to-capacity
    /// ratio that determines the hit-rate regime. Compute resources are
    /// left untouched.
    pub fn scaled(mut self, s: f64) -> Self {
        assert!(s > 0.0 && s <= 1.0);
        let cap = s.powf(1.0 / 3.0);
        let clamp_pow2 = |x: usize, lo: usize| -> usize {
            let scaled = (x as f64 * cap).max(lo as f64) as usize;
            scaled.next_power_of_two()
        };
        self.cache_lines = clamp_pow2(self.cache_lines, 16 * self.cache_assoc);
        self.dma_buffer_bytes = clamp_pow2(self.dma_buffer_bytes, 1024);
        self.onchip_bytes = ((self.onchip_bytes as f64 * cap) as u64).max(1 << 20);
        self
    }

    /// Cache sets (lines / associativity).
    pub fn cache_sets(&self) -> usize {
        self.cache_lines / self.cache_assoc
    }

    /// Apply config-level device overrides (the λ ablation knob) to a
    /// registry-resolved technology. Layers that simulate always go
    /// through this, so a config tweak reaches every consumer uniformly.
    ///
    /// The λ override applies to any *WDM optical-class* technology —
    /// fast array with wavelength concurrency — not to a hardwired name,
    /// so registry-defined optical variants ablate the same way the
    /// builtin O-SRAM does. Electrical (fabric-synchronous or single-λ)
    /// arrays pass through untouched.
    pub fn tuned_tech(
        &self,
        base: &crate::mem::tech::MemTechnology,
    ) -> crate::mem::tech::MemTechnology {
        let mut t = base.clone();
        if t.is_fast_array(self.fabric_hz) && t.wavelengths > 1 {
            if let Some(l) = self.osram_lambda_override {
                assert!(l >= 1);
                t.wavelengths = l;
                t.lanes_per_core_cycle = l;
                t.ports_per_block = (l as f64 * t.freq_hz / self.fabric_hz).round() as u32;
            }
        }
        t
    }

    /// Data-array bank cascade for an on-chip array of `tech`: electrical
    /// (fabric-synchronous) arrays widen their port by cascading
    /// [`esram_bank_factor`](Self::esram_bank_factor) blocks; a fast
    /// (optical-class) array already delivers Eq. 1 bandwidth and needs no
    /// cascading.
    pub fn bank_factor(&self, tech: &crate::mem::tech::MemTechnology) -> usize {
        if tech.is_fast_array(self.fabric_hz) {
            1
        } else {
            self.esram_bank_factor
        }
    }

    /// Bytes of one factor-matrix row (R × f32).
    pub fn row_bytes(&self) -> usize {
        self.rank * 4
    }

    /// Per-cache data capacity in bytes.
    pub fn cache_bytes(&self) -> usize {
        self.cache_lines * self.line_bytes
    }

    /// Apply overrides from a parsed config file (TOML subset). Unknown
    /// keys are rejected so typos fail loudly.
    pub fn apply_config(&mut self, c: &Config) -> Result<(), String> {
        const KNOWN: [&str; 14] = [
            "pe.count",
            "pe.pipelines",
            "pe.psum_elements",
            "cache.count",
            "cache.assoc",
            "cache.lines",
            "cache.line_bytes",
            "dma.count",
            "dma.buffer_bytes",
            "model.rank",
            "model.fabric_mhz",
            "model.esram_bank_factor",
            "model.compute_power_w",
            "platform.onchip_mb",
            "hierarchy.levels",
        ];
        for k in c.keys() {
            if k.starts_with("tech.") {
                // `[tech.<name>]` sections define registry technologies and
                // are consumed by `mem::registry::load_config`, not here.
                continue;
            }
            if !KNOWN.contains(&k) {
                return Err(format!("unknown config key `{k}`"));
            }
        }
        self.n_pes = c.usize_or("pe.count", self.n_pes);
        self.n_pipelines = c.usize_or("pe.pipelines", self.n_pipelines);
        self.psum_elements = c.usize_or("pe.psum_elements", self.psum_elements);
        self.n_caches = c.usize_or("cache.count", self.n_caches);
        self.cache_assoc = c.usize_or("cache.assoc", self.cache_assoc);
        self.cache_lines = c.usize_or("cache.lines", self.cache_lines);
        self.line_bytes = c.usize_or("cache.line_bytes", self.line_bytes);
        self.n_dma_buffers = c.usize_or("dma.count", self.n_dma_buffers);
        self.dma_buffer_bytes = c.usize_or("dma.buffer_bytes", self.dma_buffer_bytes);
        self.rank = c.usize_or("model.rank", self.rank);
        self.fabric_hz = c.f64_or("model.fabric_mhz", self.fabric_hz / 1e6) * 1e6;
        self.esram_bank_factor = c.usize_or("model.esram_bank_factor", self.esram_bank_factor);
        self.compute_power_w = c.f64_or("model.compute_power_w", self.compute_power_w);
        self.onchip_bytes =
            (c.f64_or("platform.onchip_mb", self.onchip_bytes as f64 / (1 << 20) as f64)
                * (1 << 20) as f64) as u64;
        if let Some(v) = c.get("hierarchy.levels") {
            let spec = v
                .as_str()
                .ok_or_else(|| "hierarchy.levels must be a string (see --levels)".to_string())?;
            self.levels = parse_levels(spec).map_err(|e| format!("hierarchy.levels: {e}"))?;
        }
        self.validate()
    }

    /// Structural sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_pes == 0 || self.n_pipelines == 0 || self.n_caches == 0 {
            return Err("PE/pipeline/cache counts must be positive".into());
        }
        if self.cache_lines % self.cache_assoc != 0 {
            return Err("cache_lines must be divisible by associativity".into());
        }
        if !self.cache_sets().is_power_of_two() {
            return Err("cache sets must be a power of two".into());
        }
        if self.row_bytes() > self.line_bytes {
            return Err(format!(
                "factor row ({} B) must fit in a cache line ({} B)",
                self.row_bytes(),
                self.line_bytes
            ));
        }
        if self.fabric_hz <= 0.0 {
            return Err("fabric clock must be positive".into());
        }
        self.validate_levels()
    }

    /// Structural checks for the memory-hierarchy stack. Each level
    /// line must be a power-of-two multiple of the PE cache line (so a
    /// level key is a shift of the row key), the capacity must hold a
    /// power-of-two line count (the functional model is set-associative
    /// like the PE caches), and names must be unique.
    fn validate_levels(&self) -> Result<(), String> {
        for (i, l) in self.levels.iter().enumerate() {
            let line = l.resolved_line_bytes(self.line_bytes);
            if line % self.line_bytes != 0 || !(line / self.line_bytes).is_power_of_two() {
                return Err(format!(
                    "level `{}`: line ({line} B) must be a power-of-two multiple of the \
                     cache line ({} B)",
                    l.name, self.line_bytes
                ));
            }
            if l.capacity_bytes % line as u64 != 0
                || !(l.capacity_bytes / line as u64).is_power_of_two()
            {
                return Err(format!(
                    "level `{}`: capacity ({} B) must be a power-of-two multiple of its \
                     line ({line} B)",
                    l.name, l.capacity_bytes
                ));
            }
            if l.banks == 0 {
                return Err(format!("level `{}`: bank count must be positive", l.name));
            }
            if self.levels[..i].iter().any(|p| p.name == l.name) {
                return Err(format!("duplicate level name `{}`", l.name));
            }
            // inner levels must not use a coarser line than the level
            // outside them, or a fill could not be assembled from one
            // outer request
            if let Some(prev) = i.checked_sub(1).map(|j| &self.levels[j]) {
                let prev_line = prev.resolved_line_bytes(self.line_bytes);
                if line > prev_line {
                    return Err(format!(
                        "level `{}`: line ({line} B) exceeds the outer level `{}` line \
                         ({prev_line} B)",
                        l.name, prev.name
                    ));
                }
            }
        }
        Ok(())
    }
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self::paper_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table_i() {
        let c = AcceleratorConfig::paper_default();
        assert_eq!(c.n_pes, 4);
        assert_eq!(c.n_pipelines, 80);
        assert_eq!(c.psum_elements, 1024);
        assert_eq!(c.n_caches, 3);
        assert_eq!(c.cache_assoc, 4);
        assert_eq!(c.cache_lines, 4096);
        assert_eq!(c.line_bytes, 64);
        assert_eq!(c.n_dma_buffers, 6);
        assert_eq!(c.dma_buffer_bytes, 64 * 1024);
        assert_eq!(c.rank, 16);
        c.validate().unwrap();
    }

    #[test]
    fn rank16_row_is_exactly_one_line() {
        // R=16 × 4 B = 64 B — the paper's line width; one row per line.
        let c = AcceleratorConfig::paper_default();
        assert_eq!(c.row_bytes(), c.line_bytes);
    }

    #[test]
    fn derived_geometry() {
        let c = AcceleratorConfig::paper_default();
        assert_eq!(c.cache_sets(), 1024);
        assert_eq!(c.cache_bytes(), 256 * 1024);
    }

    #[test]
    fn scaled_keeps_validity_and_shrinks() {
        let c = AcceleratorConfig::paper_default().scaled(1.0 / 256.0);
        c.validate().unwrap();
        assert!(c.cache_lines < 4096);
        assert!(c.cache_lines >= 16 * c.cache_assoc);
        assert!(c.cache_sets().is_power_of_two());
    }

    #[test]
    fn config_file_overrides() {
        let mut c = AcceleratorConfig::paper_default();
        let file = Config::parse("[pe]\ncount = 8\n[model]\nrank = 32\n[cache]\nline_bytes = 128")
            .unwrap();
        c.apply_config(&file).unwrap();
        assert_eq!(c.n_pes, 8);
        assert_eq!(c.rank, 32);
        assert_eq!(c.line_bytes, 128);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = AcceleratorConfig::paper_default();
        let file = Config::parse("[pe]\ncuont = 8").unwrap();
        assert!(c.apply_config(&file).is_err());
    }

    #[test]
    fn tech_sections_are_ignored_by_accel_config() {
        let mut c = AcceleratorConfig::paper_default();
        let file =
            Config::parse("[tech.custom]\nbase = \"e-sram\"\n[pe]\ncount = 2").unwrap();
        c.apply_config(&file).unwrap();
        assert_eq!(c.n_pes, 2);
    }

    #[test]
    fn tuned_tech_applies_lambda_override_to_wdm_optical_arrays() {
        let mut c = AcceleratorConfig::paper_default();
        c.osram_lambda_override = Some(10);
        let o = c.tuned_tech(&crate::mem::osram::osram());
        assert_eq!(o.wavelengths, 10);
        assert_eq!(o.lanes_per_core_cycle, 10);
        assert_eq!(o.ports_per_block, 400);
        // the knob is structural, not name-matched: a derived optical
        // variant (here the IMC array) ablates too
        let imc = c.tuned_tech(&crate::mem::posram::osram_imc());
        assert_eq!(imc.wavelengths, 10);
        // electrical (fabric-synchronous) technologies pass through
        let e = c.tuned_tech(&crate::mem::esram::esram());
        assert_eq!(e, crate::mem::esram::esram());
        let u = c.tuned_tech(&crate::mem::uram::uram());
        assert_eq!(u, crate::mem::uram::uram());
        // without the knob, everything is the identity
        let plain = AcceleratorConfig::paper_default();
        assert_eq!(plain.tuned_tech(&crate::mem::osram::osram()), crate::mem::osram::osram());
    }

    #[test]
    fn bank_factor_follows_the_fast_array_predicate() {
        let c = AcceleratorConfig::paper_default();
        assert_eq!(c.bank_factor(&crate::mem::esram::esram()), c.esram_bank_factor);
        assert_eq!(c.bank_factor(&crate::mem::uram::uram()), c.esram_bank_factor);
        assert_eq!(c.bank_factor(&crate::mem::osram::osram()), 1);
        assert_eq!(c.bank_factor(&crate::mem::posram::osram_imc()), 1);
    }

    #[test]
    fn hierarchy_levels_config_key_and_validation() {
        let mut c = AcceleratorConfig::paper_default();
        let file = Config::parse("[hierarchy]\nlevels = \"sram:256KiB:8banks,local:4KiB:db\"")
            .unwrap();
        c.apply_config(&file).unwrap();
        assert_eq!(c.levels.len(), 2);
        assert_eq!(c.levels[0].name, "sram");
        assert_eq!(c.levels[0].banks, 8);
        assert!(c.levels[1].double_buffer);

        // line must be a power-of-two multiple of the cache line
        let mut bad = AcceleratorConfig::paper_default();
        bad.levels = parse_levels("l0:4KiB:line96").unwrap();
        assert!(bad.validate().is_err());
        // capacity must hold a power-of-two line count
        let mut bad = AcceleratorConfig::paper_default();
        bad.levels = parse_levels("l0:192KiB").unwrap(); // 3072 lines of 64 B
        assert!(bad.validate().is_err());
        // inner line must not exceed the outer line
        let mut bad = AcceleratorConfig::paper_default();
        bad.levels = parse_levels("outer:64KiB:line128,inner:8KiB:line256").unwrap();
        assert!(bad.validate().is_err());
        // duplicate names rejected even when set programmatically
        let mut bad = AcceleratorConfig::paper_default();
        bad.levels =
            vec![MemLevelSpec::new("x", 64 * 1024), MemLevelSpec::new("x", 4 * 1024)];
        assert!(bad.validate().is_err());
        // a well-formed two-level stack validates
        let mut ok = AcceleratorConfig::paper_default();
        ok.levels = parse_levels("sram:256KiB:line256,local:4KiB:db").unwrap();
        ok.validate().unwrap();
    }

    #[test]
    fn paper_default_has_no_hierarchy_and_scaling_keeps_it() {
        let c = AcceleratorConfig::paper_default();
        assert!(c.levels.is_empty(), "degenerate config must stay degenerate");
        let mut c2 = AcceleratorConfig::paper_default();
        c2.levels = parse_levels("sram:256KiB").unwrap();
        let s = c2.scaled(1.0 / 64.0);
        assert_eq!(s.levels, parse_levels("sram:256KiB").unwrap(), "scaled() leaves levels");
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = AcceleratorConfig::paper_default();
        c.rank = 64; // 256 B row > 64 B line
        assert!(c.validate().is_err());
        let mut c2 = AcceleratorConfig::paper_default();
        c2.cache_lines = 4095;
        assert!(c2.validate().is_err());
        let mut c3 = AcceleratorConfig::paper_default();
        c3.n_pes = 0;
        assert!(c3.validate().is_err());
    }
}
