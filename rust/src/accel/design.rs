//! On-chip memory budgeting: how many SRAM blocks the Table I design
//! instantiates, for the energy model's `n_O-SRAM` term (Eq. 2) and the
//! capacity check against the 54 MB platform budget.

use crate::accel::config::AcceleratorConfig;
use crate::mem::tech::MemTechnology;

/// Bytes of on-chip memory the accelerator design actually instantiates,
/// by component (per the Fig. 4 architecture, aggregated over all PEs).
#[derive(Clone, Debug, PartialEq)]
pub struct OnChipBudget {
    pub cache_data_bytes: u64,
    pub cache_tag_bytes: u64,
    pub psum_bytes: u64,
    pub dma_bytes: u64,
    /// Multi-level memory-hierarchy arrays (`AcceleratorConfig::levels`,
    /// per PE: data + the same 8 B/line tag model as the caches). Zero
    /// for the degenerate single-level configuration, so the paper
    /// default's budget — and everything priced from it (Eq. 2 static
    /// energy, the explore area objective) — is unchanged.
    pub hier_bytes: u64,
}

impl OnChipBudget {
    /// Derive the budget from a configuration.
    pub fn from_config(cfg: &AcceleratorConfig) -> Self {
        let pes = cfg.n_pes as u64;
        let cache_data = pes * cfg.n_caches as u64 * cfg.cache_bytes() as u64;
        // Tag entry: tag (≈ 32 − log2(sets) − log2(line) bits, round to 32)
        // + valid/dirty + LRU stamp (Fig. 5/6 share Tag RAM and LRU RAM):
        // model 8 B per line.
        let cache_tag = pes * cfg.n_caches as u64 * cfg.cache_lines as u64 * 8;
        let psum = pes * cfg.n_pipelines as u64 * cfg.psum_elements as u64 * 4;
        let dma = pes * cfg.n_dma_buffers as u64 * cfg.dma_buffer_bytes as u64;
        let hier = pes
            * cfg
                .levels
                .iter()
                .map(|l| {
                    let line = l.resolved_line_bytes(cfg.line_bytes) as u64;
                    l.capacity_bytes + (l.capacity_bytes / line) * 8
                })
                .sum::<u64>();
        OnChipBudget {
            cache_data_bytes: cache_data,
            cache_tag_bytes: cache_tag,
            psum_bytes: psum,
            dma_bytes: dma,
            hier_bytes: hier,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.cache_data_bytes + self.cache_tag_bytes + self.psum_bytes + self.dma_bytes
            + self.hier_bytes
    }

    pub fn total_bits(&self) -> u64 {
        self.total_bytes() * 8
    }

    /// Number of memory blocks of the given technology the design consumes
    /// (Eq. 2's `n_O-SRAM` when `tech` is the O-SRAM).
    pub fn blocks(&self, tech: &MemTechnology) -> u64 {
        tech.blocks_for_bits(self.total_bits())
    }

    /// Does the design fit the platform's on-chip capacity?
    pub fn fits(&self, cfg: &AcceleratorConfig) -> bool {
        self.total_bytes() <= cfg.onchip_bytes
    }
}

/// A fully-resolved design instance: configuration + memory technology
/// (any registry-resolved parameter set).
#[derive(Clone, Debug)]
pub struct DesignInstance {
    pub cfg: AcceleratorConfig,
    pub tech: MemTechnology,
    pub budget: OnChipBudget,
}

impl DesignInstance {
    pub fn new(cfg: AcceleratorConfig, tech: MemTechnology) -> Self {
        let budget = OnChipBudget::from_config(&cfg);
        DesignInstance { cfg, tech, budget }
    }

    /// `n_blocks` of the instantiated technology (Eq. 2's n_O-SRAM).
    pub fn n_blocks(&self) -> u64 {
        self.budget.blocks(&self.tech)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_budget_fits_54mb() {
        let cfg = AcceleratorConfig::paper_default();
        let b = OnChipBudget::from_config(&cfg);
        // 4 PEs × (3 × 256 KB cache + 80 × 4 KB psum + 6 × 64 KB DMA)
        assert_eq!(b.cache_data_bytes, 4 * 3 * 256 * 1024);
        assert_eq!(b.psum_bytes, 4 * 80 * 1024 * 4);
        assert_eq!(b.dma_bytes, 4 * 6 * 64 * 1024);
        assert!(b.fits(&cfg), "design uses {} B of {} B", b.total_bytes(), cfg.onchip_bytes);
        // sanity: a meaningful fraction of the chip, not a rounding error
        assert!(b.total_bytes() > 4 << 20);
    }

    #[test]
    fn block_counts_differ_by_technology() {
        let cfg = AcceleratorConfig::paper_default();
        let d_o = DesignInstance::new(cfg.clone(), crate::mem::osram::osram());
        let d_e = DesignInstance::new(cfg, crate::mem::esram::esram());
        // O-SRAM blocks are 32 Kb vs E-SRAM 36 Kb ⇒ more O blocks
        assert!(d_o.n_blocks() > d_e.n_blocks());
        // n_OSRAM for Eq. 2 is in the thousands for a MB-scale design
        assert!(d_o.n_blocks() > 1000);
    }

    #[test]
    fn budget_scales_with_pes() {
        let mut cfg = AcceleratorConfig::paper_default();
        let b4 = OnChipBudget::from_config(&cfg);
        cfg.n_pes = 8;
        let b8 = OnChipBudget::from_config(&cfg);
        assert_eq!(b8.total_bytes(), 2 * b4.total_bytes());
    }

    #[test]
    fn hierarchy_levels_join_the_budget() {
        let mut cfg = AcceleratorConfig::paper_default();
        let base = OnChipBudget::from_config(&cfg);
        assert_eq!(base.hier_bytes, 0, "degenerate config instantiates no levels");
        cfg.levels =
            crate::mem::hierarchy::parse_levels("sram:256KiB:line256,local:4KiB").unwrap();
        cfg.validate().unwrap();
        let b = OnChipBudget::from_config(&cfg);
        // per PE: 256 KiB (1024 lines of 256 B) + 4 KiB (64 lines of 64 B),
        // each line carrying the 8 B tag model
        let per_pe = (256 * 1024 + 1024 * 8) + (4 * 1024 + 64 * 8);
        assert_eq!(b.hier_bytes, 4 * per_pe);
        assert_eq!(b.total_bytes(), base.total_bytes() + 4 * per_pe);
        // the area/energy models price the stack through this one number
        let with = crate::area::model::AreaModel::new(&cfg)
            .design(&crate::mem::esram::esram())
            .onchip_mem_mm2;
        cfg.levels.clear();
        let without = crate::area::model::AreaModel::new(&cfg)
            .design(&crate::mem::esram::esram())
            .onchip_mem_mm2;
        assert!(with > without, "level capacity must cost area");
    }
}
