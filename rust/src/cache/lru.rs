//! Per-set LRU replacement state (the "LRU RAM" shared by the two cache
//! pipelines in Figs. 5–6).
//!
//! Implemented as per-way monotonic use-stamps: touch sets the way's stamp
//! to a counter, victim is the smallest stamp. For the associativities in
//! play (≤ 16) a linear scan beats any fancier structure and matches what
//! the hardware's per-set age matrix computes.

/// LRU state for one cache (all sets), `assoc` ways each.
#[derive(Clone, Debug)]
pub struct LruState {
    assoc: usize,
    /// stamps[set * assoc + way] = last-use counter (0 = never used).
    stamps: Vec<u64>,
    clock: u64,
}

impl LruState {
    pub fn new(sets: usize, assoc: usize) -> Self {
        assert!(assoc >= 1 && sets >= 1);
        LruState { assoc, stamps: vec![0; sets * assoc], clock: 0 }
    }

    /// Record a use of `way` in `set`.
    #[inline]
    pub fn touch(&mut self, set: usize, way: usize) {
        debug_assert!(way < self.assoc);
        self.clock += 1;
        self.stamps[set * self.assoc + way] = self.clock;
    }

    /// Least-recently-used way in `set` (never-used ways win first).
    #[inline]
    pub fn victim(&self, set: usize) -> usize {
        let base = set * self.assoc;
        let mut best = 0usize;
        let mut best_stamp = u64::MAX;
        for w in 0..self.assoc {
            let s = self.stamps[base + w];
            if s < best_stamp {
                best_stamp = s;
                best = w;
            }
        }
        best
    }

    /// Has this way ever been touched?
    #[inline]
    pub fn used(&self, set: usize, way: usize) -> bool {
        self.stamps[set * self.assoc + way] != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_prefers_untouched_ways() {
        let mut l = LruState::new(2, 4);
        l.touch(0, 0);
        l.touch(0, 1);
        // ways 2, 3 untouched; victim must be one of them (first found: 2)
        assert_eq!(l.victim(0), 2);
        // other set unaffected
        assert_eq!(l.victim(1), 0);
    }

    #[test]
    fn victim_is_least_recent_after_fill() {
        let mut l = LruState::new(1, 4);
        for w in 0..4 {
            l.touch(0, w);
        }
        assert_eq!(l.victim(0), 0);
        l.touch(0, 0); // refresh way 0 → way 1 now oldest
        assert_eq!(l.victim(0), 1);
        l.touch(0, 1);
        l.touch(0, 2);
        assert_eq!(l.victim(0), 3);
    }

    #[test]
    fn sets_are_independent() {
        let mut l = LruState::new(4, 2);
        l.touch(2, 1);
        assert!(l.used(2, 1));
        assert!(!l.used(2, 0));
        assert!(!l.used(3, 1));
        assert_eq!(l.victim(2), 0);
    }

    #[test]
    fn lru_order_is_exact_for_access_sequence() {
        // classic: access ways 0,1,2,3,0,1 → victims in order 2,3
        let mut l = LruState::new(1, 4);
        for w in [0, 1, 2, 3, 0, 1] {
            l.touch(0, w);
        }
        assert_eq!(l.victim(0), 2);
        l.touch(0, 2);
        assert_eq!(l.victim(0), 3);
    }
}
