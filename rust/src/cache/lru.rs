//! Per-set LRU replacement state (the "LRU RAM" shared by the two cache
//! pipelines in Figs. 5–6), plus the Mattson stack-distance profile that
//! prices *every* associativity of a set mapping from one stream walk.
//!
//! [`LruState`] is implemented as per-way monotonic use-stamps: touch sets
//! the way's stamp to a counter, victim is the smallest stamp. For the
//! associativities in play (≤ 16) a linear scan beats any fancier
//! structure and matches what the hardware's per-set age matrix computes.
//!
//! [`StackDistance`] exploits the LRU **inclusion property**: an `A`-way
//! set holds exactly the `A` most-recently-used distinct keys of that
//! set, so an access hits at associativity `A` iff its recency depth in
//! the set's full LRU stack is `< A`. One truncated per-set recency stack
//! therefore answers hit/miss/eviction counts for every `A ≤ cap` of the
//! same set count — the classic single-pass reuse-distance profile
//! ([`crate::sim::profile`] walks each kernel stream once and derives the
//! whole geometry sub-grid from it, bit-identical to direct simulation).

use crate::cache::cache::CacheStats;

/// LRU state for one cache (all sets), `assoc` ways each.
#[derive(Clone, Debug)]
pub struct LruState {
    assoc: usize,
    /// stamps[set * assoc + way] = last-use counter (0 = never used).
    stamps: Vec<u64>,
    clock: u64,
}

impl LruState {
    pub fn new(sets: usize, assoc: usize) -> Self {
        assert!(assoc >= 1 && sets >= 1);
        LruState { assoc, stamps: vec![0; sets * assoc], clock: 0 }
    }

    /// Record a use of `way` in `set`.
    #[inline]
    pub fn touch(&mut self, set: usize, way: usize) {
        debug_assert!(way < self.assoc);
        self.clock += 1;
        self.stamps[set * self.assoc + way] = self.clock;
    }

    /// Least-recently-used way in `set` (never-used ways win first).
    #[inline]
    pub fn victim(&self, set: usize) -> usize {
        let base = set * self.assoc;
        let mut best = 0usize;
        let mut best_stamp = u64::MAX;
        for w in 0..self.assoc {
            let s = self.stamps[base + w];
            if s < best_stamp {
                best_stamp = s;
                best = w;
            }
        }
        best
    }

    /// Has this way ever been touched?
    #[inline]
    pub fn used(&self, set: usize, way: usize) -> bool {
        self.stamps[set * self.assoc + way] != 0
    }
}

/// Keys never take this value ([`crate::cache::cache::SetAssocCache`]
/// holds the same reservation), so it can mark empty stack slots.
const INVALID: u64 = u64::MAX;

/// Per-set LRU stack-distance histogram over one access stream.
///
/// Holds, for a fixed power-of-two set count, one recency stack per set
/// truncated to `cap` entries plus a histogram of observed depths
/// (`cap` = "deeper than `cap` or never seen" — a miss at every
/// associativity the profile can answer). [`stats_at`][Self::stats_at]
/// then derives the exact [`CacheStats`] a
/// [`SetAssocCache`][crate::cache::cache::SetAssocCache] of any
/// associativity `A ≤ cap` would report over the same stream:
///
/// * `hits(A)   = Σ_set Σ_{d<A} hist[set][d]` (inclusion property),
/// * `misses(A) = accesses − hits(A)`,
/// * `evictions(A) = Σ_set max(0, misses_set − A)` — the first `A`
///   fills of a set land in never-touched ways
///   ([`LruState::victim`] prefers them), every later fill evicts,
/// * `writebacks = 0` — the factor-row streams are read-only, a line is
///   never dirtied (the controller's own invariant).
///
/// The caller owns the set mapping: pass the same set index the target
/// cache would compute (its masked [`mix_key`][crate::cache::cache::mix_key]
/// fold), so one profile per set count serves every associativity.
#[derive(Clone, Debug)]
pub struct StackDistance {
    sets: usize,
    cap: usize,
    /// keys[set * cap + i] = i-th most-recently-used key of `set`
    /// (front-packed; `INVALID` = empty slot).
    keys: Vec<u64>,
    /// hist[set * (cap + 1) + d] = accesses of `set` at recency depth
    /// `d`; bucket `cap` counts deeper-than-`cap` and compulsory
    /// (first-touch) accesses together — both miss at every `A ≤ cap`.
    hist: Vec<u64>,
}

impl StackDistance {
    /// Profile for `sets` LRU sets answering associativities `1..=cap`.
    pub fn new(sets: usize, cap: usize) -> Self {
        assert!(sets >= 1 && cap >= 1);
        StackDistance { sets, cap, keys: vec![INVALID; sets * cap], hist: vec![0; sets * (cap + 1)] }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    /// Largest associativity [`stats_at`][Self::stats_at] can answer.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Record one access of `key` in `set`; returns its recency depth
    /// (`cap` ⇒ deeper than the truncated stack or never seen).
    #[inline]
    pub fn access(&mut self, set: usize, key: u64) -> usize {
        debug_assert!(set < self.sets);
        debug_assert_ne!(key, INVALID);
        let base = set * self.cap;
        let stack = &mut self.keys[base..base + self.cap];
        let mut depth = self.cap;
        for (i, &k) in stack.iter().enumerate() {
            if k == key {
                depth = i;
                break;
            }
            if k == INVALID {
                // front-packed: nothing beyond the first empty slot
                break;
            }
        }
        // move-to-front (drop the last entry when the key was absent)
        let shift = depth.min(self.cap - 1);
        stack.copy_within(0..shift, 1);
        stack[0] = key;
        self.hist[set * (self.cap + 1) + depth] += 1;
        depth
    }

    /// Exact [`CacheStats`] of an `assoc`-way LRU cache with this set
    /// count over the profiled stream (`assoc ≤ cap`).
    pub fn stats_at(&self, assoc: usize) -> CacheStats {
        assert!(assoc >= 1 && assoc <= self.cap, "assoc {assoc} outside 1..={}", self.cap);
        let mut out = CacheStats::default();
        let width = self.cap + 1;
        for set in 0..self.sets {
            let h = &self.hist[set * width..(set + 1) * width];
            let hits: u64 = h[..assoc].iter().sum();
            let accesses: u64 = h.iter().sum();
            let misses = accesses - hits;
            out.hits += hits;
            out.misses += misses;
            out.evictions += misses.saturating_sub(assoc as u64);
        }
        out
    }

    /// Clear stacks and histograms (reuse across profile partitions —
    /// e.g. one PE's stream ends and the next starts cold).
    pub fn reset(&mut self) {
        self.keys.fill(INVALID);
        self.hist.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, FnGen};
    use crate::util::rng::Rng;

    #[test]
    fn victim_prefers_untouched_ways() {
        let mut l = LruState::new(2, 4);
        l.touch(0, 0);
        l.touch(0, 1);
        // ways 2, 3 untouched; victim must be one of them (first found: 2)
        assert_eq!(l.victim(0), 2);
        // other set unaffected
        assert_eq!(l.victim(1), 0);
    }

    #[test]
    fn victim_is_least_recent_after_fill() {
        let mut l = LruState::new(1, 4);
        for w in 0..4 {
            l.touch(0, w);
        }
        assert_eq!(l.victim(0), 0);
        l.touch(0, 0); // refresh way 0 → way 1 now oldest
        assert_eq!(l.victim(0), 1);
        l.touch(0, 1);
        l.touch(0, 2);
        assert_eq!(l.victim(0), 3);
    }

    #[test]
    fn sets_are_independent() {
        let mut l = LruState::new(4, 2);
        l.touch(2, 1);
        assert!(l.used(2, 1));
        assert!(!l.used(2, 0));
        assert!(!l.used(3, 1));
        assert_eq!(l.victim(2), 0);
    }

    #[test]
    fn lru_order_is_exact_for_access_sequence() {
        // classic: access ways 0,1,2,3,0,1 → victims in order 2,3
        let mut l = LruState::new(1, 4);
        for w in [0, 1, 2, 3, 0, 1] {
            l.touch(0, w);
        }
        assert_eq!(l.victim(0), 2);
        l.touch(0, 2);
        assert_eq!(l.victim(0), 3);
    }

    #[test]
    fn stack_distance_counts_textbook_depths() {
        // stream a b c a b c on one set: three compulsory misses, then
        // three depth-2 reuses — hits at A=3, misses at A≤2
        let mut sd = StackDistance::new(1, 4);
        for key in [1u64, 2, 3, 1, 2, 3] {
            sd.access(0, key);
        }
        let s3 = sd.stats_at(3);
        assert_eq!((s3.hits, s3.misses, s3.evictions), (3, 3, 0));
        let s2 = sd.stats_at(2);
        assert_eq!((s2.hits, s2.misses, s2.evictions), (0, 6, 4));
        let s4 = sd.stats_at(4);
        assert_eq!((s4.hits, s4.misses), (3, 3));
    }

    #[test]
    fn stack_distance_matches_direct_cache_on_random_streams() {
        // the inclusion property, checked mechanically: one profile per
        // set count must reproduce a directly simulated SetAssocCache's
        // hits / misses / evictions for every associativity it answers
        use crate::cache::cache::{mix_key, SetAssocCache};
        let cap = 8usize;
        let gen = FnGen(|rng: &mut Rng| {
            let n = 1_000 + rng.index(1_000);
            (0..n).map(|_| rng.below(400)).collect::<Vec<u64>>()
        });
        check("stack_distance_inclusion", 25, &gen, |stream| {
            for sets in [1usize, 4, 16, 64] {
                let mut sd = StackDistance::new(sets, cap);
                for &k in stream {
                    sd.access((mix_key(k) as usize) & (sets - 1), k);
                }
                for assoc in 1..=cap {
                    let mut c = SetAssocCache::new(sets, assoc);
                    for &k in stream {
                        c.access(k, false);
                    }
                    let derived = sd.stats_at(assoc);
                    if derived != c.stats {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn stack_distance_reset_restarts_cold() {
        let mut sd = StackDistance::new(2, 4);
        sd.access(0, 7);
        sd.access(0, 7);
        assert_eq!(sd.stats_at(4).hits, 1);
        sd.reset();
        assert_eq!(sd.stats_at(4), CacheStats::default());
        // after reset the first touch is compulsory again
        assert_eq!(sd.access(0, 7), sd.cap());
    }
}
