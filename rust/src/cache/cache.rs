//! Functional set-associative cache with LRU replacement (§IV-B).
//!
//! Simulated over the *actual* factor-row index stream of a tensor mode,
//! so hit rates are measured, not assumed — this is where workload
//! locality (the discriminating variable of Fig. 7) enters the model.
//!
//! Keys are abstract line addresses: for factor matrices, the row index
//! tagged with the matrix id (one R=16 row = one 64 B line, see
//! `AcceleratorConfig::row_bytes`). Set mapping uses the low bits of a
//! mixed key like the hardware's address slicing.

use crate::cache::lru::LruState;

/// Result of one cache access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Hit,
    /// Miss; `evicted_dirty` says whether a dirty line had to be written
    /// back to external memory first.
    Miss { evicted_dirty: bool },
}

/// Running statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }
    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }
}

/// A set-associative write-back cache (functional model).
#[derive(Clone, Debug)]
pub struct SetAssocCache {
    sets: usize,
    assoc: usize,
    /// tags[set * assoc + way]; `u64::MAX` = invalid.
    tags: Vec<u64>,
    dirty: Vec<bool>,
    lru: LruState,
    pub stats: CacheStats,
}

const INVALID: u64 = u64::MAX;

impl SetAssocCache {
    /// `sets` must be a power of two (hardware address slicing).
    pub fn new(sets: usize, assoc: usize) -> Self {
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(assoc >= 1);
        SetAssocCache {
            sets,
            assoc,
            tags: vec![INVALID; sets * assoc],
            dirty: vec![false; sets * assoc],
            lru: LruState::new(sets, assoc),
            stats: CacheStats::default(),
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }
    pub fn assoc(&self) -> usize {
        self.assoc
    }
    pub fn capacity_lines(&self) -> usize {
        self.sets * self.assoc
    }

    /// Hardware-style set index: low bits of the shared [`mix_key`]
    /// folding, masked to the power-of-two set count.
    #[inline]
    fn set_of(&self, key: u64) -> usize {
        (mix_key(key) as usize) & (self.sets - 1)
    }

    /// Access `key`; `write` marks the line dirty on hit or after fill.
    pub fn access(&mut self, key: u64, write: bool) -> Access {
        debug_assert_ne!(key, INVALID, "key space excludes u64::MAX");
        let set = self.set_of(key);
        let base = set * self.assoc;
        // tag compare across ways (Fig. 6 stage 2)
        for way in 0..self.assoc {
            if self.tags[base + way] == key {
                self.lru.touch(set, way);
                if write {
                    self.dirty[base + way] = true;
                }
                self.stats.hits += 1;
                return Access::Hit;
            }
        }
        // miss: pick LRU victim, fill (Fig. 5 MEM pipeline)
        self.stats.misses += 1;
        let way = self.lru.victim(set);
        let slot = base + way;
        let evicted_dirty = self.tags[slot] != INVALID && self.dirty[slot];
        if self.tags[slot] != INVALID {
            self.stats.evictions += 1;
            if evicted_dirty {
                self.stats.writebacks += 1;
            }
        }
        self.tags[slot] = key;
        self.dirty[slot] = write;
        self.lru.touch(set, way);
        Access::Miss { evicted_dirty }
    }

    /// Is `key` currently resident (no state change)?
    pub fn probe(&self, key: u64) -> bool {
        let set = self.set_of(key);
        let base = set * self.assoc;
        (0..self.assoc).any(|w| self.tags[base + w] == key)
    }

    /// Flush: count remaining dirty lines as writebacks and invalidate all.
    pub fn flush(&mut self) -> u64 {
        let mut wb = 0;
        for i in 0..self.tags.len() {
            if self.tags[i] != INVALID && self.dirty[i] {
                wb += 1;
            }
            self.tags[i] = INVALID;
            self.dirty[i] = false;
        }
        self.stats.writebacks += wb;
        wb
    }
}

/// Compose a cache key from a matrix id and row index (factor-row lines).
#[inline]
pub fn row_key(matrix: usize, row: u32) -> u64 {
    ((matrix as u64 + 1) << 40) | row as u64
}

/// Light key mixing shared by every address-interleaving decision in the
/// model: XOR-fold of the upper tag bits into the low bits (standard
/// hardware practice to decorrelate strided streams). The cache's set
/// index and the event engine's bank index both derive from this one
/// function, so the functional model and the contention replay — exact
/// or sampled — can never disagree on where a line lives.
#[inline]
pub fn mix_key(key: u64) -> u64 {
    key ^ (key >> 17)
}

/// Which of `banks` interleaved cache banks serves `key` — the event
/// engine's arbitration target ([`crate::sim::event`]). Same [`mix_key`]
/// folding as the set index; banks need not be a power of two, so the
/// fold is reduced by modulo rather than a mask.
#[inline]
pub fn bank_of(key: u64, banks: usize) -> usize {
    (mix_key(key) % banks as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, FnGen};
    use crate::util::rng::Rng;

    #[test]
    fn bank_and_set_share_one_key_mixing() {
        // the set index is the masked mix, the bank index the modular
        // mix — one mix_key, two reductions. If they ever diverged, the
        // sampled and exact replays could disagree on bank assignment.
        let c = SetAssocCache::new(64, 2);
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            let key = r.next_u64() >> 1; // stay clear of INVALID
            assert_eq!(c.set_of(key), (mix_key(key) as usize) & 63);
            assert_eq!(bank_of(key, 64), (mix_key(key) % 64) as usize);
            // power-of-two bank counts agree with the masked form too
            assert_eq!(bank_of(key, 16), (mix_key(key) as usize) & 15);
            assert!(bank_of(key, 7) < 7); // non-power-of-two supported
        }
    }

    #[test]
    fn hit_after_fill() {
        let mut c = SetAssocCache::new(16, 4);
        assert!(matches!(c.access(42, false), Access::Miss { .. }));
        assert_eq!(c.access(42, false), Access::Hit);
        assert!(c.probe(42));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn capacity_eviction_in_one_set() {
        let mut c = SetAssocCache::new(1, 2); // one set, 2 ways
        c.access(1, false);
        c.access(2, false);
        c.access(3, false); // evicts key 1 (LRU)
        assert!(!c.probe(1));
        assert!(c.probe(2) && c.probe(3));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = SetAssocCache::new(1, 1);
        c.access(1, true); // fill dirty
        match c.access(2, false) {
            Access::Miss { evicted_dirty } => assert!(evicted_dirty),
            _ => panic!("expected miss"),
        }
        assert_eq!(c.stats.writebacks, 1);
        // clean eviction does not write back
        match c.access(3, false) {
            Access::Miss { evicted_dirty } => assert!(!evicted_dirty),
            _ => panic!("expected miss"),
        }
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = SetAssocCache::new(1, 1);
        c.access(7, false);
        c.access(7, true); // dirty via write hit
        match c.access(8, false) {
            Access::Miss { evicted_dirty } => assert!(evicted_dirty),
            _ => panic!(),
        }
    }

    #[test]
    fn flush_writes_back_dirty_lines() {
        let mut c = SetAssocCache::new(4, 2);
        c.access(1, true);
        c.access(2, false);
        let wb = c.flush();
        assert_eq!(wb, 1);
        assert!(!c.probe(1) && !c.probe(2));
    }

    #[test]
    fn lru_order_within_set() {
        let mut c = SetAssocCache::new(1, 4);
        for k in 1..=4 {
            c.access(k, false);
        }
        c.access(1, false); // refresh 1 → LRU is 2
        c.access(5, false); // evict 2
        assert!(!c.probe(2));
        assert!(c.probe(1) && c.probe(3) && c.probe(4) && c.probe(5));
    }

    #[test]
    fn working_set_within_capacity_converges_to_all_hits() {
        let mut c = SetAssocCache::new(64, 4); // 256 lines
        let keys: Vec<u64> = (0..200).collect();
        // first pass: misses; second pass: all hits (LRU, no conflicts in
        // excess of associativity because keys are dense)
        for &k in &keys {
            c.access(k, false);
        }
        let h0 = c.stats.hits;
        for &k in &keys {
            assert_eq!(c.access(k, false), Access::Hit, "key {k}");
        }
        assert_eq!(c.stats.hits - h0, 200);
    }

    #[test]
    fn hit_rate_monotone_in_capacity_for_zipf_stream() {
        // bigger cache ⇒ hit rate can only improve for the same stream
        let mut rng = Rng::new(11);
        let z = crate::util::rng::Zipf::new(10_000, 1.0);
        let stream: Vec<u64> = (0..50_000).map(|_| z.sample(&mut rng) as u64).collect();
        let mut rates = Vec::new();
        for sets in [16usize, 64, 256, 1024] {
            let mut c = SetAssocCache::new(sets, 4);
            for &k in &stream {
                c.access(k, false);
            }
            rates.push(c.stats.hit_rate());
        }
        for w in rates.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "rates={rates:?}");
        }
        assert!(rates[3] > rates[0] + 0.05, "capacity must matter: {rates:?}");
    }

    #[test]
    fn row_keys_never_collide_across_matrices() {
        assert_ne!(row_key(0, 5), row_key(1, 5));
        assert_ne!(row_key(0, u32::MAX), row_key(1, 0));
    }

    #[test]
    fn prop_stats_conserve_and_probe_consistent() {
        let gen = FnGen(|rng: &mut Rng| {
            let n = 500 + rng.index(500);
            (0..n).map(|_| (rng.below(300), rng.f64() < 0.3)).collect::<Vec<(u64, bool)>>()
        });
        check("cache_conservation", 40, &gen, |ops| {
            let mut c = SetAssocCache::new(16, 2);
            for &(k, w) in ops {
                let r = c.access(k, w);
                // after any access the key must be resident
                if !c.probe(k) {
                    return false;
                }
                let _ = r;
            }
            c.stats.accesses() == ops.len() as u64
                && c.stats.writebacks <= c.stats.evictions + 32
        });
    }
}
