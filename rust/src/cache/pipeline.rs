//! Timing model of the cache's two pipelines (Figs. 5–6) and of generic
//! on-chip SRAM arrays (psum buffer, DMA buffers).
//!
//! The functional cache (`cache.rs`) answers *what* hits; this module
//! answers *how fast*: how many line requests per fabric cycle an array
//! built from a given [`MemTechnology`] can serve, and at what latency.
//!
//! ## Throughput
//!
//! One factor row / cache line is `line_bytes / 4` 32-bit words. A block
//! serves `lanes × f_mem / f_fabric` words per fabric cycle (Eq. 1). An
//! *electrical* data array additionally cascades `bank_factor` BRAMs to
//! widen the port (standard FPGA cache construction — this is a *design*
//! choice, so it is an [`AcceleratorConfig`](crate::accel::config::AcceleratorConfig)
//! knob, not a device constant). The optical array needs no cascading:
//! wavelength concurrency and the 40× clock already deliver 200 words per
//! fabric cycle (§III-A), which is the point of the paper.
//!
//! ## Latency
//!
//! The PE pipeline of Fig. 6 has 4 stages (tag access, tag compare, LRU
//! update / evaluation, data access), clocked in the memory domain, plus
//! the synchronizer crossing for asynchronous (optical) arrays. Both
//! pipelines are fully pipelined — latency is overlap-able, throughput is
//! the binding constraint, which is why the engine charges occupancy in
//! words and only exposes latency for reporting and for the dependent-
//! access (pointer-chase) penalty on slice boundaries.

use crate::mem::sync::SyncInterface;
use crate::mem::tech::MemTechnology;

/// Fig. 6 PE-pipeline depth in memory-core cycles.
pub const PE_PIPELINE_STAGES: u32 = 4;
/// Fig. 5 MEM-pipeline depth in memory-core cycles (tag probe, line fill
/// write, LRU update, response).
pub const MEM_PIPELINE_STAGES: u32 = 4;

/// Throughput/latency summary of one on-chip SRAM array instance.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayTiming {
    /// 32-bit words served per fabric cycle.
    pub words_per_fabric_cycle: f64,
    /// Pipelined access latency seen from the fabric, fabric cycles.
    pub latency_fabric_cycles: f64,
}

impl ArrayTiming {
    /// Build timing for an array of the given technology.
    ///
    /// `bank_factor` — port-widening cascade for electrical arrays; pass 1
    /// for optical arrays (see module docs).
    pub fn new(tech: &MemTechnology, fabric_hz: f64, bank_factor: usize) -> Self {
        assert!(bank_factor >= 1);
        let words = tech.words_per_fabric_cycle(fabric_hz) * bank_factor as f64;
        let sync = SyncInterface::new(tech, fabric_hz);
        let stages = PE_PIPELINE_STAGES as f64 * fabric_hz / tech.freq_hz;
        let latency = (stages + sync.crossing_fabric_cycles).max(1.0);
        ArrayTiming { words_per_fabric_cycle: words, latency_fabric_cycles: latency }
    }

    /// Fabric cycles of occupancy to transfer `words` 32-bit words.
    #[inline]
    pub fn occupancy_cycles(&self, words: f64) -> f64 {
        words / self.words_per_fabric_cycle
    }
}

/// Timing of one cache instance: the PE (hit) pipeline and MEM (fill)
/// pipeline share the tag/data/LRU arrays (Figs. 5–6), so both draw from
/// the same word budget; each additionally has its own issue limit of one
/// request per memory-core cycle.
#[derive(Clone, Debug)]
pub struct CacheTiming {
    /// Shared array bandwidth.
    pub array: ArrayTiming,
    /// Words per line (line_bytes / 4).
    pub words_per_line: usize,
    /// Max line *requests* issued per fabric cycle per pipeline
    /// (1 per memory-core cycle).
    pub issue_per_fabric_cycle: f64,
}

impl CacheTiming {
    pub fn new(
        tech: &MemTechnology,
        fabric_hz: f64,
        bank_factor: usize,
        line_bytes: usize,
    ) -> Self {
        let array = ArrayTiming::new(tech, fabric_hz, bank_factor);
        CacheTiming {
            array,
            words_per_line: line_bytes / 4,
            issue_per_fabric_cycle: (tech.freq_hz / fabric_hz).max(1.0),
        }
    }

    /// Fabric-cycle occupancy of one hit (tag + data read of one line),
    /// bounded by both the word bandwidth and the issue rate.
    pub fn hit_occupancy(&self) -> f64 {
        let bw = self.array.occupancy_cycles(self.words_per_line as f64);
        let issue = 1.0 / self.issue_per_fabric_cycle;
        bw.max(issue)
    }

    /// Fabric-cycle occupancy a miss adds on the MEM pipeline (line fill
    /// write + tag/LRU update; the DRAM time is charged to the channel).
    pub fn fill_occupancy(&self) -> f64 {
        self.hit_occupancy()
    }

    /// Hit latency (for reporting and dependent-access penalties).
    pub fn hit_latency(&self) -> f64 {
        self.array.latency_fabric_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;

    #[test]
    fn esram_array_words_with_banking() {
        let e = esram();
        let t = ArrayTiming::new(&e, FABRIC_HZ, 4);
        // dual port × 4 banks = 8 words per fabric cycle
        assert!((t.words_per_fabric_cycle - 8.0).abs() < 1e-12);
        // synchronous, 4 stages at fabric clock
        assert!((t.latency_fabric_cycles - 4.0).abs() < 1e-12);
    }

    #[test]
    fn osram_array_words_match_eq1() {
        let o = osram();
        let t = ArrayTiming::new(&o, FABRIC_HZ, 1);
        assert!((t.words_per_fabric_cycle - 200.0).abs() < 1e-9);
        // 4 stages at 20 GHz = 0.1 fabric cycles + 2 sync ⇒ 2.1
        assert!((t.latency_fabric_cycles - 2.1).abs() < 1e-9);
    }

    #[test]
    fn esram_cache_serves_half_line_per_cycle() {
        let e = esram();
        let c = CacheTiming::new(&e, FABRIC_HZ, 4, 64);
        // 16 words/line over 8 words/cycle ⇒ 2 cycles per request
        assert!((c.hit_occupancy() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn osram_cache_two_orders_faster() {
        let o = osram();
        let e = esram();
        let co = CacheTiming::new(&o, FABRIC_HZ, 1, 64);
        let ce = CacheTiming::new(&e, FABRIC_HZ, 4, 64);
        let ratio = ce.hit_occupancy() / co.hit_occupancy();
        assert!(ratio > 20.0, "O/E cache throughput ratio {ratio}");
        // issue rate (40/cycle) binds before word bandwidth for O-SRAM:
        // 16 words / 200 = 0.08 > 1/40 = 0.025 ⇒ bandwidth-bound at 0.08
        assert!((co.hit_occupancy() - 0.08).abs() < 1e-12);
    }

    #[test]
    fn occupancy_scales_linearly_in_words() {
        let o = osram();
        let t = ArrayTiming::new(&o, FABRIC_HZ, 1);
        assert!((t.occupancy_cycles(400.0) - 2.0 * t.occupancy_cycles(200.0)).abs() < 1e-12);
    }

    #[test]
    fn fill_occupancy_positive_and_latency_reported() {
        for m in [esram(), osram()] {
            let c = CacheTiming::new(&m, FABRIC_HZ, 2, 64);
            assert!(c.fill_occupancy() > 0.0);
            assert!(c.hit_latency() >= 1.0);
        }
    }
}
