//! The O-SRAM/E-SRAM cache subsystem (paper §IV-B, Figs. 5–6).
//!
//! Each PE's memory controller contains `n_caches` set-associative caches
//! shared among the input factor matrices. A cache is modeled at two
//! levels:
//!
//! * [`lru`] + [`cache`] — *functional*: a real set-associative LRU cache
//!   simulated over the actual factor-row index stream, producing exact
//!   hit/miss/eviction counts (the workload-dependent part of the model).
//! * [`pipeline`] — *timing*: the PE pipeline (Fig. 6: tag access → tag
//!   compare → LRU update → data access) and MEM pipeline (Fig. 5) as
//!   issue-rate/latency parameters derived from the plugged
//!   [`MemTechnology`](crate::mem::tech::MemTechnology).
//!
//! Both simulation engines drive the *same* functional cache, so hit
//! rates are engine-independent; they consume the timing differently:
//! the analytic engine ([`crate::sim::engine`]) charges aggregate
//! occupancy per access, while the event engine ([`crate::sim::event`])
//! arbitrates accesses across the array's
//! [`bank_factor`](crate::accel::config::AcceleratorConfig::bank_factor)
//! banks and measures the serialization that same-bank collisions add.

pub mod cache;
pub mod lru;
pub mod pipeline;
