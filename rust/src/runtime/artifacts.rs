//! The artifact manifest: `artifacts/manifest.txt` written by
//! `python/compile/aot.py`, one tab-separated line per lowered entry point:
//!
//! ```text
//! mttkrp3_b1024_r16\tmttkrp3_b1024_r16.hlo.txt\tin=f32[1024],s32[1024],f32[1024,16],f32[1024,16]\tout=f32[1024,16]
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Element dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }
}

/// Shape of one argument/output: dtype + dims.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ShapeSpec {
    /// Parse `f32[1024,16]`.
    pub fn parse(s: &str) -> Result<Self> {
        let (d, rest) =
            s.split_once('[').with_context(|| format!("bad shape `{s}`"))?;
        let dims_s = rest.strip_suffix(']').with_context(|| format!("bad shape `{s}`"))?;
        let dims = if dims_s.is_empty() {
            Vec::new()
        } else {
            dims_s
                .split(',')
                .map(|x| x.trim().parse::<usize>().with_context(|| format!("bad dim in `{s}`")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(ShapeSpec { dtype: DType::parse(d)?, dims })
    }

    pub fn n_elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<ShapeSpec>,
    pub output: ShapeSpec,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse manifest text; `dir` is the artifacts directory paths are
    /// resolved against.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 4 {
                bail!("manifest line {}: expected 4 tab-separated fields", ln + 1);
            }
            let name = fields[0].to_string();
            let path = dir.join(fields[1]);
            let ins = fields[2]
                .strip_prefix("in=")
                .with_context(|| format!("manifest line {}: missing in=", ln + 1))?;
            let outs = fields[3]
                .strip_prefix("out=")
                .with_context(|| format!("manifest line {}: missing out=", ln + 1))?;
            let inputs = split_shapes(ins)?
                .iter()
                .map(|s| ShapeSpec::parse(s))
                .collect::<Result<Vec<_>>>()?;
            let output = ShapeSpec::parse(outs)?;
            if artifacts
                .insert(name.clone(), ArtifactSpec { name: name.clone(), path, inputs, output })
                .is_some()
            {
                bail!("duplicate artifact `{name}`");
            }
        }
        Ok(Manifest { artifacts })
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Default artifacts directory: `$PHOTON_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PHOTON_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

/// Split `f32[1024],s32[1024],f32[1024,16]` at top-level commas (commas
/// inside brackets are dims).
fn split_shapes(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '[' => {
                depth += 1;
                cur.push(c);
            }
            ']' => {
                depth = depth.checked_sub(1).with_context(|| format!("unbalanced ] in `{s}`"))?;
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "mttkrp3\tmttkrp3.hlo.txt\tin=f32[1024],s32[1024],f32[1024,16],f32[1024,16]\tout=f32[1024,16]\ngram\tgram.hlo.txt\tin=f32[1024,16]\tout=f32[16,16]\n";

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("mttkrp3").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dtype, DType::S32);
        assert_eq!(a.inputs[2].dims, vec![1024, 16]);
        assert_eq!(a.output.n_elements(), 1024 * 16);
        assert_eq!(a.path, Path::new("/a/mttkrp3.hlo.txt"));
    }

    #[test]
    fn shape_parse_cases() {
        assert_eq!(
            ShapeSpec::parse("f32[3,16,16]").unwrap(),
            ShapeSpec { dtype: DType::F32, dims: vec![3, 16, 16] }
        );
        assert_eq!(ShapeSpec::parse("s32[]").unwrap().dims, Vec::<usize>::new());
        assert!(ShapeSpec::parse("f16[4]").is_err());
        assert!(ShapeSpec::parse("f32(4)").is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("too\tfew\tfields", Path::new(".")).is_err());
        assert!(Manifest::parse("a\tb\tnotin=x\tout=f32[1]", Path::new(".")).is_err());
        let dup = "a\ta.hlo\tin=f32[1]\tout=f32[1]\na\ta.hlo\tin=f32[1]\tout=f32[1]";
        assert!(Manifest::parse(dup, Path::new(".")).is_err());
    }

    #[test]
    fn missing_artifact_error_is_helpful() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let e = m.get("nope").unwrap_err().to_string();
        assert!(e.contains("nope") && e.contains("mttkrp3"));
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // integration check against the actual `make artifacts` output
        let dir = Manifest::default_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("mttkrp3_b1024_r16").is_ok());
            assert!(m.get("gram_t1024_r16").is_ok());
        }
    }
}
