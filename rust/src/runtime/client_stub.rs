//! Stub PJRT client, compiled when the `photon_pjrt` cfg is off.
//!
//! Mirrors the public surface of the real [`client`](super::client)
//! module so every caller (CLI `--artifacts` paths, benches, examples)
//! type-checks identically; constructing a [`Runtime`] fails with a clear
//! message instead. The numeric MTTKRP reference path
//! ([`crate::mttkrp::reference`]) is unaffected — only artifact execution
//! needs the real PJRT bindings.

use std::cell::RefCell;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::runtime::artifacts::{ArtifactSpec, Manifest};

/// A typed argument to an artifact call.
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    S32(&'a [i32]),
}

const UNAVAILABLE: &str = "PJRT runtime unavailable: photon-mttkrp was built without the \
     photon_pjrt backend (the XLA bindings are not vendored offline; add the `xla` dependency \
     and build with RUSTFLAGS=\"--cfg photon_pjrt\"); use the CPU reference path instead";

/// Stub runtime. [`Runtime::from_dir`] always fails; the struct exists so
/// the API (and the `Compute::Artifacts` plumbing) stays identical.
pub struct Runtime {
    manifest: Manifest,
    /// Execution counters (exposed for the perf benches).
    pub executions: RefCell<u64>,
}

impl Runtime {
    /// Always fails in the stub build (after validating that `dir` holds a
    /// readable manifest, so error precedence matches the real client).
    pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        bail!("{UNAVAILABLE}");
    }

    /// Load from the default artifacts directory (`$PHOTON_ARTIFACTS` or
    /// `./artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::from_dir(&Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Unreachable in practice (no stub `Runtime` can be constructed).
    pub fn warm(&self, _name: &str) -> Result<()> {
        bail!("{UNAVAILABLE}");
    }

    /// Unreachable in practice (no stub `Runtime` can be constructed).
    pub fn execute_f32(&self, _name: &str, _args: &[Arg<'_>]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }
}

/// Resolve an artifacts dir that works from the repo root and from
/// `cargo test` (which runs in the crate root too).
pub fn artifacts_dir() -> PathBuf {
    Manifest::default_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_with_a_clear_message() {
        // a manifest-less dir fails on the manifest first (same as the
        // real client), a present one on the missing feature
        let err = Runtime::from_dir(std::path::Path::new("/nonexistent-artifacts"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("manifest") || err.contains("read"), "{err}");
        // process-unique path so concurrent suites on one machine don't race
        let dir =
            std::env::temp_dir().join(format!("photon_stub_artifacts_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "").unwrap();
        let err = Runtime::from_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
