//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the rust hot path. Python never runs here.
//!
//! * [`artifacts`] — the manifest parser: names, files, argument/output
//!   shapes of every lowered entry point.
//! * [`client`] — the PJRT CPU client wrapper: compile-once executable
//!   cache and typed execute helpers. Requires the `xla` bindings crate,
//!   which cannot ship in the offline dependency graph, so the real
//!   client is gated behind the custom cfg `photon_pjrt` (add the `xla`
//!   dependency, then build with `RUSTFLAGS="--cfg photon_pjrt"`).
//!   Without it a stub with the same API is compiled that fails at
//!   `Runtime` construction with a clear message, so offline builds and
//!   tests stay green while every caller keeps type-checking against the
//!   real surface.

pub mod artifacts;

#[cfg(photon_pjrt)]
pub mod client;

#[cfg(not(photon_pjrt))]
#[path = "client_stub.rs"]
pub mod client;
