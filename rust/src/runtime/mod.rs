//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the rust hot path. Python never runs here.
//!
//! * [`artifacts`] — the manifest parser: names, files, argument/output
//!   shapes of every lowered entry point.
//! * [`client`] — the PJRT CPU client wrapper: compile-once executable
//!   cache and typed execute helpers.

pub mod artifacts;
pub mod client;
