//! The PJRT client wrapper: HLO-text → compiled executable cache → typed
//! execution (adapted from /opt/xla-example/load_hlo).
//!
//! One [`Runtime`] owns the PJRT CPU client and a lazily-populated cache
//! of compiled executables (one per artifact — compilation happens once,
//! execution is the steady-state path). Arguments are passed as typed
//! slices and validated against the manifest shapes before they reach the
//! PJRT boundary, so shape bugs fail with a named artifact and argument
//! index instead of an opaque XLA error.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::runtime::artifacts::{ArtifactSpec, DType, Manifest};

/// A typed argument to an artifact call.
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    S32(&'a [i32]),
}

impl Arg<'_> {
    fn len(&self) -> usize {
        match self {
            Arg::F32(x) => x.len(),
            Arg::S32(x) => x.len(),
        }
    }
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) => DType::F32,
            Arg::S32(_) => DType::S32,
        }
    }
}

/// PJRT CPU runtime with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Execution counters (exposed for the perf benches).
    pub executions: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest from `dir` and start a PJRT CPU client.
    pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            executions: RefCell::new(0),
        })
    }

    /// Load from the default artifacts directory (`$PHOTON_ARTIFACTS` or
    /// `./artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::from_dir(&Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .with_context(|| format!("parse HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compile artifact `{}`", spec.name))
    }

    /// Ensure `name` is compiled (warm the cache explicitly; `execute`
    /// does this lazily).
    pub fn warm(&self, name: &str) -> Result<()> {
        let spec = self.manifest.get(name)?.clone();
        if !self.executables.borrow().contains_key(name) {
            let exe = self.compile(&spec)?;
            self.executables.borrow_mut().insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Execute artifact `name` with `args`; returns the flattened f32
    /// output (all our artifacts produce a single f32 array).
    pub fn execute_f32(&self, name: &str, args: &[Arg<'_>]) -> Result<Vec<f32>> {
        let spec = self.manifest.get(name)?.clone();
        self.validate(&spec, args)?;
        self.warm(name)?;
        let literals: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(args)
            .map(|(shape, arg)| {
                let dims: Vec<i64> = shape.dims.iter().map(|&d| d as i64).collect();
                let lit = match arg {
                    Arg::F32(x) => xla::Literal::vec1(x),
                    Arg::S32(x) => xla::Literal::vec1(x),
                };
                lit.reshape(&dims).map_err(anyhow::Error::from)
            })
            .collect::<Result<_>>()?;
        let exes = self.executables.borrow();
        let exe = exes.get(name).expect("warmed above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute `{name}`"))?[0][0]
            .to_literal_sync()?;
        *self.executions.borrow_mut() += 1;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    fn validate(&self, spec: &ArtifactSpec, args: &[Arg<'_>]) -> Result<()> {
        if spec.inputs.len() != args.len() {
            bail!("artifact `{}` takes {} args, got {}", spec.name, spec.inputs.len(), args.len());
        }
        for (i, (shape, arg)) in spec.inputs.iter().zip(args).enumerate() {
            if shape.dtype != arg.dtype() {
                bail!(
                    "artifact `{}` arg {i}: dtype mismatch ({:?} expected)",
                    spec.name,
                    shape.dtype
                );
            }
            if shape.n_elements() != arg.len() {
                bail!(
                    "artifact `{}` arg {i}: {} elements given, shape {:?} needs {}",
                    spec.name,
                    arg.len(),
                    shape.dims,
                    shape.n_elements()
                );
            }
        }
        Ok(())
    }
}

/// Resolve an artifacts dir that works from the repo root and from
/// `cargo test` (which runs in the crate root too).
pub fn artifacts_dir() -> PathBuf {
    Manifest::default_dir()
}

#[cfg(test)]
mod tests {
    //! These tests need built artifacts (`make artifacts`); they skip
    //! cleanly when the directory is absent so `cargo test` stays green in
    //! a fresh checkout.
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if dir.join("manifest.txt").exists() {
            Some(Runtime::from_dir(&dir).expect("runtime"))
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn mttkrp3_artifact_matches_cpu_math() {
        let Some(rt) = runtime() else { return };
        let b = 1024usize;
        let r = 16usize;
        let vals: Vec<f32> = (0..b).map(|i| (i % 7) as f32 * 0.25).collect();
        let segs: Vec<i32> = (0..b).map(|i| (i as i32) % 33).collect();
        let f1: Vec<f32> = (0..b * r).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
        let f2: Vec<f32> = (0..b * r).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let out = rt
            .execute_f32(
                "mttkrp3_b1024_r16",
                &[Arg::F32(&vals), Arg::S32(&segs), Arg::F32(&f1), Arg::F32(&f2)],
            )
            .unwrap();
        assert_eq!(out.len(), b * r);
        // CPU oracle
        let mut want = vec![0.0f32; b * r];
        for i in 0..b {
            let s = segs[i] as usize;
            for j in 0..r {
                want[s * r + j] += vals[i] * f1[i * r + j] * f2[i * r + j];
            }
        }
        for (i, (&g, &w)) in out.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn gram_artifact_matches_cpu_math() {
        let Some(rt) = runtime() else { return };
        let (t, r) = (1024usize, 16usize);
        let f: Vec<f32> = (0..t * r).map(|i| ((i % 17) as f32 - 8.0) * 0.05).collect();
        let out = rt.execute_f32("gram_t1024_r16", &[Arg::F32(&f)]).unwrap();
        assert_eq!(out.len(), r * r);
        let mut want = vec![0.0f32; r * r];
        for row in 0..t {
            for a in 0..r {
                for b_ in 0..r {
                    want[a * r + b_] += f[row * r + a] * f[row * r + b_];
                }
            }
        }
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-2 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn executable_cache_compiles_once() {
        let Some(rt) = runtime() else { return };
        let r = 16usize;
        let rows = vec![1.0f32; 1024 * r];
        let eye: Vec<f32> =
            (0..r * r).map(|i| if i % (r + 1) == 0 { 1.0 } else { 0.0 }).collect();
        for _ in 0..3 {
            let out = rt
                .execute_f32("factor_update_b1024_r16", &[Arg::F32(&rows), Arg::F32(&eye)])
                .unwrap();
            assert!((out[0] - 1.0).abs() < 1e-6);
        }
        assert_eq!(*rt.executions.borrow(), 3);
        assert_eq!(rt.executables.borrow().len(), 1);
    }

    #[test]
    fn shape_validation_rejects_bad_args() {
        let Some(rt) = runtime() else { return };
        let short = vec![1.0f32; 10];
        let e = rt.execute_f32("gram_t1024_r16", &[Arg::F32(&short)]).unwrap_err().to_string();
        assert!(e.contains("elements"), "{e}");
        let ints = vec![0i32; 1024 * 16];
        let e = rt.execute_f32("gram_t1024_r16", &[Arg::S32(&ints)]).unwrap_err().to_string();
        assert!(e.contains("dtype"), "{e}");
        let e = rt.execute_f32("gram_t1024_r16", &[]).unwrap_err().to_string();
        assert!(e.contains("takes"), "{e}");
    }
}
