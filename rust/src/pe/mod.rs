//! Processing element (Fig. 4): execution unit with parallel rank
//! pipelines and the partial-sum buffer.

pub mod exec;
