//! Execution unit timing (Fig. 4, Table I "Parallel Pipelines").
//!
//! A PE's execution unit contains `n_pipelines` identical pipelines, each
//! executing the Algorithm 1 inner loop: for a nonzero x at (i₀, …),
//! `A(i₀, r) += x × B(i₁, r) × C(i₂, r) × …` for r = 1..R. One pipeline
//! retires one rank-element FMA chain per cycle, so a nonzero of an N-mode
//! tensor costs `R × (N−1)` pipeline-cycles of multiply plus the final
//! accumulate (fused). Partial sums live in the technology-dependent
//! partial-sum buffer: each nonzero reads and writes the R-element row
//! segment (2R word-ops), and each completed output slice drains R words.

use crate::cache::pipeline::ArrayTiming;

/// Timing model of one PE's execution unit + psum buffer.
#[derive(Clone, Debug)]
pub struct ExecUnit {
    pub n_pipelines: usize,
    pub rank: usize,
    /// Partial-sum buffer array timing (per PE; the buffer is banked per
    /// pipeline by construction — Table I sizes it per pipeline — so the
    /// array bandwidth scales with the pipeline count for both techs; the
    /// *per-bank* width is what the technology changes).
    pub psum: ArrayTiming,
    /// Banks the psum buffer exposes (= pipelines, by construction).
    pub psum_banks: usize,
}

/// Per-nonzero / per-slice charges the engine accumulates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecCharge {
    /// Pipeline occupancy in fabric cycles.
    pub pipeline_cycles: f64,
    /// Psum-buffer occupancy in fabric cycles.
    pub psum_cycles: f64,
    /// Psum words touched (for `S_active` energy accounting).
    pub psum_words: u64,
}

impl ExecUnit {
    pub fn new(n_pipelines: usize, rank: usize, psum: ArrayTiming, psum_banks: usize) -> Self {
        assert!(n_pipelines > 0 && rank > 0 && psum_banks > 0);
        ExecUnit { n_pipelines, rank, psum, psum_banks }
    }

    /// Aggregate psum bandwidth: banks × per-bank words/cycle. Public so
    /// kernels with non-MTTKRP psum footprints (e.g. the TTM chain's
    /// `R^(N−1)`-wide rows) price against the same formula — one owner.
    pub fn psum_words_per_cycle(&self) -> f64 {
        self.psum.words_per_fabric_cycle * self.psum_banks as f64
    }

    /// Charge for processing one nonzero of an `n_modes`-way tensor.
    pub fn nonzero(&self, n_modes: usize) -> ExecCharge {
        debug_assert!(n_modes >= 2);
        let r = self.rank as f64;
        let mults = r * (n_modes as f64 - 1.0);
        let psum_words = 2 * self.rank as u64; // read R + write R
        ExecCharge {
            pipeline_cycles: mults / self.n_pipelines as f64,
            psum_cycles: psum_words as f64 / self.psum_words_per_cycle(),
            psum_words,
        }
    }

    /// Charge for draining one completed output slice (R words leave the
    /// psum buffer toward the store path).
    pub fn drain_slice(&self) -> ExecCharge {
        let words = self.rank as u64;
        ExecCharge {
            pipeline_cycles: 0.0,
            psum_cycles: words as f64 / self.psum_words_per_cycle(),
            psum_words: words,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;
    use crate::mem::tech::{MemTechnology, FABRIC_HZ};

    fn unit(tech: &MemTechnology, banks_per_array: usize) -> ExecUnit {
        let t = ArrayTiming::new(tech, FABRIC_HZ, banks_per_array);
        ExecUnit::new(80, 16, t, 8)
    }

    #[test]
    fn pipeline_cost_matches_alg1_op_count() {
        let u = unit(&esram(), 1);
        // 3-mode: R(N−1) = 32 mults over 80 pipelines = 0.4 cyc/nnz
        let c = u.nonzero(3);
        assert!((c.pipeline_cycles - 0.4).abs() < 1e-12);
        // 5-mode: 64/80
        assert!((u.nonzero(5).pipeline_cycles - 0.8).abs() < 1e-12);
    }

    #[test]
    fn psum_charge_reads_and_writes_rank_words() {
        let u = unit(&esram(), 1);
        let c = u.nonzero(3);
        assert_eq!(c.psum_words, 32);
        // 32 words over (2 words/cyc × 8 banks) = 2 cyc
        assert!((c.psum_cycles - 2.0).abs() < 1e-12);
        let o = unit(&osram(), 1);
        // O-SRAM: 32 / (200 × 8) = 0.02
        assert!((o.nonzero(3).psum_cycles - 0.02).abs() < 1e-12);
    }

    #[test]
    fn drain_charges_rank_words() {
        let u = unit(&osram(), 1);
        let d = u.drain_slice();
        assert_eq!(d.psum_words, 16);
        assert_eq!(d.pipeline_cycles, 0.0);
        assert!(d.psum_cycles > 0.0);
    }

    #[test]
    fn compute_cost_is_technology_independent() {
        let e = unit(&esram(), 1);
        let o = unit(&osram(), 1);
        assert_eq!(e.nonzero(3).pipeline_cycles, o.nonzero(3).pipeline_cycles);
    }
}
