//! Table IV: layout area of the two systems.
//!
//! | system  | on-chip memory      | PEs        | total                |
//! |---------|---------------------|------------|----------------------|
//! | E-SRAM  | 43.2 mm²            | 202.2 mm²  | 247.2 mm² (paper)    |
//! | O-SRAM  | 103.7 × 10⁴ mm²     | 202.2 mm²  | 103.7 × 10⁴ mm²      |
//!
//! Note the paper's E-SRAM "Total" (247.2) differs from the sum of its own
//! components (43.2 + 202.2 = 245.4) by ~0.7% — presumably interface glue
//! counted only in the total. We report the component sum and carry the
//! paper's printed value as `PAPER_ESRAM_TOTAL_MM2` for comparison output.

use crate::accel::config::AcceleratorConfig;
use crate::accel::design::OnChipBudget;
use crate::mem::registry;
use crate::mem::tech::MemTechnology;

/// PE-array area at 12 nm (Table IV, identical for both systems — the
/// compute mesh is CMOS either way). This is the area of the Table I
/// array of [`PE_AREA_COUNT`] PEs; per-PE pricing divides by it.
pub const PE_AREA_MM2: f64 = 202.2;
/// PE count the Table IV [`PE_AREA_MM2`] figure corresponds to.
pub const PE_AREA_COUNT: usize = 4;
/// Single-reticle limit, mm² (~26 × 33 mm) — the §II wafer-scale
/// feasibility line.
pub const RETICLE_MM2: f64 = 858.0;
/// The paper's printed E-SRAM total (see module docs on the 0.7% gap).
pub const PAPER_ESRAM_TOTAL_MM2: f64 = 247.2;
/// The paper's printed O-SRAM on-chip-memory and total area.
pub const PAPER_OSRAM_MEM_MM2: f64 = 103.7e4;

/// Area breakdown of one system instance, mm².
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaBreakdown {
    pub onchip_mem_mm2: f64,
    pub pe_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.onchip_mem_mm2 + self.pe_mm2
    }
}

/// The Table IV model: full-platform on-chip memory (54 MB) in the given
/// technology + the fixed PE array.
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub cfg: AcceleratorConfig,
}

impl AreaModel {
    pub fn new(cfg: &AcceleratorConfig) -> Self {
        AreaModel { cfg: cfg.clone() }
    }

    /// Area of the platform with its full on-chip memory in `tech`
    /// (Table IV replaces *all* 54 MB, not just the bytes the design
    /// uses). Any registry-resolved technology prices through the same
    /// per-bit model.
    pub fn platform(&self, tech: &MemTechnology) -> AreaBreakdown {
        let bits = self.cfg.onchip_bytes * 8;
        AreaBreakdown { onchip_mem_mm2: tech.area_mm2(bits), pe_mm2: PE_AREA_MM2 }
    }

    /// Resolve `name` through the global registry and price the platform.
    pub fn platform_by_name(&self, name: &str) -> Result<AreaBreakdown, String> {
        Ok(self.platform(&registry::resolve(name)?))
    }

    /// Area of the **instantiated design**, not the whole 54 MB platform:
    /// the on-chip bits [`OnChipBudget`] counts (caches + tags + psum +
    /// DMA buffers, which scale with the PE count and the cache/rank
    /// knobs) priced per-bit in `tech`, plus the PE array scaled to the
    /// config's PE count from the Table IV [`PE_AREA_MM2`] /
    /// [`PE_AREA_COUNT`] figure. This is the area objective (and the
    /// `--budget-mm2` constraint) of the explore subsystem — unlike
    /// [`Self::platform`], it responds to every design knob a search
    /// sweeps.
    pub fn design(&self, tech: &MemTechnology) -> AreaBreakdown {
        let bits = OnChipBudget::from_config(&self.cfg).total_bits();
        AreaBreakdown {
            onchip_mem_mm2: tech.area_mm2(bits),
            pe_mm2: PE_AREA_MM2 * self.cfg.n_pes as f64 / PE_AREA_COUNT as f64,
        }
    }

    /// `tech` : `base` total-area ratio (e.g. the wafer-scale penalty of
    /// §V-D with the o-sram/e-sram pair).
    pub fn penalty_over(&self, tech: &MemTechnology, base: &MemTechnology) -> f64 {
        self.platform(tech).total_mm2() / self.platform(base).total_mm2()
    }

    /// O-SRAM : E-SRAM total-area ratio — the wafer-scale penalty of §V-D.
    pub fn area_penalty(&self) -> f64 {
        self.penalty_over(&registry::tech("o-sram"), &registry::tech("e-sram"))
    }

    /// Does the O-SRAM system exceed a single reticle ([`RETICLE_MM2`])?
    /// It must — that is the wafer-scale argument of §II.
    pub fn requires_wafer_scale(&self) -> bool {
        self.platform(&registry::tech("o-sram")).total_mm2() > RETICLE_MM2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;

    fn model() -> AreaModel {
        AreaModel::new(&AcceleratorConfig::paper_default())
    }

    #[test]
    fn esram_row_matches_table_iv() {
        let a = model().platform(&tech("e-sram"));
        assert!((a.onchip_mem_mm2 - 43.2).abs() < 1e-6, "{}", a.onchip_mem_mm2);
        assert_eq!(a.pe_mm2, 202.2);
        // component sum; paper prints 247.2 (see module docs)
        assert!((a.total_mm2() - 245.4).abs() < 1e-6);
        assert!((a.total_mm2() - PAPER_ESRAM_TOTAL_MM2).abs() / PAPER_ESRAM_TOTAL_MM2 < 0.01);
    }

    #[test]
    fn osram_row_matches_table_iv() {
        let a = model().platform(&tech("o-sram"));
        assert!((a.onchip_mem_mm2 - 103.7e4).abs() / 103.7e4 < 1e-9);
        // memory dwarfs PEs: total ≈ memory (paper prints the same number)
        assert!((a.total_mm2() - 103.7e4).abs() / 103.7e4 < 1e-3);
    }

    #[test]
    fn wafer_scale_is_required() {
        let m = model();
        assert!(m.requires_wafer_scale());
        let penalty = m.area_penalty();
        assert!(penalty > 1e3, "area penalty {penalty} should be >3 orders");
    }

    #[test]
    fn design_area_responds_to_the_explore_knobs() {
        let base = model();
        let d_e = base.design(&tech("e-sram"));
        let d_o = base.design(&tech("o-sram"));
        // the design instantiates a few MB, far below the 54 MB platform
        assert!(d_e.onchip_mem_mm2 < base.platform(&tech("e-sram")).onchip_mem_mm2);
        assert!(d_o.onchip_mem_mm2 < base.platform(&tech("o-sram")).onchip_mem_mm2);
        // a Table-I e-sram design fits a reticle; the o-sram one cannot
        assert!(d_e.total_mm2() < RETICLE_MM2);
        assert!(d_o.total_mm2() > RETICLE_MM2);
        // PE area scales with the PE count, memory with the cache knobs
        let mut cfg = AcceleratorConfig::paper_default();
        cfg.n_pes = 8;
        let d8 = AreaModel::new(&cfg).design(&tech("e-sram"));
        assert!((d8.pe_mm2 - 2.0 * PE_AREA_MM2).abs() < 1e-9);
        assert!(d8.onchip_mem_mm2 > d_e.onchip_mem_mm2);
        let mut cfg = AcceleratorConfig::paper_default();
        cfg.cache_lines = 8192;
        let big = AreaModel::new(&cfg).design(&tech("e-sram"));
        assert_eq!(big.pe_mm2, d_e.pe_mm2);
        assert!(big.onchip_mem_mm2 > d_e.onchip_mem_mm2);
    }

    #[test]
    fn area_scales_with_capacity() {
        let mut cfg = AcceleratorConfig::paper_default();
        cfg.onchip_bytes /= 2;
        let m = AreaModel::new(&cfg);
        let full = model().platform(&tech("o-sram")).onchip_mem_mm2;
        let half = m.platform(&tech("o-sram")).onchip_mem_mm2;
        assert!((half - full / 2.0).abs() / full < 1e-9);
    }
}
