//! Area model (paper §V-D, Table IV).

pub mod model;
