//! Public drive-everything entry points (the prelude API).
//!
//! * [`simulate_mode`] / [`simulate_all_modes`] — the performance model
//!   (timing, traffic, energy counters) for one tensor on one memory
//!   technology, with the paper's locality-enhancing remapping applied
//!   first (§IV-A "determine a mapping of X into memory for each mode").
//! * [`compare_technologies`] — the Fig. 7 / Fig. 8 primitive: run both
//!   technologies and report per-mode speedup + run energy savings.
//! * [`compute_mode`] — the numeric path: real MTTKRP values through the
//!   AOT artifacts (or the scalar reference when artifacts are absent).

use crate::accel::config::AcceleratorConfig;
use crate::energy::model::{EnergyBreakdown, EnergyModel};
use crate::mem::tech::MemTech;
use crate::mttkrp::block::mttkrp_via_artifacts;
use crate::mttkrp::reference::{mttkrp, FactorMatrix};
use crate::runtime::client::Runtime;
use crate::sim::engine;
use crate::sim::result::{ModeReport, SimReport};
use crate::tensor::coo::SparseTensor;
use crate::tensor::remap;

/// Apply the §IV-A memory mapping (degree-descending remap on every mode)
/// and return the remapped tensor. Factor matrices must be permuted with
/// [`remap::permute_rows`] when numerics are carried alongside.
pub fn apply_memory_mapping(tensor: &SparseTensor) -> SparseTensor {
    let remaps = remap::degree_remaps(tensor);
    let mut t = tensor.clone();
    remap::apply(&mut t, &remaps);
    t
}

/// Simulate one output mode (with the memory mapping applied).
pub fn simulate_mode(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: MemTech,
) -> ModeReport {
    let t = apply_memory_mapping(tensor);
    engine::simulate_mode(&t, mode, cfg, tech)
}

/// Simulate all modes (the full spMTTKRP of Fig. 7's x-axis).
pub fn simulate_all_modes(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: MemTech,
) -> SimReport {
    let t = apply_memory_mapping(tensor);
    engine::simulate_all_modes(&t, cfg, tech)
}

/// Both technologies on one tensor: per-mode speedups + energy savings.
#[derive(Clone, Debug)]
pub struct TechComparison {
    pub tensor: String,
    pub esram: SimReport,
    pub osram: SimReport,
    pub esram_energy: EnergyBreakdown,
    pub osram_energy: EnergyBreakdown,
}

impl TechComparison {
    /// Fig. 7 series: speedup per mode.
    pub fn mode_speedups(&self) -> Vec<f64> {
        self.esram
            .modes
            .iter()
            .zip(&self.osram.modes)
            .map(|(e, o)| e.runtime_cycles() / o.runtime_cycles())
            .collect()
    }

    /// Total-execution-time speedup.
    pub fn total_speedup(&self) -> f64 {
        self.esram.total_runtime_cycles() / self.osram.total_runtime_cycles()
    }

    /// Fig. 8 metric: E-SRAM run energy / O-SRAM run energy.
    pub fn energy_savings(&self) -> f64 {
        self.esram_energy.total_j() / self.osram_energy.total_j()
    }
}

/// Run the full E-vs-O comparison for one tensor (the Fig. 7/8 primitive).
pub fn compare_technologies(tensor: &SparseTensor, cfg: &AcceleratorConfig) -> TechComparison {
    let t = apply_memory_mapping(tensor);
    let esram = engine::simulate_all_modes(&t, cfg, MemTech::ESram);
    let osram = engine::simulate_all_modes(&t, cfg, MemTech::OSram);
    let em = EnergyModel::new(cfg);
    TechComparison {
        tensor: tensor.name.clone(),
        esram_energy: em.run_energy(&esram),
        osram_energy: em.run_energy(&osram),
        esram,
        osram,
    }
}

/// How the numeric MTTKRP is computed.
pub enum Compute<'rt> {
    /// Scalar CPU reference (always available).
    Reference,
    /// Through the AOT artifacts on the PJRT runtime.
    Artifacts(&'rt Runtime),
}

/// Numeric spMTTKRP for one mode.
pub fn compute_mode(
    compute: &Compute<'_>,
    tensor: &SparseTensor,
    mode: usize,
    factors: &[FactorMatrix],
) -> anyhow::Result<FactorMatrix> {
    match compute {
        Compute::Reference => Ok(mttkrp(tensor, mode, factors)),
        Compute::Artifacts(rt) => mttkrp_via_artifacts(rt, tensor, mode, factors),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen::{self, TensorSpec};

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    #[test]
    fn memory_mapping_preserves_structure() {
        let t = TensorSpec::custom("t", vec![50, 60, 70], 2000, 0.8).generate(1);
        let m = apply_memory_mapping(&t);
        m.validate().unwrap();
        assert_eq!(m.nnz(), t.nnz());
        assert_eq!(m.dims, t.dims);
        // multiset of values unchanged
        let mut a = t.values.clone();
        let mut b = m.values.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    fn remap_never_hurts_hit_rate_much() {
        // degree remap should help (or at least not wreck) cache behaviour
        let t = TensorSpec::custom("z", vec![4000, 4000, 4000], 50_000, 1.0).generate(3);
        let cfg = cfg();
        let plain = engine::simulate_mode(&t, 0, &cfg, MemTech::OSram);
        let mapped = simulate_mode(&t, 0, &cfg, MemTech::OSram);
        assert!(mapped.hit_rate() >= plain.hit_rate() - 0.02);
    }

    #[test]
    fn comparison_has_consistent_shape() {
        let t = TensorSpec::custom("c", vec![100, 100, 100], 20_000, 0.9).generate(2);
        let c = compare_technologies(&t, &cfg());
        assert_eq!(c.mode_speedups().len(), 3);
        for s in c.mode_speedups() {
            assert!(s >= 0.99, "speedup {s} below 1");
        }
        assert!(c.total_speedup() >= 1.0);
        assert!(c.energy_savings() > 1.0);
    }

    #[test]
    fn compute_reference_path_works() {
        let t = gen::random(&[10, 12, 14], 500, 4);
        let f: Vec<FactorMatrix> = t
            .dims
            .iter()
            .map(|&d| FactorMatrix::random(d as usize, 16, 7))
            .collect();
        let out = compute_mode(&Compute::Reference, &t, 1, &f).unwrap();
        assert_eq!(out.rows, 12);
    }
}
