//! Public drive-everything entry points (the prelude API).
//!
//! * [`simulate_mode`] / [`simulate_all_modes`] — the performance model
//!   (timing, traffic, energy counters) for one tensor on one memory
//!   technology, with the paper's locality-enhancing remapping applied
//!   first (§IV-A "determine a mapping of X into memory for each mode").
//! * [`compare_technologies`] — the N-way generalization of the Fig. 7 /
//!   Fig. 8 primitive: run any list of registry-resolved technologies on
//!   one tensor and report per-mode speedups + run-energy ratios against
//!   the first (baseline) entry.
//! * [`compare_paper_pair`] — the paper's exact E-SRAM vs O-SRAM pair.
//! * [`compute_mode`] — the numeric path: real MTTKRP values through the
//!   AOT artifacts (or the scalar reference when artifacts are absent).

use crate::accel::config::AcceleratorConfig;
use crate::energy::model::{EnergyBreakdown, EnergyModel};
use crate::mem::registry;
use crate::mem::tech::MemTechnology;
use crate::mttkrp::block::mttkrp_via_artifacts;
use crate::mttkrp::reference::{mttkrp, FactorMatrix};
use crate::runtime::client::Runtime;
use crate::sim::engine;
use crate::sim::result::{ModeReport, SimReport};
use crate::tensor::coo::SparseTensor;
use crate::tensor::remap;

/// Apply the §IV-A memory mapping (degree-descending remap on every mode)
/// and return the remapped tensor. Factor matrices must be permuted with
/// [`remap::permute_rows`] when numerics are carried alongside.
pub fn apply_memory_mapping(tensor: &SparseTensor) -> SparseTensor {
    let remaps = remap::degree_remaps(tensor);
    let mut t = tensor.clone();
    remap::apply(&mut t, &remaps);
    t
}

/// Simulate one output mode (with the memory mapping applied).
pub fn simulate_mode(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    let t = apply_memory_mapping(tensor);
    engine::simulate_mode(&t, mode, cfg, tech)
}

/// Simulate all modes (the full spMTTKRP of Fig. 7's x-axis).
pub fn simulate_all_modes(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    let t = apply_memory_mapping(tensor);
    engine::simulate_all_modes(&t, cfg, tech)
}

/// One technology's full-run result inside a [`TechComparison`].
#[derive(Clone, Debug)]
pub struct TechRun {
    pub report: SimReport,
    pub energy: EnergyBreakdown,
}

impl TechRun {
    /// The registry name of the technology this run used.
    pub fn name(&self) -> &str {
        &self.report.tech.name
    }
}

/// N technologies on one tensor: per-mode speedups + energy ratios, all
/// relative to the first (baseline) run.
#[derive(Clone, Debug)]
pub struct TechComparison {
    pub tensor: String,
    /// One run per requested technology; `runs[0]` is the baseline.
    pub runs: Vec<TechRun>,
}

impl TechComparison {
    /// The baseline run (the first technology passed in).
    pub fn baseline(&self) -> &TechRun {
        &self.runs[0]
    }

    /// The run for a technology name, if it was part of the comparison.
    pub fn run(&self, name: &str) -> Option<&TechRun> {
        self.runs.iter().find(|r| r.name() == name)
    }

    /// The run for `name`, panicking with the available names otherwise.
    pub fn require(&self, name: &str) -> &TechRun {
        self.run(name).unwrap_or_else(|| {
            panic!("technology `{name}` not in comparison (have: {:?})", self.names())
        })
    }

    /// Technology names in run order (baseline first).
    pub fn names(&self) -> Vec<&str> {
        self.runs.iter().map(|r| r.name()).collect()
    }

    /// Fig. 7 series for one technology: per-mode speedup over the
    /// baseline (`baseline runtime / tech runtime`).
    pub fn mode_speedups(&self, name: &str) -> Vec<f64> {
        let run = self.require(name);
        self.baseline()
            .report
            .modes
            .iter()
            .zip(&run.report.modes)
            .map(|(b, t)| b.runtime_cycles() / t.runtime_cycles())
            .collect()
    }

    /// Total-execution-time speedup of `name` over the baseline.
    pub fn total_speedup(&self, name: &str) -> f64 {
        self.baseline().report.total_runtime_cycles()
            / self.require(name).report.total_runtime_cycles()
    }

    /// Fig. 8 metric for one technology: baseline run energy / tech run
    /// energy (above 1.0 ⇒ `name` saves energy).
    pub fn energy_savings(&self, name: &str) -> f64 {
        self.baseline().energy.total_j() / self.require(name).energy.total_j()
    }
}

/// Run every technology in `techs` on one tensor (the memory mapping and
/// tensor preparation are shared across runs). `techs[0]` is the baseline
/// the speedup/energy accessors compare against.
pub fn compare_technologies(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
) -> TechComparison {
    assert!(!techs.is_empty(), "compare_technologies needs at least one technology");
    // the accessors are name-keyed (find-first), so a duplicate name would
    // shadow its twin's numbers silently — reject it up front, like the
    // sweep engine does
    let mut seen: Vec<&str> = Vec::new();
    for t in techs {
        assert!(!seen.contains(&t.name.as_str()), "technology `{}` listed twice", t.name);
        seen.push(&t.name);
    }
    let t = apply_memory_mapping(tensor);
    let em = EnergyModel::new(cfg);
    let runs = techs
        .iter()
        .map(|tech| {
            let report = engine::simulate_all_modes(&t, cfg, tech);
            let energy = em.run_energy(&report);
            TechRun { report, energy }
        })
        .collect();
    TechComparison { tensor: tensor.name.clone(), runs }
}

/// The paper's Fig. 7 / Fig. 8 primitive: E-SRAM baseline vs O-SRAM.
pub fn compare_paper_pair(tensor: &SparseTensor, cfg: &AcceleratorConfig) -> TechComparison {
    compare_technologies(
        tensor,
        cfg,
        &[registry::tech("e-sram"), registry::tech("o-sram")],
    )
}

/// Every technology in the global registry on one tensor, baseline =
/// first registered entry (`e-sram`).
pub fn compare_all_registered(tensor: &SparseTensor, cfg: &AcceleratorConfig) -> TechComparison {
    compare_technologies(tensor, cfg, &registry::all())
}

/// How the numeric MTTKRP is computed.
pub enum Compute<'rt> {
    /// Scalar CPU reference (always available).
    Reference,
    /// Through the AOT artifacts on the PJRT runtime.
    Artifacts(&'rt Runtime),
}

/// Numeric spMTTKRP for one mode.
pub fn compute_mode(
    compute: &Compute<'_>,
    tensor: &SparseTensor,
    mode: usize,
    factors: &[FactorMatrix],
) -> anyhow::Result<FactorMatrix> {
    match compute {
        Compute::Reference => Ok(mttkrp(tensor, mode, factors)),
        Compute::Artifacts(rt) => mttkrp_via_artifacts(rt, tensor, mode, factors),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::tensor::gen::{self, TensorSpec};

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    #[test]
    fn memory_mapping_preserves_structure() {
        let t = TensorSpec::custom("t", vec![50, 60, 70], 2000, 0.8).generate(1);
        let m = apply_memory_mapping(&t);
        m.validate().unwrap();
        assert_eq!(m.nnz(), t.nnz());
        assert_eq!(m.dims, t.dims);
        // multiset of values unchanged
        let mut a = t.values.clone();
        let mut b = m.values.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    fn remap_never_hurts_hit_rate_much() {
        // degree remap should help (or at least not wreck) cache behaviour
        let t = TensorSpec::custom("z", vec![4000, 4000, 4000], 50_000, 1.0).generate(3);
        let cfg = cfg();
        let plain = engine::simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let mapped = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        assert!(mapped.hit_rate() >= plain.hit_rate() - 0.02);
    }

    #[test]
    fn paper_pair_comparison_has_consistent_shape() {
        let t = TensorSpec::custom("c", vec![100, 100, 100], 20_000, 0.9).generate(2);
        let c = compare_paper_pair(&t, &cfg());
        assert_eq!(c.names(), vec!["e-sram", "o-sram"]);
        assert_eq!(c.mode_speedups("o-sram").len(), 3);
        for s in c.mode_speedups("o-sram") {
            assert!(s >= 0.99, "speedup {s} below 1");
        }
        assert!(c.total_speedup("o-sram") >= 1.0);
        assert!(c.energy_savings("o-sram") > 1.0);
        // the baseline compared against itself is exactly 1.0
        assert_eq!(c.total_speedup("e-sram"), 1.0);
        assert_eq!(c.energy_savings("e-sram"), 1.0);
    }

    #[test]
    fn n_way_comparison_covers_every_requested_tech() {
        let t = TensorSpec::custom("n", vec![80, 80, 80], 10_000, 1.0).generate(4);
        let techs =
            [tech("e-sram"), tech("e-uram"), tech("o-sram"), tech("o-sram-imc")];
        let c = compare_technologies(&t, &cfg(), &techs);
        assert_eq!(c.runs.len(), 4);
        assert_eq!(c.names(), vec!["e-sram", "e-uram", "o-sram", "o-sram-imc"]);
        // both optical points must beat the electrical baseline
        assert!(c.total_speedup("o-sram") >= 1.0);
        assert!(c.total_speedup("o-sram-imc") >= 1.0);
        // the wider-comb IMC array can never be slower than the base O-SRAM
        assert!(
            c.total_speedup("o-sram-imc") >= c.total_speedup("o-sram") * 0.999,
            "imc {} vs o-sram {}",
            c.total_speedup("o-sram-imc"),
            c.total_speedup("o-sram")
        );
        // unknown name panics with the available list
        let err = std::panic::catch_unwind(|| c.total_speedup("t-sram"));
        assert!(err.is_err());
    }

    #[test]
    fn compare_all_registered_spans_the_registry() {
        let t = TensorSpec::custom("r", vec![60, 60, 60], 5_000, 1.0).generate(9);
        let c = compare_all_registered(&t, &cfg());
        assert!(c.runs.len() >= 4);
        assert_eq!(c.baseline().name(), "e-sram");
    }

    #[test]
    fn compute_reference_path_works() {
        let t = gen::random(&[10, 12, 14], 500, 4);
        let f: Vec<FactorMatrix> = t
            .dims
            .iter()
            .map(|&d| FactorMatrix::random(d as usize, 16, 7))
            .collect();
        let out = compute_mode(&Compute::Reference, &t, 1, &f).unwrap();
        assert_eq!(out.rows, 12);
    }
}
