//! Public drive-everything entry points (the prelude API).
//!
//! * [`simulate_mode`] / [`simulate_all_modes`] — the performance model
//!   (timing, traffic, energy counters) for one tensor on one memory
//!   technology, with the paper's locality-enhancing remapping applied
//!   first (§IV-A "determine a mapping of X into memory for each mode").
//!   The `_with_engine` variants select the simulation backend
//!   ([`EngineKind`]: analytic roofline or event-driven contention); the
//!   `_with_kernel` variants additionally select the workload
//!   ([`KernelKind`]: spMTTKRP, Tucker TTM-chain, SpMM).
//! * [`compare_technologies`] — the N-way generalization of the Fig. 7 /
//!   Fig. 8 primitive: run any list of registry-resolved technologies on
//!   one tensor and report per-mode speedups + run-energy ratios against
//!   the first (baseline) entry, for any kernel on either engine.
//! * [`compare_paper_pair`] — the paper's exact E-SRAM vs O-SRAM pair.
//! * [`cross_validate`] — run both engines on one tensor per technology
//!   and report the analytic-vs-event runtime delta (the roofline model's
//!   error bound on that workload).
//! * [`compute_mode`] — the numeric path: real MTTKRP values through the
//!   AOT artifacts (or the scalar reference when artifacts are absent).

use crate::accel::config::AcceleratorConfig;
use crate::energy::model::{EnergyBreakdown, EnergyModel};
use crate::kernel::KernelKind;
use crate::mem::registry;
use crate::mem::tech::MemTechnology;
use crate::mttkrp::block::mttkrp_via_artifacts;
use crate::mttkrp::reference::{mttkrp, FactorMatrix};
use crate::runtime::client::Runtime;
use crate::sim::result::{ModeReport, SimReport};
use crate::sim::{EngineKind, SimBudget};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;
use crate::tensor::remap;

/// Apply the §IV-A memory mapping (degree-descending remap on every mode)
/// and return the remapped tensor. Factor matrices must be permuted with
/// [`remap::permute_rows`] when numerics are carried alongside.
pub fn apply_memory_mapping(tensor: &SparseTensor) -> SparseTensor {
    let remaps = remap::degree_remaps(tensor);
    let mut t = tensor.clone();
    remap::apply(&mut t, &remaps);
    t
}

/// Simulate one output mode (with the memory mapping applied) on the
/// analytic engine, spMTTKRP kernel.
pub fn simulate_mode(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> ModeReport {
    simulate_mode_with_kernel(tensor, mode, cfg, tech, EngineKind::Analytic, KernelKind::Spmttkrp)
}

/// [`simulate_mode`] on an explicitly selected simulation backend.
pub fn simulate_mode_with_engine(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    engine: EngineKind,
) -> ModeReport {
    simulate_mode_with_kernel(tensor, mode, cfg, tech, engine, KernelKind::Spmttkrp)
}

/// [`simulate_mode`] on an explicitly selected backend *and* kernel.
pub fn simulate_mode_with_kernel(
    tensor: &SparseTensor,
    mode: usize,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    engine: EngineKind,
    kernel: KernelKind,
) -> ModeReport {
    let t = apply_memory_mapping(tensor);
    engine.simulate_kernel_mode(kernel.kernel(), &t, mode, cfg, tech)
}

/// Simulate all modes (the full spMTTKRP of Fig. 7's x-axis) on the
/// analytic engine.
pub fn simulate_all_modes(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
) -> SimReport {
    simulate_all_modes_with_kernel(tensor, cfg, tech, EngineKind::Analytic, KernelKind::Spmttkrp)
}

/// [`simulate_all_modes`] on an explicitly selected simulation backend.
pub fn simulate_all_modes_with_engine(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    engine: EngineKind,
) -> SimReport {
    simulate_all_modes_with_kernel(tensor, cfg, tech, engine, KernelKind::Spmttkrp)
}

/// [`simulate_all_modes`] on an explicitly selected backend *and* kernel.
pub fn simulate_all_modes_with_kernel(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    engine: EngineKind,
    kernel: KernelKind,
) -> SimReport {
    let t = apply_memory_mapping(tensor);
    engine.simulate_kernel_all_modes(kernel.kernel(), &t, cfg, tech)
}

/// One technology's full-run result inside a [`TechComparison`].
#[derive(Clone, Debug)]
pub struct TechRun {
    pub report: SimReport,
    pub energy: EnergyBreakdown,
}

impl TechRun {
    /// The registry name of the technology this run used.
    pub fn name(&self) -> &str {
        &self.report.tech.name
    }
}

/// N technologies on one tensor: per-mode speedups + energy ratios, all
/// relative to the first (baseline) run.
#[derive(Clone, Debug)]
pub struct TechComparison {
    pub tensor: String,
    /// One run per requested technology; `runs[0]` is the baseline.
    pub runs: Vec<TechRun>,
}

impl TechComparison {
    /// The baseline run (the first technology passed in).
    pub fn baseline(&self) -> &TechRun {
        &self.runs[0]
    }

    /// The run for a technology name, if it was part of the comparison.
    pub fn run(&self, name: &str) -> Option<&TechRun> {
        self.runs.iter().find(|r| r.name() == name)
    }

    /// The run for `name`, panicking with the available names otherwise.
    pub fn require(&self, name: &str) -> &TechRun {
        self.run(name).unwrap_or_else(|| {
            panic!("technology `{name}` not in comparison (have: {:?})", self.names())
        })
    }

    /// Technology names in run order (baseline first).
    pub fn names(&self) -> Vec<&str> {
        self.runs.iter().map(|r| r.name()).collect()
    }

    /// Fig. 7 series for one technology: per-mode speedup over the
    /// baseline (`baseline runtime / tech runtime`).
    pub fn mode_speedups(&self, name: &str) -> Vec<f64> {
        let run = self.require(name);
        self.baseline()
            .report
            .modes
            .iter()
            .zip(&run.report.modes)
            .map(|(b, t)| b.runtime_cycles() / t.runtime_cycles())
            .collect()
    }

    /// Total-execution-time speedup of `name` over the baseline.
    pub fn total_speedup(&self, name: &str) -> f64 {
        self.baseline().report.total_runtime_cycles()
            / self.require(name).report.total_runtime_cycles()
    }

    /// Fig. 8 metric for one technology: baseline run energy / tech run
    /// energy (above 1.0 ⇒ `name` saves energy).
    pub fn energy_savings(&self, name: &str) -> f64 {
        self.baseline().energy.total_j() / self.require(name).energy.total_j()
    }
}

/// Run every technology in `techs` on one tensor (the memory mapping and
/// tensor preparation are shared across runs). `techs[0]` is the baseline
/// the speedup/energy accessors compare against.
pub fn compare_technologies(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
) -> TechComparison {
    compare_technologies_with_kernel(tensor, cfg, techs, EngineKind::Analytic, KernelKind::Spmttkrp)
}

/// [`compare_technologies`] on an explicitly selected backend (every run
/// in one comparison uses the same engine, so speedups compare like with
/// like).
pub fn compare_technologies_with_engine(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
    engine: EngineKind,
) -> TechComparison {
    compare_technologies_with_kernel(tensor, cfg, techs, engine, KernelKind::Spmttkrp)
}

/// [`compare_technologies`] on an explicitly selected backend *and*
/// kernel (engine- and kernel-uniform across every run, so the ratios
/// always compare like with like).
pub fn compare_technologies_with_kernel(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
    engine: EngineKind,
    kernel: KernelKind,
) -> TechComparison {
    compare_technologies_with_budget(tensor, cfg, techs, engine, kernel, SimBudget::default())
}

/// [`compare_technologies_with_kernel`] under an explicit host-execution
/// [`SimBudget`].
pub fn compare_technologies_with_budget(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
    engine: EngineKind,
    kernel: KernelKind,
    budget: SimBudget,
) -> TechComparison {
    let mut cs = compare_technologies_on_engines(tensor, cfg, techs, &[engine], kernel, budget);
    cs.pop().expect("one comparison per requested engine")
}

/// One fully prepared workload: the (optionally §IV-A remapped) tensor
/// plus its memoized per-mode [`ModeView`]s. This is the expensive
/// O(nnz) part of every simulation — preparing it once and fanning many
/// (technology × engine × request) runs across it is the amortization
/// trick [`compare_technologies_on_engines`] uses within one call and
/// the serving layer ([`crate::serve`]) uses across a whole batch
/// window of requests.
pub struct PreparedWorkload {
    /// The tensor the engines see (already remapped when `remap`).
    pub tensor: SparseTensor,
    /// `(mode, view)` for every output mode, built exactly once.
    pub views: Vec<(usize, ModeView)>,
    /// Whether the §IV-A mapping was applied (part of workload identity).
    pub remap: bool,
}

impl PreparedWorkload {
    /// Remap (when asked) and build every per-mode view.
    pub fn new(tensor: &SparseTensor, remap: bool) -> Self {
        let t = if remap { apply_memory_mapping(tensor) } else { tensor.clone() };
        let views = (0..t.n_modes()).map(|m| (m, ModeView::build(&t, m))).collect();
        PreparedWorkload { tensor: t, views, remap }
    }
}

/// The fully-knobbed comparison primitive every `compare_*` front-end
/// reduces to: run every technology in `techs` on **each** listed
/// engine, returning one [`TechComparison`] per engine in order. The
/// §IV-A memory mapping is applied once and the O(nnz) per-mode
/// [`ModeView`] builds are **memoized** through a [`PreparedWorkload`]:
/// each (tensor, mode) view is built exactly once and shared across
/// every technology × engine run, instead of being rebuilt
/// `|techs| × |engines| × |modes|` times (the CLI's `--engine event`
/// delta printing passes `[Event, Analytic]` here, so the analytic
/// bound reuses the event pass's workload preparation).
pub fn compare_technologies_on_engines(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
    engines: &[EngineKind],
    kernel: KernelKind,
    budget: SimBudget,
) -> Vec<TechComparison> {
    assert!(!techs.is_empty(), "compare_technologies needs at least one technology");
    assert!(!engines.is_empty(), "compare_technologies needs at least one engine");
    // the accessors are name-keyed (find-first), so a duplicate name would
    // shadow its twin's numbers silently — reject it up front, like the
    // sweep engine does
    let mut seen: Vec<&str> = Vec::new();
    for t in techs {
        assert!(!seen.contains(&t.name.as_str()), "technology `{}` listed twice", t.name);
        seen.push(&t.name);
    }
    let w = PreparedWorkload::new(tensor, true);
    let em = EnergyModel::new(cfg);
    let k = kernel.kernel();
    engines
        .iter()
        .map(|engine| {
            let runs = techs
                .iter()
                .map(|tech| {
                    let report = engine.simulate_kernel_all_modes_with_views_budget(
                        k, &w.tensor, &w.views, cfg, tech, budget,
                    );
                    let energy = em.run_energy(&report);
                    TechRun { report, energy }
                })
                .collect();
            TechComparison { tensor: tensor.name.clone(), runs }
        })
        .collect()
}

/// One technology's analytic-vs-event cross-validation result.
#[derive(Clone, Debug)]
pub struct EngineDelta {
    pub tech: String,
    /// Full-run cycles priced by the analytic roofline engine.
    pub analytic_cycles: f64,
    /// Full-run cycles measured by the event-driven replay (≥ analytic).
    pub event_cycles: f64,
}

impl EngineDelta {
    /// `event / analytic` (1.0 = perfect agreement, always ≥ 1.0).
    pub fn ratio(&self) -> f64 {
        self.event_cycles / self.analytic_cycles
    }

    /// Contention the roofline model hides, as a percentage of its own
    /// estimate (`(ratio − 1) × 100`).
    pub fn delta_pct(&self) -> f64 {
        (self.ratio() - 1.0) * 100.0
    }
}

/// Run **both** engines on one tensor for every technology in `techs` and
/// return the per-technology runtime deltas — the analytic model's
/// measured error bound on this workload (spMTTKRP). The §IV-A memory
/// mapping, the tensor preparation and the O(nnz) per-mode view builds
/// are all shared across every (technology × engine) run, like the sweep
/// engine does.
pub fn cross_validate(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
) -> Vec<EngineDelta> {
    cross_validate_kernel(tensor, cfg, techs, KernelKind::Spmttkrp)
}

/// [`cross_validate`] for an explicitly selected kernel.
pub fn cross_validate_kernel(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    techs: &[MemTechnology],
    kernel: KernelKind,
) -> Vec<EngineDelta> {
    let t = apply_memory_mapping(tensor);
    let views: Vec<(usize, ModeView)> =
        (0..t.n_modes()).map(|m| (m, ModeView::build(&t, m))).collect();
    techs
        .iter()
        .map(|tech| {
            let total = |kind: EngineKind| -> f64 {
                views
                    .iter()
                    .map(|(m, v)| {
                        kind.simulate_kernel_mode_with_view(kernel.kernel(), &t, v, *m, cfg, tech)
                            .runtime_cycles()
                    })
                    .sum()
            };
            EngineDelta {
                tech: tech.name.clone(),
                analytic_cycles: total(EngineKind::Analytic),
                event_cycles: total(EngineKind::Event),
            }
        })
        .collect()
}

/// The paper's exact E-SRAM-baseline vs O-SRAM technology pair — the
/// single owner of that pair definition for every front-end.
pub fn paper_pair() -> [MemTechnology; 2] {
    [registry::tech("e-sram"), registry::tech("o-sram")]
}

/// The paper's Fig. 7 / Fig. 8 primitive: E-SRAM baseline vs O-SRAM.
pub fn compare_paper_pair(tensor: &SparseTensor, cfg: &AcceleratorConfig) -> TechComparison {
    compare_paper_pair_with_engine(tensor, cfg, EngineKind::Analytic)
}

/// [`compare_paper_pair`] on an explicitly selected backend.
pub fn compare_paper_pair_with_engine(
    tensor: &SparseTensor,
    cfg: &AcceleratorConfig,
    engine: EngineKind,
) -> TechComparison {
    compare_technologies_with_engine(tensor, cfg, &paper_pair(), engine)
}

/// Every technology in the global registry on one tensor, baseline =
/// first registered entry (`e-sram`).
pub fn compare_all_registered(tensor: &SparseTensor, cfg: &AcceleratorConfig) -> TechComparison {
    compare_technologies(tensor, cfg, &registry::all())
}

/// How the numeric MTTKRP is computed.
pub enum Compute<'rt> {
    /// Scalar CPU reference (always available).
    Reference,
    /// Through the AOT artifacts on the PJRT runtime.
    Artifacts(&'rt Runtime),
}

/// Numeric spMTTKRP for one mode.
pub fn compute_mode(
    compute: &Compute<'_>,
    tensor: &SparseTensor,
    mode: usize,
    factors: &[FactorMatrix],
) -> anyhow::Result<FactorMatrix> {
    match compute {
        Compute::Reference => Ok(mttkrp(tensor, mode, factors)),
        Compute::Artifacts(rt) => mttkrp_via_artifacts(rt, tensor, mode, factors),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::sim::engine;
    use crate::tensor::gen::{self, TensorSpec};

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    #[test]
    fn memory_mapping_preserves_structure() {
        let t = TensorSpec::custom("t", vec![50, 60, 70], 2000, 0.8).generate(1);
        let m = apply_memory_mapping(&t);
        m.validate().unwrap();
        assert_eq!(m.nnz(), t.nnz());
        assert_eq!(m.dims, t.dims);
        // multiset of values unchanged
        let mut a = t.values.clone();
        let mut b = m.values.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    fn remap_never_hurts_hit_rate_much() {
        // degree remap should help (or at least not wreck) cache behaviour
        let t = TensorSpec::custom("z", vec![4000, 4000, 4000], 50_000, 1.0).generate(3);
        let cfg = cfg();
        let plain = engine::simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let mapped = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        assert!(mapped.hit_rate() >= plain.hit_rate() - 0.02);
    }

    #[test]
    fn paper_pair_comparison_has_consistent_shape() {
        let t = TensorSpec::custom("c", vec![100, 100, 100], 20_000, 0.9).generate(2);
        let c = compare_paper_pair(&t, &cfg());
        assert_eq!(c.names(), vec!["e-sram", "o-sram"]);
        assert_eq!(c.mode_speedups("o-sram").len(), 3);
        for s in c.mode_speedups("o-sram") {
            assert!(s >= 0.99, "speedup {s} below 1");
        }
        assert!(c.total_speedup("o-sram") >= 1.0);
        assert!(c.energy_savings("o-sram") > 1.0);
        // the baseline compared against itself is exactly 1.0
        assert_eq!(c.total_speedup("e-sram"), 1.0);
        assert_eq!(c.energy_savings("e-sram"), 1.0);
    }

    #[test]
    fn n_way_comparison_covers_every_requested_tech() {
        let t = TensorSpec::custom("n", vec![80, 80, 80], 10_000, 1.0).generate(4);
        let techs =
            [tech("e-sram"), tech("e-uram"), tech("o-sram"), tech("o-sram-imc")];
        let c = compare_technologies(&t, &cfg(), &techs);
        assert_eq!(c.runs.len(), 4);
        assert_eq!(c.names(), vec!["e-sram", "e-uram", "o-sram", "o-sram-imc"]);
        // both optical points must beat the electrical baseline
        assert!(c.total_speedup("o-sram") >= 1.0);
        assert!(c.total_speedup("o-sram-imc") >= 1.0);
        // the wider-comb IMC array can never be slower than the base O-SRAM
        assert!(
            c.total_speedup("o-sram-imc") >= c.total_speedup("o-sram") * 0.999,
            "imc {} vs o-sram {}",
            c.total_speedup("o-sram-imc"),
            c.total_speedup("o-sram")
        );
        // unknown name panics with the available list
        let err = std::panic::catch_unwind(|| c.total_speedup("t-sram"));
        assert!(err.is_err());
    }

    #[test]
    fn compare_all_registered_spans_the_registry() {
        let t = TensorSpec::custom("r", vec![60, 60, 60], 5_000, 1.0).generate(9);
        let c = compare_all_registered(&t, &cfg());
        assert!(c.runs.len() >= 4);
        assert_eq!(c.baseline().name(), "e-sram");
    }

    #[test]
    fn engine_variants_agree_with_the_defaults() {
        let t = TensorSpec::custom("v", vec![80, 80, 80], 8_000, 0.9).generate(6);
        let cfg = cfg();
        let a = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let a2 = simulate_mode_with_engine(&t, 0, &cfg, &tech("o-sram"), EngineKind::Analytic);
        assert_eq!(a.runtime_cycles().to_bits(), a2.runtime_cycles().to_bits());
        let e = simulate_mode_with_engine(&t, 0, &cfg, &tech("o-sram"), EngineKind::Event);
        assert!(e.runtime_cycles() >= a.runtime_cycles());
        // comparisons carry the engine through every run
        let techs = [tech("e-sram"), tech("o-sram")];
        let ce = compare_technologies_with_engine(&t, &cfg, &techs, EngineKind::Event);
        assert_eq!(ce.names(), vec!["e-sram", "o-sram"]);
        assert!(ce.total_speedup("o-sram") > 0.0);
    }

    #[test]
    fn kernel_variants_flow_through_the_driver() {
        let t = TensorSpec::custom("k", vec![90, 90, 90], 7_000, 0.8).generate(12);
        let cfg = cfg();
        // explicit spmttkrp == the default path, bit for bit
        let a = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let b = simulate_mode_with_kernel(
            &t, 0, &cfg, &tech("o-sram"), EngineKind::Analytic, KernelKind::Spmttkrp,
        );
        assert_eq!(a.runtime_cycles().to_bits(), b.runtime_cycles().to_bits());
        // the other kernels run end to end and label their reports
        for kernel in [KernelKind::Spttm, KernelKind::Spmm] {
            let r = simulate_all_modes_with_kernel(
                &t, &cfg, &tech("o-sram"), EngineKind::Analytic, kernel,
            );
            assert_eq!(r.kernel, kernel.name());
            assert_eq!(r.modes.len(), 3);
            let c = compare_technologies_with_kernel(
                &t, &cfg, &paper_pair(), EngineKind::Analytic, kernel,
            );
            assert_eq!(c.names(), vec!["e-sram", "o-sram"]);
            assert!(c.total_speedup("o-sram") > 0.0, "{kernel}");
        }
        // cross-validation holds per kernel too
        for kernel in KernelKind::ALL {
            for d in cross_validate_kernel(&t, &cfg, &paper_pair(), kernel) {
                assert!(d.ratio() >= 1.0 - 1e-12, "{kernel} on {}: {}", d.tech, d.ratio());
            }
        }
    }

    #[test]
    fn budget_comparison_matches_the_default_path() {
        // the memoized-view + budget primitive must reproduce the
        // classic per-run path bit for bit, at any thread budget
        let t = TensorSpec::custom("b", vec![70, 70, 70], 6_000, 0.7).generate(15);
        let cfg = cfg();
        let base = compare_technologies(&t, &cfg, &paper_pair());
        for budget in [SimBudget::single_threaded(), SimBudget::with_threads(3)] {
            let c = compare_technologies_with_budget(
                &t,
                &cfg,
                &paper_pair(),
                EngineKind::Analytic,
                KernelKind::Spmttkrp,
                budget,
            );
            assert_eq!(base.names(), c.names());
            for (a, b) in base.runs.iter().zip(&c.runs) {
                assert_eq!(
                    a.report.total_runtime_cycles().to_bits(),
                    b.report.total_runtime_cycles().to_bits(),
                    "{budget:?}"
                );
                assert_eq!(a.energy.total_j().to_bits(), b.energy.total_j().to_bits());
            }
        }
    }

    #[test]
    fn multi_engine_comparison_shares_one_workload() {
        // one memoized workload, N engines: per-engine results must match
        // the single-engine paths bit for bit, and the event comparison
        // may never beat its analytic twin
        let t = TensorSpec::custom("me", vec![80, 80, 80], 6_000, 0.6).generate(21);
        let cfg = cfg();
        let budget = SimBudget::single_threaded();
        let cs = compare_technologies_on_engines(
            &t,
            &cfg,
            &paper_pair(),
            &[EngineKind::Event, EngineKind::Analytic],
            KernelKind::Spmttkrp,
            budget,
        );
        assert_eq!(cs.len(), 2);
        let single = compare_technologies_with_budget(
            &t,
            &cfg,
            &paper_pair(),
            EngineKind::Analytic,
            KernelKind::Spmttkrp,
            budget,
        );
        for (a, b) in cs[1].runs.iter().zip(&single.runs) {
            let (x, y) = (a.report.total_runtime_cycles(), b.report.total_runtime_cycles());
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (ev, an) in cs[0].runs.iter().zip(&cs[1].runs) {
            assert!(
                ev.report.total_runtime_cycles() >= an.report.total_runtime_cycles(),
                "{}",
                ev.name()
            );
        }
    }

    #[test]
    fn cross_validation_bounds_the_analytic_model() {
        let t = TensorSpec::custom("x", vec![400, 400, 400], 10_000, 0.5).generate(8);
        let deltas = cross_validate(&t, &cfg(), &[tech("e-sram"), tech("o-sram")]);
        assert_eq!(deltas.len(), 2);
        for d in &deltas {
            assert!(d.ratio() >= 1.0, "{}: event may not beat analytic ({})", d.tech, d.ratio());
            assert!(d.delta_pct() >= 0.0);
            assert!(d.analytic_cycles > 0.0 && d.event_cycles.is_finite());
        }
    }

    #[test]
    fn compute_reference_path_works() {
        let t = gen::random(&[10, 12, 14], 500, 4);
        let f: Vec<FactorMatrix> = t
            .dims
            .iter()
            .map(|&d| FactorMatrix::random(d as usize, 16, 7))
            .collect();
        let out = compute_mode(&Compute::Reference, &t, 1, &f).unwrap();
        assert_eq!(out.rows, 12);
    }
}
