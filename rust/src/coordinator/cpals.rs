//! CP-ALS tensor decomposition on top of the MTTKRP paths — the
//! end-to-end workload (examples/cp_als.rs) proving the full stack
//! composes: rust coordinator → per-mode MTTKRP through the AOT artifacts
//! → CP factor update with the mini-linalg solver → fit metric.
//!
//! Standard alternating least squares for the CP model
//! `X ≈ Σ_r λ_r · a_r ⊗ b_r ⊗ c_r …`:
//!
//! ```text
//! for each mode d:  M   = MTTKRP(X, d)            (the kernel under study)
//!                   V   = ⊛_{m≠d} F_mᵀF_m         (Hadamard of grams)
//!                   F_d = M V⁻¹ ; normalize columns → λ
//! fit = 1 − ‖X − X̂‖ / ‖X‖   computed sparsely:
//!   ‖X − X̂‖² = ‖X‖² − 2⟨X, X̂⟩ + ‖X̂‖²,
//!   ⟨X, X̂⟩ = Σ_nnz x · Σ_r λ_r Π_m F_m(i_m, r)   (one more MTTKRP-style pass)
//!   ‖X̂‖²   = λᵀ (⊛_m F_mᵀF_m) λ
//! ```

use anyhow::Result;

use crate::coordinator::driver::{compute_mode, Compute};
use crate::coordinator::linalg::{self, SquareMat};
use crate::mttkrp::reference::FactorMatrix;
use crate::tensor::coo::SparseTensor;

/// One CP-ALS iteration record (for the fit curve log).
#[derive(Clone, Copy, Debug)]
pub struct IterStat {
    pub iter: usize,
    pub fit: f64,
    pub fit_delta: f64,
}

/// The decomposition result.
#[derive(Clone, Debug)]
pub struct CpModel {
    pub factors: Vec<FactorMatrix>,
    pub lambda: Vec<f64>,
    pub history: Vec<IterStat>,
}

impl CpModel {
    pub fn final_fit(&self) -> f64 {
        self.history.last().map(|s| s.fit).unwrap_or(0.0)
    }
}

/// CP-ALS configuration.
#[derive(Clone, Debug)]
pub struct CpAlsConfig {
    pub rank: usize,
    pub max_iters: usize,
    /// Stop when |Δfit| falls below this.
    pub tol: f64,
    pub seed: u64,
}

impl Default for CpAlsConfig {
    fn default() -> Self {
        CpAlsConfig { rank: 16, max_iters: 20, tol: 1e-5, seed: 42 }
    }
}

/// Run CP-ALS. `compute` selects the MTTKRP backend (reference CPU or the
/// PJRT artifacts).
pub fn cp_als(
    tensor: &SparseTensor,
    cfg: &CpAlsConfig,
    compute: &Compute<'_>,
) -> Result<CpModel> {
    let n = tensor.n_modes();
    let r = cfg.rank;
    let mut factors: Vec<FactorMatrix> = tensor
        .dims
        .iter()
        .enumerate()
        .map(|(m, &d)| FactorMatrix::random(d as usize, r, cfg.seed + m as u64))
        .collect();
    let mut lambda = vec![1.0f64; r];
    // cached grams of every factor
    let mut grams: Vec<SquareMat> =
        factors.iter().map(|f| linalg::gram(&f.data, r)).collect();

    let norm_x = tensor.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    let mut history = Vec::new();
    let mut prev_fit = 0.0f64;

    for iter in 0..cfg.max_iters {
        for d in 0..n {
            // M = MTTKRP(X, d) using current factors
            let m = compute_mode(compute, tensor, d, &factors)?;
            // V = Hadamard of the other grams (⊛-neutral seed: all-ones)
            let mut v = SquareMat::ones(r);
            for (j, g) in grams.iter().enumerate() {
                if j != d {
                    v = v.hadamard(g);
                }
            }
            // F_d = M V⁻¹ (solve Vᵀ = V SPD-ish; rows are RHS)
            let rows = m.rows;
            let rhs: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let solved = linalg::solve_spd(&v, &rhs);
            let mut new_data: Vec<f32> = solved.iter().map(|&x| x as f32).collect();
            lambda = linalg::normalize_columns(&mut new_data, r);
            factors[d] = FactorMatrix { rows, rank: r, data: new_data };
            grams[d] = linalg::gram(&factors[d].data, r);
        }

        let fit = fit_metric(tensor, &factors, &lambda, &grams, norm_x);
        let delta = (fit - prev_fit).abs();
        history.push(IterStat { iter, fit, fit_delta: delta });
        if iter > 0 && delta < cfg.tol {
            break;
        }
        prev_fit = fit;
    }
    Ok(CpModel { factors, lambda, history })
}

/// Sparse CP fit: `1 − ‖X − X̂‖ / ‖X‖` (see module docs).
fn fit_metric(
    tensor: &SparseTensor,
    factors: &[FactorMatrix],
    lambda: &[f64],
    grams: &[SquareMat],
    norm_x: f64,
) -> f64 {
    let r = lambda.len();
    // ⟨X, X̂⟩
    let mut inner = 0.0f64;
    let mut prod = vec![0.0f64; r];
    for k in 0..tensor.nnz() {
        prod.iter_mut().zip(lambda).for_each(|(p, &l)| *p = l);
        for (m, f) in factors.iter().enumerate() {
            let row = f.row(tensor.indices[m][k] as usize);
            for q in 0..r {
                prod[q] *= row[q] as f64;
            }
        }
        inner += tensor.values[k] as f64 * prod.iter().sum::<f64>();
    }
    // ‖X̂‖² = λᵀ (⊛ grams) λ  (⊛-neutral seed: all-ones)
    let mut had = SquareMat::ones(r);
    for g in grams {
        had = had.hadamard(g);
    }
    let mut norm_model_sq = 0.0f64;
    for a in 0..r {
        for b in 0..r {
            norm_model_sq += lambda[a] * had.at(a, b) * lambda[b];
        }
    }
    let resid_sq = (norm_x * norm_x - 2.0 * inner + norm_model_sq).max(0.0);
    1.0 - resid_sq.sqrt() / norm_x.max(1e-30)
}

/// Build a synthetic tensor with an exact low-rank CP structure plus
/// noise — the standard recoverability workload for CP-ALS validation.
pub fn low_rank_tensor(
    dims: &[u64],
    true_rank: usize,
    nnz: usize,
    noise: f32,
    seed: u64,
) -> SparseTensor {
    let mut rng = crate::util::rng::Rng::new(seed);
    let factors: Vec<FactorMatrix> = dims
        .iter()
        .enumerate()
        .map(|(m, &d)| FactorMatrix::random(d as usize, true_rank, seed ^ (m as u64) << 8))
        .collect();
    let mut t = SparseTensor::new("lowrank", dims.to_vec());
    let mut coords = vec![0u32; dims.len()];
    // sample distinct cells: duplicates would sum and break low-rankness
    let mut seen = std::collections::HashSet::new();
    let cells: f64 = dims.iter().map(|&d| d as f64).product();
    let nnz = nnz.min((cells * 0.8) as usize);
    while t.nnz() < nnz {
        for (m, &d) in dims.iter().enumerate() {
            coords[m] = rng.below(d) as u32;
        }
        if !seen.insert(coords.clone()) {
            continue;
        }
        let mut v = 0.0f64;
        for q in 0..true_rank {
            let mut p = 1.0f64;
            for (m, f) in factors.iter().enumerate() {
                p *= f.row(coords[m] as usize)[q] as f64;
            }
            v += p;
        }
        t.push(&coords, v as f32 + noise * (rng.f32() - 0.5));
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_improves_and_converges_on_low_rank_data() {
        // dense sampling (≈70% fill): a sparse CP model treats unsampled
        // cells as hard zeros, so only densely-sampled low-rank tensors
        // are recoverable to high fit.
        let t = low_rank_tensor(&[12, 12, 12], 3, 1650, 0.0, 7); // ~95% fill
        let cfg = CpAlsConfig { rank: 6, max_iters: 40, tol: 1e-9, seed: 3 };
        let model = cp_als(&t, &cfg, &Compute::Reference).unwrap();
        assert!(model.history.len() >= 2);
        // ceiling check: the ~5% masked cells bound the achievable fit at
        // ≈0.5 for the *true* factors; ALS must meet or beat that (it
        // reaches ≈0.64 — see the dbg study in EXPERIMENTS.md).
        assert!(model.final_fit() > 0.55, "fit {}", model.final_fit());
        // monotone-ish improvement: final ≥ first
        assert!(model.final_fit() >= model.history[0].fit - 1e-6);
    }

    #[test]
    fn sparse_masking_lowers_fit() {
        // the masking effect itself: same generator, sparser sample ⇒
        // worse CP fit (the implicit zeros fight the low-rank structure)
        let dense = low_rank_tensor(&[12, 12, 12], 3, 1200, 0.0, 7);
        let sparse = low_rank_tensor(&[12, 12, 12], 3, 250, 0.0, 7);
        let cfg = CpAlsConfig { rank: 6, max_iters: 15, tol: 1e-9, seed: 3 };
        let fd = cp_als(&dense, &cfg, &Compute::Reference).unwrap().final_fit();
        let fs = cp_als(&sparse, &cfg, &Compute::Reference).unwrap().final_fit();
        assert!(fd > fs, "dense-fill fit {fd} should beat sparse-fill {fs}");
    }

    #[test]
    fn fit_bounded_above_by_one() {
        let t = low_rank_tensor(&[10, 10, 10], 2, 500, 0.1, 1);
        let model =
            cp_als(
                &t,
                &CpAlsConfig { rank: 4, max_iters: 5, ..Default::default() },
                &Compute::Reference,
            )
                .unwrap();
        for s in &model.history {
            assert!(s.fit <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn noise_lowers_fit() {
        let clean = low_rank_tensor(&[20, 20, 20], 3, 2000, 0.0, 9);
        let noisy = low_rank_tensor(&[20, 20, 20], 3, 2000, 2.0, 9);
        let cfg = CpAlsConfig { rank: 6, max_iters: 10, ..Default::default() };
        let fc = cp_als(&clean, &cfg, &Compute::Reference).unwrap().final_fit();
        let fnz = cp_als(&noisy, &cfg, &Compute::Reference).unwrap().final_fit();
        assert!(fc > fnz, "clean {fc} vs noisy {fnz}");
    }

    #[test]
    fn four_mode_decomposition_runs() {
        let t = low_rank_tensor(&[7, 6, 5, 6], 2, 900, 0.01, 5); // ~71% fill
        let cfg = CpAlsConfig { rank: 4, max_iters: 20, tol: 1e-9, ..Default::default() };
        let model = cp_als(&t, &cfg, &Compute::Reference).unwrap();
        assert_eq!(model.factors.len(), 4);
        assert!(model.final_fit() > 0.5, "fit {}", model.final_fit());
    }

    #[test]
    fn lambda_columns_are_normalized() {
        let t = low_rank_tensor(&[15, 15, 15], 3, 1000, 0.0, 2);
        let cfg = CpAlsConfig { rank: 4, max_iters: 3, ..Default::default() };
        let model = cp_als(&t, &cfg, &Compute::Reference).unwrap();
        for f in &model.factors {
            for q in 0..4 {
                let norm: f64 = (0..f.rows)
                    .map(|i| (f.row(i)[q] as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!((norm - 1.0).abs() < 1e-3, "column norm {norm}");
            }
        }
        assert!(model.lambda.iter().all(|&l| l > 0.0));
    }
}
