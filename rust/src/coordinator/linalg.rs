//! Small dense linear algebra for the CP-ALS update (R ≤ 32).
//!
//! Everything is row-major `Vec<f64>` (f64 internally: the normal
//! equations `⊛ grams` can be ill-conditioned and the matrices are tiny,
//! so precision is free).

/// Row-major square matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct SquareMat {
    pub n: usize,
    pub data: Vec<f64>,
}

impl SquareMat {
    pub fn zeros(n: usize) -> Self {
        SquareMat { n, data: vec![0.0; n * n] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// All-ones matrix — the neutral element of the *Hadamard* product
    /// (using `identity` there zeroes every cross term; see cpals).
    pub fn ones(n: usize) -> Self {
        SquareMat { n, data: vec![1.0; n * n] }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &SquareMat) -> SquareMat {
        assert_eq!(self.n, other.n);
        SquareMat {
            n: self.n,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect(),
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

/// Gram matrix `G = FᵀF` of a row-major `rows × rank` f32 matrix.
pub fn gram(data: &[f32], rank: usize) -> SquareMat {
    assert_eq!(data.len() % rank, 0);
    let rows = data.len() / rank;
    let mut g = SquareMat::zeros(rank);
    for i in 0..rows {
        let row = &data[i * rank..(i + 1) * rank];
        for a in 0..rank {
            let ra = row[a] as f64;
            for b in a..rank {
                g.data[a * rank + b] += ra * row[b] as f64;
            }
        }
    }
    // mirror the upper triangle
    for a in 0..rank {
        for b in 0..a {
            g.data[a * rank + b] = g.data[b * rank + a];
        }
    }
    g
}

/// Cholesky factorization (in place lower triangle). Returns `None` if the
/// matrix is not positive definite (caller adds ridge and retries).
pub fn cholesky(m: &SquareMat) -> Option<SquareMat> {
    let n = m.n;
    let mut l = SquareMat::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = m.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.at(j, j));
            }
        }
    }
    Some(l)
}

/// Solve `M x = b` for many right-hand sides via Cholesky; `rhs` is
/// row-major `nrhs × n` (each row one RHS). Adds an escalating ridge if
/// needed. Returns row-major solutions of the same shape.
pub fn solve_spd(m: &SquareMat, rhs: &[f64]) -> Vec<f64> {
    let n = m.n;
    assert_eq!(rhs.len() % n, 0);
    let mut ridge = 0.0;
    let scale = m.max_abs().max(1e-30);
    let l = loop {
        let mut try_m = m.clone();
        if ridge > 0.0 {
            for i in 0..n {
                try_m.data[i * n + i] += ridge;
            }
        }
        if let Some(l) = cholesky(&try_m) {
            break l;
        }
        ridge = if ridge == 0.0 { scale * 1e-12 } else { ridge * 100.0 };
        assert!(ridge < scale * 1e3, "solve_spd: matrix unrecoverably singular");
    };
    let nrhs = rhs.len() / n;
    let mut out = vec![0.0f64; rhs.len()];
    for r in 0..nrhs {
        let b = &rhs[r * n..(r + 1) * n];
        // forward: L y = b
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= l.at(i, k) * y[k];
            }
            y[i] = s / l.at(i, i);
        }
        // backward: Lᵀ x = y
        let x = &mut out[r * n..(r + 1) * n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l.at(k, i) * x[k];
            }
            x[i] = s / l.at(i, i);
        }
    }
    out
}

/// Inverse of an SPD matrix via Cholesky solves against the identity.
pub fn inv_spd(m: &SquareMat) -> SquareMat {
    let n = m.n;
    let eye = SquareMat::identity(n);
    let x = solve_spd(m, &eye.data);
    // solve returned rows of M⁻¹ᵀ = M⁻¹ (symmetric)
    SquareMat { n, data: x }
}

/// Normalize the columns of a row-major `rows × rank` f32 matrix to unit
/// 2-norm; returns the column norms λ_r (zero-norm columns get λ = 1 and
/// are left untouched — keeps CP-ALS stable on degenerate inits).
pub fn normalize_columns(data: &mut [f32], rank: usize) -> Vec<f64> {
    let rows = data.len() / rank;
    let mut norms = vec![0.0f64; rank];
    for i in 0..rows {
        for r in 0..rank {
            let v = data[i * rank + r] as f64;
            norms[r] += v * v;
        }
    }
    for n in &mut norms {
        *n = n.sqrt();
        if *n == 0.0 {
            *n = 1.0;
        }
    }
    for i in 0..rows {
        for r in 0..rank {
            data[i * rank + r] /= norms[r] as f32;
        }
    }
    norms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, FnGen};
    use crate::util::rng::Rng;

    #[test]
    fn gram_small_hand_check() {
        // F = [[1, 2], [3, 4]] → FᵀF = [[10, 14], [14, 20]]
        let g = gram(&[1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(g.data, vec![10.0, 14.0, 14.0, 20.0]);
    }

    #[test]
    fn cholesky_reconstructs() {
        let m = SquareMat { n: 2, data: vec![4.0, 2.0, 2.0, 3.0] };
        let l = cholesky(&m).unwrap();
        // L Lᵀ = M
        for i in 0..2 {
            for j in 0..2 {
                let mut s = 0.0;
                for k in 0..2 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - m.at(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = SquareMat { n: 2, data: vec![1.0, 2.0, 2.0, 1.0] }; // eigvals 3, −1
        assert!(cholesky(&m).is_none());
    }

    #[test]
    fn solve_spd_known_system() {
        let m = SquareMat { n: 2, data: vec![4.0, 2.0, 2.0, 3.0] };
        // b = M · [1, 2]ᵀ = [8, 8]
        let x = solve_spd(&m, &[8.0, 8.0]);
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn inv_spd_times_m_is_identity() {
        let m = SquareMat { n: 3, data: vec![5.0, 1.0, 0.5, 1.0, 4.0, 0.2, 0.5, 0.2, 3.0] };
        let inv = inv_spd(&m);
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += inv.at(i, k) * m.at(k, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-10, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn singular_matrix_gets_ridge_not_panic() {
        let m = SquareMat { n: 2, data: vec![1.0, 1.0, 1.0, 1.0] }; // rank 1
        let x = solve_spd(&m, &[2.0, 2.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn normalize_columns_unit_norm_and_lambdas() {
        let mut f = vec![3.0f32, 0.0, 4.0, 0.0]; // col0 = [3,4] norm 5, col1 zero
        let lam = normalize_columns(&mut f, 2);
        assert!((lam[0] - 5.0).abs() < 1e-6);
        assert_eq!(lam[1], 1.0);
        let n0 = (f[0] * f[0] + f[2] * f[2]).sqrt();
        assert!((n0 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prop_solve_recovers_random_spd_systems() {
        let gen = FnGen(|rng: &mut Rng| {
            let n = 1 + rng.index(8);
            // SPD via AᵀA + εI
            let a: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let mut m = SquareMat::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = if i == j { 0.1 } else { 0.0 };
                    for k in 0..n {
                        s += a[k * n + i] * a[k * n + j];
                    }
                    m.set(i, j, s);
                }
            }
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let mut b = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += m.at(i, j) * x[j];
                }
            }
            (m.n as u64, m.data.clone(), x, b)
        });
        check("solve_spd_recovers", 60, &gen, |(n, data, x, b)| {
            let m = SquareMat { n: *n as usize, data: data.clone() };
            let got = solve_spd(&m, b);
            got.iter().zip(x).all(|(g, w)| (g - w).abs() < 1e-6 * (1.0 + w.abs()))
        });
    }
}
