//! The L3 coordinator: ties tensors, the simulator, the energy/area
//! models and the PJRT numeric path into end-to-end drivers.
//!
//! * [`linalg`] — small dense linear algebra (gram, Cholesky solve,
//!   column normalization) for the CP-ALS update — no external BLAS in
//!   this environment, and R ≤ 32 keeps everything tiny.
//! * [`scheduler`] — work partitioning across PEs / numeric block plans.
//! * [`driver`] — the public simulate/compute entry points (prelude API).
//! * [`cpals`] — CP-ALS tensor decomposition on top of the MTTKRP paths:
//!   the end-to-end workload that proves all layers compose.

pub mod cpals;
pub mod driver;
pub mod linalg;
pub mod scheduler;
