//! The L3 coordinator: ties tensors, the simulation engines, the
//! energy/area models and the PJRT numeric path into end-to-end drivers.
//!
//! * [`linalg`] — small dense linear algebra (gram, Cholesky solve,
//!   column normalization) for the CP-ALS update — no external BLAS in
//!   this environment, and R ≤ 32 keeps everything tiny.
//! * [`scheduler`] — work partitioning across PEs / numeric block plans
//!   (re-exports the single [`crate::sim::engine::partition_slices`]
//!   path both simulation engines use, so scheduling and simulation can
//!   never drift apart).
//! * [`driver`] — the public simulate/compare/cross-validate entry
//!   points (prelude API); every simulate entry point has a
//!   `_with_engine` variant selecting the analytic or event backend.
//! * [`cpals`] — CP-ALS tensor decomposition on top of the MTTKRP paths:
//!   the end-to-end workload that proves all layers compose.

pub mod cpals;
pub mod driver;
pub mod linalg;
pub mod scheduler;
