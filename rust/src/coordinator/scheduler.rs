//! Work scheduling: PE partitions for the simulator (re-exported from the
//! engine) and block plans for the numeric path.

pub use crate::sim::engine::partition_slices;

use crate::tensor::csf::ModeView;

/// A numeric-path execution plan: which slices each worker processes and
/// how many artifact blocks that amounts to.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerPlan {
    pub worker: usize,
    /// Slice index range `[lo, hi)` of the mode view.
    pub slices: (usize, usize),
    pub nnz: u64,
    pub blocks: u64,
}

/// Plan the numeric execution of one mode across `n_workers`, mirroring
/// the simulator's PE partitioning so the numeric path exercises the same
/// decomposition the timing model charges for.
pub fn plan_workers(view: &ModeView, n_workers: usize, block: usize) -> Vec<WorkerPlan> {
    partition_slices(view, n_workers)
        .into_iter()
        .enumerate()
        .map(|(w, (lo, hi))| {
            let nnz: u64 = (lo..hi).map(|s| view.slice(s).len() as u64).sum();
            WorkerPlan {
                worker: w,
                slices: (lo, hi),
                nnz,
                blocks: nnz.div_ceil(block as u64),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;

    #[test]
    fn plans_cover_everything_and_count_blocks() {
        let t = gen::random(&[100, 40, 40], 10_000, 1);
        let view = ModeView::build(&t, 0);
        let plans = plan_workers(&view, 4, 1024);
        assert_eq!(plans.len(), 4);
        let total: u64 = plans.iter().map(|p| p.nnz).sum();
        assert_eq!(total, 10_000);
        for p in &plans {
            assert_eq!(p.blocks, p.nnz.div_ceil(1024));
        }
        assert_eq!(plans[0].slices.0, 0);
        assert_eq!(plans.last().unwrap().slices.1, view.n_slices());
    }

    #[test]
    fn degenerate_single_worker() {
        let t = gen::random(&[10, 10], 100, 2);
        let view = ModeView::build(&t, 1);
        let plans = plan_workers(&view, 1, 1024);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].nnz, 100);
    }
}
