//! Coordinate-format (COO) sparse tensors with FROSTT `.tns` I/O.
//!
//! Layout: structure-of-arrays — one flat `Vec<u32>` of indices per mode
//! plus a `Vec<f32>` of values. SoA keeps the simulator's per-mode walks
//! cache-friendly and lets the trace generator iterate a single mode's
//! index stream without striding over the others.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// An N-mode sparse tensor in coordinate format.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    /// Human-readable name (e.g. `"nell-2@1/256"`).
    pub name: String,
    /// Size of each mode, `dims.len()` = number of modes N ≥ 1.
    pub dims: Vec<u64>,
    /// `indices[m][k]` = mode-`m` coordinate of nonzero `k`.
    pub indices: Vec<Vec<u32>>,
    /// `values[k]` = value of nonzero `k`.
    pub values: Vec<f32>,
}

impl SparseTensor {
    /// Create an empty tensor with the given mode sizes.
    pub fn new(name: &str, dims: Vec<u64>) -> Self {
        assert!(!dims.is_empty(), "tensor needs at least one mode");
        assert!(
            dims.iter().all(|&d| d > 0 && d <= u32::MAX as u64 + 1),
            "mode sizes must fit u32 coordinates"
        );
        let n = dims.len();
        SparseTensor {
            name: name.to_string(),
            dims,
            indices: vec![Vec::new(); n],
            values: Vec::new(),
        }
    }

    /// Number of modes N.
    #[inline]
    pub fn n_modes(&self) -> usize {
        self.dims.len()
    }

    /// Number of stored nonzeros |T|.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density |T| / ∏ dims (Table II's rightmost column).
    pub fn density(&self) -> f64 {
        let cells: f64 = self.dims.iter().map(|&d| d as f64).product();
        self.nnz() as f64 / cells
    }

    /// Append a nonzero. Panics (debug) if coordinates are out of range.
    #[inline]
    pub fn push(&mut self, coords: &[u32], value: f32) {
        debug_assert_eq!(coords.len(), self.n_modes());
        for (m, (&c, &d)) in coords.iter().zip(&self.dims).enumerate() {
            debug_assert!((c as u64) < d, "mode {m}: coord {c} out of range {d}");
            let _ = m;
        }
        for (m, &c) in coords.iter().enumerate() {
            self.indices[m].push(c);
        }
        self.values.push(value);
    }

    /// Coordinates of nonzero `k` (allocates; hot paths should index
    /// `self.indices[m][k]` directly).
    pub fn coords(&self, k: usize) -> Vec<u32> {
        self.indices.iter().map(|col| col[k]).collect()
    }

    /// Full structural validation: arity, lengths, coordinate ranges.
    pub fn validate(&self) -> Result<()> {
        if self.dims.is_empty() {
            bail!("tensor {} has no modes", self.name);
        }
        if self.indices.len() != self.dims.len() {
            bail!(
                "tensor {}: {} index columns for {} modes",
                self.name,
                self.indices.len(),
                self.dims.len()
            );
        }
        for (m, col) in self.indices.iter().enumerate() {
            if col.len() != self.values.len() {
                bail!(
                    "tensor {}: mode {m} has {} coords but {} values",
                    self.name,
                    col.len(),
                    self.values.len()
                );
            }
            let dim = self.dims[m];
            if let Some(&bad) = col.iter().find(|&&c| c as u64 >= dim) {
                bail!("tensor {}: mode {m} coordinate {bad} ≥ dim {dim}", self.name);
            }
        }
        Ok(())
    }

    /// Sort nonzeros lexicographically with `mode` as the primary key (the
    /// order Algorithm 1 consumes for output mode `mode`). Stable w.r.t.
    /// remaining modes in ascending mode order. Returns the permutation
    /// applied (old position of each new slot).
    pub fn sort_by_mode(&mut self, mode: usize) -> Vec<u32> {
        assert!(mode < self.n_modes());
        let n = self.nnz();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let key_modes: Vec<usize> =
            std::iter::once(mode).chain((0..self.n_modes()).filter(|&m| m != mode)).collect();
        order.sort_unstable_by(|&a, &b| {
            for &m in &key_modes {
                let (ia, ib) = (self.indices[m][a as usize], self.indices[m][b as usize]);
                match ia.cmp(&ib) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        });
        self.apply_permutation(&order);
        order
    }

    /// Reorder nonzeros so new slot `i` holds old nonzero `perm[i]`.
    pub fn apply_permutation(&mut self, perm: &[u32]) {
        assert_eq!(perm.len(), self.nnz());
        for col in &mut self.indices {
            let new: Vec<u32> = perm.iter().map(|&p| col[p as usize]).collect();
            *col = new;
        }
        let newv: Vec<f32> = perm.iter().map(|&p| self.values[p as usize]).collect();
        self.values = newv;
    }

    /// Total bytes a hardware run must move for the tensor itself:
    /// each nonzero is N u32 coordinates + one f32 value.
    pub fn nnz_bytes(&self) -> u64 {
        (self.nnz() as u64) * (4 * self.n_modes() as u64 + 4)
    }

    // ------------------------------------------------------------------
    // FROSTT .tns text format: one nonzero per line,
    // `i_1 i_2 ... i_N value`, 1-based indices, `#` comments.
    // ------------------------------------------------------------------

    /// Parse FROSTT `.tns` text. Mode sizes are taken as the max coordinate
    /// seen per mode (the FROSTT convention) unless `dims` is given.
    pub fn read_tns(reader: impl BufRead, name: &str, dims: Option<Vec<u64>>) -> Result<Self> {
        let mut rows: Vec<(Vec<u32>, f32)> = Vec::new();
        let mut n_modes: Option<usize> = None;
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.context("read error")?;
            let body = line.split('#').next().unwrap_or("").trim();
            if body.is_empty() {
                continue;
            }
            let fields: Vec<&str> = body.split_whitespace().collect();
            if fields.len() < 2 {
                bail!("{name}:{}: expected `i.. value`, got `{body}`", lineno + 1);
            }
            let n = fields.len() - 1;
            match n_modes {
                None => n_modes = Some(n),
                Some(expect) if expect != n => {
                    bail!("{name}:{}: {n} coords, expected {expect}", lineno + 1)
                }
                _ => {}
            }
            let mut coords = Vec::with_capacity(n);
            for f in &fields[..n] {
                let one_based: u64 =
                    f.parse().with_context(|| format!("{name}:{}: bad index `{f}`", lineno + 1))?;
                if one_based == 0 {
                    bail!("{name}:{}: .tns indices are 1-based, got 0", lineno + 1);
                }
                coords.push((one_based - 1) as u32);
            }
            let value: f32 = fields[n]
                .parse()
                .with_context(|| format!("{name}:{}: bad value `{}`", lineno + 1, fields[n]))?;
            rows.push((coords, value));
        }
        let n = n_modes.unwrap_or(dims.as_ref().map(|d| d.len()).unwrap_or(0));
        if n == 0 {
            bail!("{name}: empty tensor file and no dims given");
        }
        let dims = dims.unwrap_or_else(|| {
            (0..n)
                .map(|m| rows.iter().map(|(c, _)| c[m] as u64 + 1).max().unwrap_or(1))
                .collect()
        });
        let mut t = SparseTensor::new(name, dims);
        for (coords, v) in rows {
            t.push(&coords, v);
        }
        t.validate()?;
        Ok(t)
    }

    /// Load a `.tns` file from disk.
    pub fn load_tns(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("tensor").to_string();
        Self::read_tns(std::io::BufReader::new(file), &name, None)
    }

    /// Write FROSTT `.tns` text (1-based indices).
    pub fn write_tns(&self, w: impl Write) -> Result<()> {
        let mut w = BufWriter::new(w);
        for k in 0..self.nnz() {
            for m in 0..self.n_modes() {
                write!(w, "{} ", self.indices[m][k] as u64 + 1)?;
            }
            writeln!(w, "{}", self.values[k])?;
        }
        w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SparseTensor {
        let mut t = SparseTensor::new("t", vec![4, 5, 6]);
        t.push(&[3, 0, 2], 1.0);
        t.push(&[0, 4, 5], 2.0);
        t.push(&[3, 0, 1], 3.0);
        t.push(&[1, 2, 2], 4.0);
        t
    }

    #[test]
    fn basic_accessors() {
        let t = small();
        assert_eq!(t.n_modes(), 3);
        assert_eq!(t.nnz(), 4);
        assert_eq!(t.coords(1), vec![0, 4, 5]);
        assert!((t.density() - 4.0 / 120.0).abs() < 1e-12);
        assert_eq!(t.nnz_bytes(), 4 * (12 + 4));
        t.validate().unwrap();
    }

    #[test]
    fn sort_by_mode_groups_output_index() {
        let mut t = small();
        t.sort_by_mode(0);
        assert_eq!(t.indices[0], vec![0, 1, 3, 3]);
        // ties on mode 0 broken by remaining modes ascending: (3,0,1) < (3,0,2)
        assert_eq!(t.indices[2][2], 1);
        assert_eq!(t.indices[2][3], 2);
        // values follow their nonzeros
        assert_eq!(t.values, vec![2.0, 4.0, 3.0, 1.0]);
        t.validate().unwrap();
    }

    #[test]
    fn sort_by_middle_mode() {
        let mut t = small();
        t.sort_by_mode(1);
        let mut prev = 0u32;
        for &i in &t.indices[1] {
            assert!(i >= prev);
            prev = i;
        }
    }

    #[test]
    fn permutation_roundtrip() {
        let t0 = small();
        let mut t = t0.clone();
        let perm = t.sort_by_mode(2);
        // invert and restore
        let mut inv = vec![0u32; perm.len()];
        for (newpos, &old) in perm.iter().enumerate() {
            inv[old as usize] = newpos as u32;
        }
        // applying inv to sorted gives original? apply_permutation semantics:
        // new[i] = old[perm[i]]; to undo apply perm2 with perm2[j] = position
        // of original j in sorted = inv[j].
        t.apply_permutation(&inv);
        assert_eq!(t, t0);
    }

    #[test]
    fn tns_roundtrip() {
        let t = small();
        let mut buf = Vec::new();
        t.write_tns(&mut buf).unwrap();
        let back =
            SparseTensor::read_tns(std::io::Cursor::new(buf), "t", Some(t.dims.clone())).unwrap();
        assert_eq!(back.indices, t.indices);
        assert_eq!(back.values, t.values);
    }

    #[test]
    fn tns_parses_comments_and_infers_dims() {
        let text = "# header\n1 1 1 5.0\n2 3 4 -1.5  # trailing\n\n";
        let t = SparseTensor::read_tns(std::io::Cursor::new(text), "x", None).unwrap();
        assert_eq!(t.dims, vec![2, 3, 4]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.values[1], -1.5);
        assert_eq!(t.coords(0), vec![0, 0, 0]);
    }

    #[test]
    fn tns_rejects_zero_based_and_ragged() {
        assert!(SparseTensor::read_tns(std::io::Cursor::new("0 1 1 2.0"), "x", None).is_err());
        assert!(SparseTensor::read_tns(std::io::Cursor::new("1 1 1 2.0\n1 1 2.0"), "x", None)
            .is_err());
        assert!(SparseTensor::read_tns(std::io::Cursor::new(""), "x", None).is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut t = small();
        t.dims[0] = 2; // now coord 3 is invalid
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_length_mismatch() {
        let mut t = small();
        t.values.pop();
        assert!(t.validate().is_err());
    }
}
