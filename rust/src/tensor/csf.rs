//! Per-output-mode compressed view (CSF-style, two levels).
//!
//! Algorithm 1 consumes nonzeros grouped by the output-mode index so each
//! output row `A(i,:)` is produced exactly once with no partial sums spilled
//! to DRAM. [`ModeView`] materializes that grouping: slices (distinct output
//! indices) → the range of nonzeros in each slice, over a mode-sorted
//! nonzero ordering, without duplicating the tensor.

use crate::tensor::coo::SparseTensor;

/// A two-level compressed view of a tensor for one output mode.
///
/// `slice_ptr` is the classic CSR-style offsets array: slice `s` covers
/// nonzeros `order[slice_ptr[s] .. slice_ptr[s+1]]`, all sharing output
/// index `slice_idx[s]`. `order[k]` maps view position → original nonzero.
#[derive(Clone, Debug)]
pub struct ModeView {
    /// The output mode this view is for.
    pub mode: usize,
    /// Distinct output-mode indices, ascending.
    pub slice_idx: Vec<u32>,
    /// Offsets into `order`, length `slice_idx.len() + 1`.
    pub slice_ptr: Vec<u32>,
    /// Permutation: view position → original nonzero id.
    pub order: Vec<u32>,
}

impl ModeView {
    /// Build the view for `mode`.
    ///
    /// Two strategies, picked by density of the output mode:
    /// * **counting sort** — O(nnz + dim), stable; ideal when the mode
    ///   dimension is comparable to nnz;
    /// * **comparison sort** — O(nnz log nnz); when `dim ≫ nnz` the
    ///   counting sort's dim-sized histogram (tens of MB for web-scale
    ///   modes) costs more in allocation + cold-memory traffic than the
    ///   log factor (§Perf: 4.3 → >15 M nnz-events/s on miss-heavy
    ///   workloads).
    ///
    /// Both produce identical views (stable grouping by output index,
    /// original order within a slice).
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        assert!(mode < t.n_modes(), "mode {mode} out of range");
        let dim = t.dims[mode] as usize;
        let nnz = t.nnz();
        if dim <= 4 * nnz + 1024 {
            Self::build_counting(t, mode, dim)
        } else {
            Self::build_sorting(t, mode)
        }
    }

    /// Counting-sort construction (histogram → prefix sum → scatter).
    fn build_counting(t: &SparseTensor, mode: usize, dim: usize) -> Self {
        let col = &t.indices[mode];
        let nnz = t.nnz();
        let mut count = vec![0u32; dim + 1];
        for &i in col {
            count[i as usize + 1] += 1;
        }
        for s in 0..dim {
            count[s + 1] += count[s];
        }
        let mut order = vec![0u32; nnz];
        let mut cursor = count.clone();
        for (k, &i) in col.iter().enumerate() {
            let slot = cursor[i as usize];
            order[slot as usize] = k as u32;
            cursor[i as usize] += 1;
        }

        // compress empty slices out
        let mut slice_idx = Vec::new();
        let mut slice_ptr = vec![0u32];
        for i in 0..dim {
            if count[i + 1] > count[i] {
                slice_idx.push(i as u32);
                slice_ptr.push(count[i + 1]);
            }
        }
        ModeView { mode, slice_idx, slice_ptr, order }
    }

    /// Sort-based construction for `dim ≫ nnz` modes.
    fn build_sorting(t: &SparseTensor, mode: usize) -> Self {
        let col = &t.indices[mode];
        let nnz = t.nnz();
        let mut order: Vec<u32> = (0..nnz as u32).collect();
        // stable sort on output index keeps original order within slices,
        // matching build_counting exactly
        order.sort_by_key(|&k| col[k as usize]);
        let mut slice_idx = Vec::new();
        let mut slice_ptr = vec![0u32];
        let mut prev: Option<u32> = None;
        for (pos, &k) in order.iter().enumerate() {
            let idx = col[k as usize];
            if prev != Some(idx) {
                if prev.is_some() {
                    slice_ptr.push(pos as u32);
                }
                slice_idx.push(idx);
                prev = Some(idx);
            }
        }
        if prev.is_some() {
            slice_ptr.push(nnz as u32);
        }
        ModeView { mode, slice_idx, slice_ptr, order }
    }

    /// Number of non-empty output slices (rows of A actually written).
    #[inline]
    pub fn n_slices(&self) -> usize {
        self.slice_idx.len()
    }

    /// Total nonzeros covered (= tensor nnz).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.order.len()
    }

    /// Iterate `(output_index, &[original nonzero ids])` per slice.
    pub fn slices(&self) -> impl Iterator<Item = (u32, &[u32])> + '_ {
        self.slice_idx.iter().enumerate().map(move |(s, &idx)| {
            let lo = self.slice_ptr[s] as usize;
            let hi = self.slice_ptr[s + 1] as usize;
            (idx, &self.order[lo..hi])
        })
    }

    /// Nonzeros in slice `s` (by position, not output index).
    pub fn slice(&self, s: usize) -> &[u32] {
        let lo = self.slice_ptr[s] as usize;
        let hi = self.slice_ptr[s + 1] as usize;
        &self.order[lo..hi]
    }

    /// Fibers-per-slice summary used by the generators' calibration tests.
    pub fn avg_nnz_per_slice(&self) -> f64 {
        if self.n_slices() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_slices() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, FnGen};
    use crate::util::rng::Rng;

    fn small() -> SparseTensor {
        let mut t = SparseTensor::new("t", vec![4, 5, 6]);
        t.push(&[3, 0, 2], 1.0);
        t.push(&[0, 4, 5], 2.0);
        t.push(&[3, 0, 1], 3.0);
        t.push(&[1, 2, 2], 4.0);
        t
    }

    #[test]
    fn groups_by_output_index() {
        let t = small();
        let v = ModeView::build(&t, 0);
        assert_eq!(v.slice_idx, vec![0, 1, 3]);
        assert_eq!(v.n_slices(), 3);
        assert_eq!(v.slice(0), &[1]); // nonzero 1 has i0 = 0
        assert_eq!(v.slice(1), &[3]);
        assert_eq!(v.slice(2), &[0, 2]); // stable: original order kept
        assert_eq!(v.nnz(), 4);
    }

    #[test]
    fn every_mode_covers_all_nonzeros() {
        let t = small();
        for m in 0..3 {
            let v = ModeView::build(&t, m);
            let mut seen = vec![false; t.nnz()];
            for (_, slice) in v.slices() {
                for &k in slice {
                    assert!(!seen[k as usize], "duplicate nonzero {k}");
                    seen[k as usize] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "mode {m} missed nonzeros");
        }
    }

    #[test]
    fn slices_have_uniform_output_index() {
        let t = small();
        for m in 0..3 {
            let v = ModeView::build(&t, m);
            for (idx, slice) in v.slices() {
                for &k in slice {
                    assert_eq!(t.indices[m][k as usize], idx);
                }
            }
        }
    }

    #[test]
    fn counting_and_sorting_builders_agree() {
        // force both paths on the same data and compare field-for-field
        let mut t = SparseTensor::new("b", vec![1_000_000, 8]);
        let mut rng = Rng::new(9);
        for _ in 0..500 {
            t.push(&[rng.below(1_000_000) as u32, rng.below(8) as u32], 1.0);
        }
        let by_sort = ModeView::build(&t, 0); // dim ≫ nnz ⇒ sorting path
        let by_count = ModeView::build_counting(&t, 0, 1_000_000);
        assert_eq!(by_sort.slice_idx, by_count.slice_idx);
        assert_eq!(by_sort.slice_ptr, by_count.slice_ptr);
        assert_eq!(by_sort.order, by_count.order);
        // dense mode takes the counting path; cross-check it too
        let dense_sort = ModeView::build_sorting(&t, 1);
        let dense_count = ModeView::build(&t, 1);
        assert_eq!(dense_sort.order, dense_count.order);
        assert_eq!(dense_sort.slice_ptr, dense_count.slice_ptr);
    }

    #[test]
    fn empty_tensor_has_no_slices() {
        let t = SparseTensor::new("e", vec![10, 10]);
        let v = ModeView::build(&t, 0);
        assert_eq!(v.n_slices(), 0);
        assert_eq!(v.nnz(), 0);
        assert_eq!(v.avg_nnz_per_slice(), 0.0);
    }

    #[test]
    fn prop_view_is_partition_with_sorted_slices() {
        // random small tensors: view is a partition of nonzeros and
        // slice_idx strictly ascending, for every mode.
        let gen = FnGen(|rng: &mut Rng| {
            let n_modes = 1 + rng.index(4);
            let dims: Vec<u64> = (0..n_modes).map(|_| 1 + rng.below(12)).collect();
            let nnz = rng.index(60);
            let mut t = SparseTensor::new("p", dims.clone());
            for _ in 0..nnz {
                let coords: Vec<u32> =
                    dims.iter().map(|&d| rng.below(d) as u32).collect();
                t.push(&coords, rng.f32());
            }
            (t.dims.clone(), t.indices.clone(), t.values.clone())
        });
        check("modeview_partition", 60, &gen, |(dims, indices, values)| {
            let t = SparseTensor {
                name: "p".into(),
                dims: dims.clone(),
                indices: indices.clone(),
                values: values.clone(),
            };
            (0..t.n_modes()).all(|m| {
                let v = ModeView::build(&t, m);
                let mut seen = vec![false; t.nnz()];
                let mut prev: i64 = -1;
                for (idx, slice) in v.slices() {
                    if (idx as i64) <= prev || slice.is_empty() {
                        return false;
                    }
                    prev = idx as i64;
                    for &k in slice {
                        if seen[k as usize] {
                            return false;
                        }
                        seen[k as usize] = true;
                    }
                }
                seen.iter().all(|&b| b)
            })
        });
    }
}
