//! Locality-enhancing index remapping (the paper's "mapping of X into
//! memory for each mode", §IV-A).
//!
//! The goal in the paper is to minimize time spent on tensor loads, factor
//! loads, output stores and compute. The controllable degree of freedom at
//! model level is the *labeling* of mode indices: relabeling hot factor
//! rows to adjacent indices turns scattered accesses into cache-line
//! neighbours. We implement the standard degree-descending relabeling over
//! the hypergraph (hot vertices first), which is what hypergraph-
//! partitioning-based reorderings degenerate to for single-FPGA runs.

use crate::tensor::coo::SparseTensor;
use crate::tensor::hypergraph::Hypergraph;

/// A per-mode relabeling: `new_index = map[old_index]`.
#[derive(Clone, Debug)]
pub struct ModeRemap {
    pub mode: usize,
    pub map: Vec<u32>,
}

impl ModeRemap {
    /// Identity remap.
    pub fn identity(mode: usize, dim: usize) -> Self {
        ModeRemap { mode, map: (0..dim as u32).collect() }
    }

    /// Degree-descending remap: the highest-degree vertex gets index 0.
    /// Ties break by original index for determinism.
    pub fn by_degree(h: &Hypergraph, mode: usize) -> Self {
        let deg = &h.modes[mode].degree;
        let mut order: Vec<u32> = (0..deg.len() as u32).collect();
        order.sort_by(|&a, &b| {
            deg[b as usize].cmp(&deg[a as usize]).then(a.cmp(&b))
        });
        // order[rank] = old index with that rank; invert to map[old] = rank
        let mut map = vec![0u32; deg.len()];
        for (rank, &old) in order.iter().enumerate() {
            map[old as usize] = rank as u32;
        }
        ModeRemap { mode, map }
    }

    /// Check the map is a permutation of 0..dim.
    pub fn is_permutation(&self) -> bool {
        let mut seen = vec![false; self.map.len()];
        for &v in &self.map {
            let v = v as usize;
            if v >= seen.len() || seen[v] {
                return false;
            }
            seen[v] = true;
        }
        true
    }
}

/// Apply per-mode remaps to a tensor (in place). Factor matrices must be
/// permuted consistently by the caller when numerics matter — the
/// coordinator does this via [`permute_rows`].
pub fn apply(t: &mut SparseTensor, remaps: &[ModeRemap]) {
    for r in remaps {
        assert_eq!(r.map.len() as u64, t.dims[r.mode], "remap arity");
        for idx in &mut t.indices[r.mode] {
            *idx = r.map[*idx as usize];
        }
    }
}

/// Permute the rows of a dense row-major matrix `(rows × rank)` so row `i`
/// moves to `map[i]` — keeps factor matrices consistent with a remapped
/// tensor.
pub fn permute_rows(data: &[f32], rank: usize, map: &[u32]) -> Vec<f32> {
    assert_eq!(data.len(), map.len() * rank, "matrix shape mismatch");
    let mut out = vec![0.0f32; data.len()];
    for (old, &new) in map.iter().enumerate() {
        let src = &data[old * rank..(old + 1) * rank];
        out[new as usize * rank..(new as usize + 1) * rank].copy_from_slice(src);
    }
    out
}

/// Build degree-descending remaps for every mode of a tensor.
pub fn degree_remaps(t: &SparseTensor) -> Vec<ModeRemap> {
    let h = Hypergraph::build(t);
    (0..t.n_modes()).map(|m| ModeRemap::by_degree(&h, m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, FnGen};
    use crate::util::rng::Rng;

    fn small() -> SparseTensor {
        let mut t = SparseTensor::new("t", vec![4, 5, 6]);
        t.push(&[3, 0, 2], 1.0);
        t.push(&[0, 4, 5], 2.0);
        t.push(&[3, 0, 1], 3.0);
        t.push(&[1, 2, 2], 4.0);
        t
    }

    #[test]
    fn degree_remap_puts_hot_vertex_first() {
        let t = small();
        let h = Hypergraph::build(&t);
        let r = ModeRemap::by_degree(&h, 0);
        // mode-0 degrees [1,1,0,2] → old index 3 is hottest → new index 0
        assert_eq!(r.map[3], 0);
        assert!(r.is_permutation());
        // ties (old 0 and 1, both degree 1) break by original index
        assert_eq!(r.map[0], 1);
        assert_eq!(r.map[1], 2);
        assert_eq!(r.map[2], 3);
    }

    #[test]
    fn apply_remap_preserves_validity_and_degrees() {
        let mut t = small();
        let remaps = degree_remaps(&t);
        apply(&mut t, &remaps);
        t.validate().unwrap();
        // degree multiset preserved
        let h = Hypergraph::build(&t);
        let mut d: Vec<u32> = h.modes[0].degree.clone();
        d.sort_unstable();
        assert_eq!(d, vec![0, 1, 1, 2]);
        // hottest vertex now at index 0
        assert_eq!(h.modes[0].degree[0], 2);
    }

    #[test]
    fn permute_rows_follows_map() {
        // 3 rows × rank 2, map row0→2, row1→0, row2→1
        let data = [0.0, 0.1, 1.0, 1.1, 2.0, 2.1];
        let out = permute_rows(&data, 2, &[2, 0, 1]);
        assert_eq!(out, vec![1.0, 1.1, 2.0, 2.1, 0.0, 0.1]);
    }

    #[test]
    fn identity_is_noop() {
        let mut t = small();
        let orig = t.clone();
        let ids: Vec<ModeRemap> =
            (0..3).map(|m| ModeRemap::identity(m, t.dims[m] as usize)).collect();
        apply(&mut t, &ids);
        assert_eq!(t, orig);
    }

    #[test]
    fn prop_degree_remap_is_permutation() {
        let gen = FnGen(|rng: &mut Rng| {
            let dim = 1 + rng.index(50);
            let nnz = rng.index(200);
            let mut t = SparseTensor::new("p", vec![dim as u64, 8]);
            for _ in 0..nnz {
                t.push(&[rng.index(dim) as u32, rng.below(8) as u32], 1.0);
            }
            (t.dims.clone(), t.indices.clone(), t.values.clone())
        });
        check("degree_remap_perm", 80, &gen, |(dims, indices, values)| {
            let t = SparseTensor {
                name: "p".into(),
                dims: dims.clone(),
                indices: indices.clone(),
                values: values.clone(),
            };
            degree_remaps(&t).iter().all(|r| r.is_permutation())
        });
    }

    #[test]
    fn prop_remap_then_permuted_factors_consistent() {
        // numerics invariance is exercised end-to-end in mttkrp tests; here
        // check the row permutation round-trips through the map.
        let gen = FnGen(|rng: &mut Rng| {
            let rows = 1 + rng.index(20);
            let rank = 1 + rng.index(8);
            let data: Vec<f32> = (0..rows * rank).map(|_| rng.f32()).collect();
            let map = rng.permutation(rows).iter().map(|&x| x as u32).collect::<Vec<_>>();
            (data, rank as u64, map)
        });
        check("permute_rows_bijective", 80, &gen, |(data, rank, map)| {
            let rank = *rank as usize;
            let out = permute_rows(data, rank, map);
            // applying the inverse map restores the original
            let mut inv = vec![0u32; map.len()];
            for (old, &new) in map.iter().enumerate() {
                inv[new as usize] = old as u32;
            }
            permute_rows(&out, rank, &inv) == *data
        });
    }
}
