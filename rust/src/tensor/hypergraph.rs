//! Hypergraph model of a sparse tensor (paper §IV-A, Fig. 3).
//!
//! For an N-mode tensor with M nonzeros, H = (V, E) has
//! |V| = Σ dims (one vertex per index of every mode) and |E| = M (one
//! hyperedge per nonzero, connecting its N coordinates). The paper uses
//! this model to reason about the memory mapping; here it also feeds the
//! locality statistics ([`remap`](crate::tensor::remap) and the generator
//! calibration tests).

use crate::tensor::coo::SparseTensor;

/// Degree statistics of one mode's vertex class.
#[derive(Clone, Debug, PartialEq)]
pub struct ModeDegrees {
    /// `degree[i]` = number of hyperedges touching vertex `i` of this mode
    /// (= nonzeros whose coordinate in this mode is `i`).
    pub degree: Vec<u32>,
    /// Vertices with degree > 0.
    pub active: usize,
}

impl ModeDegrees {
    pub fn max(&self) -> u32 {
        self.degree.iter().copied().max().unwrap_or(0)
    }

    /// Fraction of all hyperedge endpoints landing on the `k` highest-degree
    /// vertices — the "head mass", a direct proxy for cache hit potential:
    /// if 90% of factor-row accesses hit 1% of rows, a small cache covers
    /// them.
    pub fn head_mass(&self, k: usize) -> f64 {
        let total: u64 = self.degree.iter().map(|&d| d as u64).sum();
        if total == 0 {
            return 0.0;
        }
        let mut sorted: Vec<u32> = self.degree.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let head: u64 = sorted.iter().take(k).map(|&d| d as u64).sum();
        head as f64 / total as f64
    }
}

/// The hypergraph H = (V, E) of a tensor, stored as per-mode degree arrays
/// plus global counts (the full incidence structure is the tensor itself —
/// no need to duplicate it).
#[derive(Clone, Debug)]
pub struct Hypergraph {
    pub n_vertices: u64,
    pub n_hyperedges: usize,
    pub modes: Vec<ModeDegrees>,
}

impl Hypergraph {
    pub fn build(t: &SparseTensor) -> Self {
        let mut modes = Vec::with_capacity(t.n_modes());
        for m in 0..t.n_modes() {
            let mut degree = vec![0u32; t.dims[m] as usize];
            for &i in &t.indices[m] {
                degree[i as usize] += 1;
            }
            let active = degree.iter().filter(|&&d| d > 0).count();
            modes.push(ModeDegrees { degree, active });
        }
        Hypergraph {
            n_vertices: t.dims.iter().sum(),
            n_hyperedges: t.nnz(),
            modes,
        }
    }

    /// Paper §IV-A analytic totals for MTTKRP on this tensor.
    ///
    /// * compute per mode: `N × |T| × R` (N−1 multiplies + 1 add per rank
    ///   element);
    /// * external data transferred for output mode `out`:
    ///   `|T| + (N−1)×|T|×R + I_out×R` elements.
    pub fn compute_per_mode(&self, rank: usize) -> u64 {
        self.modes.len() as u64 * self.n_hyperedges as u64 * rank as u64
    }

    /// Elements transferred from/to external memory for output mode `out`
    /// (tensor loads + input factor rows + output rows).
    pub fn data_transfer_elements(&self, out: usize, rank: usize) -> u64 {
        let n = self.modes.len() as u64;
        let t = self.n_hyperedges as u64;
        let i_out = self.modes[out].degree.len() as u64;
        t + (n - 1) * t * rank as u64 + i_out * rank as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SparseTensor {
        let mut t = SparseTensor::new("t", vec![4, 5, 6]);
        t.push(&[3, 0, 2], 1.0);
        t.push(&[0, 4, 5], 2.0);
        t.push(&[3, 0, 1], 3.0);
        t.push(&[1, 2, 2], 4.0);
        t
    }

    #[test]
    fn counts_match_paper_formulas() {
        let t = small();
        let h = Hypergraph::build(&t);
        assert_eq!(h.n_vertices, 4 + 5 + 6);
        assert_eq!(h.n_hyperedges, 4);
        // N × |T| × R with N=3, |T|=4, R=16
        assert_eq!(h.compute_per_mode(16), 3 * 4 * 16);
        // |T| + (N-1)|T|R + I_out R for mode 0: 4 + 2*4*16 + 4*16
        assert_eq!(h.data_transfer_elements(0, 16), 4 + 128 + 64);
        // for mode 2: I_out = 6
        assert_eq!(h.data_transfer_elements(2, 16), 4 + 128 + 96);
    }

    #[test]
    fn degrees_sum_to_nnz_each_mode() {
        let t = small();
        let h = Hypergraph::build(&t);
        for md in &h.modes {
            let sum: u64 = md.degree.iter().map(|&d| d as u64).sum();
            assert_eq!(sum, t.nnz() as u64);
        }
        assert_eq!(h.modes[0].degree, vec![1, 1, 0, 2]);
        assert_eq!(h.modes[0].active, 3);
        assert_eq!(h.modes[0].max(), 2);
    }

    #[test]
    fn head_mass_behaviour() {
        let t = small();
        let h = Hypergraph::build(&t);
        // mode 0 degrees [1,1,0,2]: top-1 mass = 2/4
        assert!((h.modes[0].head_mass(1) - 0.5).abs() < 1e-12);
        assert!((h.modes[0].head_mass(4) - 1.0).abs() < 1e-12);
        // empty tensor
        let e = SparseTensor::new("e", vec![3]);
        let he = Hypergraph::build(&e);
        assert_eq!(he.modes[0].head_mass(3), 0.0);
    }

    #[test]
    fn head_mass_is_monotone_in_k() {
        // growing the head can only absorb more endpoint mass: for every
        // mode, head_mass(k) ≤ head_mass(k+1), anchored at 0 for k = 0
        // and exactly 1 once the head covers every active vertex
        let t = crate::tensor::gen::TensorSpec::custom("m", vec![60, 45, 30], 4_000, 0.9)
            .generate(17);
        let h = Hypergraph::build(&t);
        for (m, md) in h.modes.iter().enumerate() {
            assert_eq!(md.head_mass(0), 0.0, "mode {m}");
            let mut prev = 0.0;
            for k in 1..=md.degree.len() {
                let hm = md.head_mass(k);
                assert!(
                    hm >= prev - 1e-12,
                    "mode {m}: head_mass({k}) = {hm} < head_mass({}) = {prev}",
                    k - 1
                );
                assert!(hm <= 1.0 + 1e-12, "mode {m}: head_mass({k}) = {hm} above 1");
                prev = hm;
            }
            assert!((md.head_mass(md.degree.len()) - 1.0).abs() < 1e-12, "mode {m}");
            // k past the dimension saturates rather than panicking
            assert!((md.head_mass(md.degree.len() + 100) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn totals_cross_check_against_the_kernel_closed_forms() {
        // the hypergraph's §IV-A totals and mttkrp::trace::mode_totals
        // (now the spmttkrp kernel's closed forms) are two derivations of
        // the same formulas — they must agree exactly, mode by mode
        let t = crate::tensor::gen::TensorSpec::custom("x", vec![80, 25, 55, 12], 3_000, 0.7)
            .generate(23);
        let h = Hypergraph::build(&t);
        for rank in [8usize, 16, 32] {
            for mode in 0..t.n_modes() {
                let totals = crate::mttkrp::trace::mode_totals(&t, mode, rank);
                assert_eq!(
                    h.compute_per_mode(rank),
                    totals.compute_ops,
                    "rank {rank} mode {mode}"
                );
                assert_eq!(
                    h.data_transfer_elements(mode, rank),
                    totals.transfer_elements,
                    "rank {rank} mode {mode}"
                );
            }
        }
    }

    #[test]
    fn degrees_and_trace_agree_on_request_counts() {
        // per-mode degree sums are the factor-request totals of every
        // *other* mode's §IV-A formula: Σ_m≠d Σ_i degree_m[i] = (N−1)|T|
        let t = crate::tensor::gen::TensorSpec::custom("r", vec![40, 40, 40], 2_500, 1.1)
            .generate(31);
        let h = Hypergraph::build(&t);
        for mode in 0..t.n_modes() {
            let requests: u64 = h
                .modes
                .iter()
                .enumerate()
                .filter(|(m, _)| *m != mode)
                .map(|(_, md)| md.degree.iter().map(|&d| d as u64).sum::<u64>())
                .sum();
            let totals = crate::mttkrp::trace::mode_totals(&t, mode, 16);
            assert_eq!(requests, totals.factor_requests, "mode {mode}");
        }
    }
}
