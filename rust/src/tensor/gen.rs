//! Synthetic tensor generators reproducing the Table II FROSTT workloads.
//!
//! The build environment has no network access and the real tensors run to
//! 4.7 B nonzeros, so each Table II tensor is reproduced as a *synthetic
//! fingerprint*: exact mode dimensions and nonzero count (scaled by a
//! configurable factor), plus a per-mode **Zipf popularity exponent** that
//! reproduces the tensor's access-locality profile — the single property
//! that drives the paper's speedup spread (Fig. 7): tensors whose factor-
//! row accesses concentrate on few hot rows are on-chip-bandwidth-bound
//! (big O-SRAM wins, e.g. NELL-2 / PATENTS), tensors with flat access
//! distributions are DRAM-bound (small wins, e.g. NELL-1 / DELICIOUS).
//!
//! Exponents are calibrated from published FROSTT per-mode statistics
//! (dimension sizes vs nnz ⇒ average row reuse, plus the domain semantics
//! of each mode, e.g. REDDIT's word mode is a natural-language Zipf).
//! Real `.tns` files drop in via [`SparseTensor::load_tns`] unchanged.
//!
//! Scaling rule (`scaled(s)`): nnz × s, every dim × s^(1/N) — this keeps
//! the density column of Table II (and the relative working-set-to-cache
//! ratio once the accelerator config is scaled with
//! [`crate::accel::config::AcceleratorConfig::scaled`]).

use crate::tensor::coo::SparseTensor;
use crate::util::rng::{Rng, Zipf};

/// The seven FROSTT tensors of Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FrosttTensor {
    Nell1,
    Nell2,
    Patents,
    Lbnl,
    Delicious,
    Amazon,
    Reddit,
}

impl FrosttTensor {
    pub const ALL: [FrosttTensor; 7] = [
        FrosttTensor::Nell1,
        FrosttTensor::Nell2,
        FrosttTensor::Patents,
        FrosttTensor::Lbnl,
        FrosttTensor::Delicious,
        FrosttTensor::Amazon,
        FrosttTensor::Reddit,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FrosttTensor::Nell1 => "nell-1",
            FrosttTensor::Nell2 => "nell-2",
            FrosttTensor::Patents => "patents",
            FrosttTensor::Lbnl => "lbnl",
            FrosttTensor::Delicious => "delicious",
            FrosttTensor::Amazon => "amazon",
            FrosttTensor::Reddit => "reddit",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|t| t.name() == s)
    }
}

/// A generative specification: Table II numbers + locality fingerprint.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    /// Full-size mode dimensions (Table II).
    pub dims: Vec<u64>,
    /// Full-size nonzero count (Table II).
    pub nnz: u64,
    /// Per-mode Zipf exponent α (0 = uniform): the locality fingerprint.
    pub alpha: Vec<f64>,
    /// Scale factor applied by [`scaled`](Self::scaled) (1.0 = full size).
    pub scale: f64,
}

/// Table II presets. Dimensions and nnz are the paper's exact numbers.
///
/// α calibration rationale per tensor (mode order as in Table II). The
/// values are fit so that the *measured* per-mode cache hit rates under the
/// Table I cache land in the regime the paper reports for each tensor
/// (NELL-2/PATENTS on-chip-bound, NELL-1/DELICIOUS DRAM-bound); the domain
/// semantics justify the ordering:
/// * **NELL-1** (2.9M × 2.1M × 25.5M, 143.6M nnz) — entity/relation/entity
///   knowledge triples over multi-million-row factor matrices; accesses are
///   near-flat ⇒ DRAM-bound, the paper's low-speedup case. α = .55/.55/.35.
/// * **NELL-2** (12.1K × 9.2K × 28.8K, 76.9M) — the pruned dense NELL; tiny
///   dims give ~2 500 nnz per row on average ⇒ extreme on-chip reuse, the
///   paper's high-speedup case. α = 1.35/1.35/1.25.
/// * **PATENTS** (46 × 239.2K × 239.2K, 3.6B) — mode 0 has 46 rows (years):
///   always cache-resident; citation popularity is strongly head-heavy and
///   the density (1.4e-3) gives ~240 reuses per row. α = 1.45/1.4/1.4.
/// * **LBNL** (1.6K × 4.2K × 1.6K × 4.2K × 868.1K, 1.7M, 5 modes) — network
///   flows (src/dst addr/port, time); small address modes are bursty-hot,
///   the 868K time-expanded mode is cold. α = 1.0/.95/1.0/.95/.45.
/// * **DELICIOUS** (532.9K × 17.3M × 2.5M × 1.4K, 140.1M, 4 modes) — user ×
///   url × tag × date bookmarks; the 17.3M url mode is essentially flat ⇒
///   DRAM-bound like NELL-1. α = .65/.3/.75/1.1.
/// * **AMAZON** (4.8M × 1.8M × 1.8M, 1.7B) — user × item × word reviews;
///   word mode is language-Zipf (α ≈ 1.2 empirically), user/item flatter.
///   α = .6/.7/1.2.
/// * **REDDIT** (8.2M × 177K × 8.1M, 4.7B) — user × subreddit × word;
///   subreddit mode (177K) is strongly head-heavy. α = .6/1.25/1.1.
pub fn preset(t: FrosttTensor) -> TensorSpec {
    let (dims, nnz, alpha): (Vec<u64>, u64, Vec<f64>) = match t {
        FrosttTensor::Nell1 => {
            (vec![2_900_000, 2_100_000, 25_500_000], 143_600_000, vec![0.55, 0.55, 0.35])
        }
        FrosttTensor::Nell2 => (vec![12_100, 9_200, 28_800], 76_900_000, vec![1.3, 1.3, 1.2]),
        FrosttTensor::Patents => {
            (vec![46, 239_200, 239_200], 3_600_000_000, vec![1.45, 1.4, 1.4])
        }
        FrosttTensor::Lbnl => (
            vec![1_600, 4_200, 1_600, 4_200, 868_100],
            1_700_000,
            vec![1.0, 0.95, 1.0, 0.95, 0.6],
        ),
        FrosttTensor::Delicious => (
            vec![532_900, 17_300_000, 2_500_000, 1_400],
            140_100_000,
            vec![0.65, 0.3, 0.85, 1.1],
        ),
        FrosttTensor::Amazon => {
            (vec![4_800_000, 1_800_000, 1_800_000], 1_700_000_000, vec![0.6, 0.7, 1.3])
        }
        FrosttTensor::Reddit => {
            (vec![8_200_000, 177_000, 8_100_000], 4_700_000_000, vec![0.6, 1.25, 1.2])
        }
    };
    TensorSpec { name: t.name().to_string(), dims, nnz, alpha, scale: 1.0 }
}

impl TensorSpec {
    /// A generic spec for tests: given dims/nnz and a single α for all modes.
    pub fn custom(name: &str, dims: Vec<u64>, nnz: u64, alpha: f64) -> Self {
        let n = dims.len();
        TensorSpec { name: name.to_string(), dims, nnz, alpha: vec![alpha; n], scale: 1.0 }
    }

    /// Scale the workload: nnz × s, dims × s^(1/N) (≥ 4 per mode, and never
    /// above the original), preserving Table II's density ordering.
    pub fn scaled(mut self, s: f64) -> Self {
        assert!(s > 0.0 && s <= 1.0, "scale must be in (0, 1]");
        if (s - 1.0).abs() < f64::EPSILON {
            return self;
        }
        let n = self.dims.len() as f64;
        let dim_factor = s.powf(1.0 / n);
        for d in &mut self.dims {
            let scaled = (*d as f64 * dim_factor).round() as u64;
            *d = scaled.clamp(4.min(*d), *d);
        }
        self.nnz = ((self.nnz as f64 * s).round() as u64).max(1);
        self.scale = s;
        self.name = format!("{}@{:.0e}", self.name, s);
        self
    }

    /// Scaled density (should track Table II's column within rounding).
    pub fn density(&self) -> f64 {
        self.nnz as f64 / self.dims.iter().map(|&d| d as f64).product::<f64>()
    }

    /// Generate the tensor. Deterministic in `seed`.
    ///
    /// Each nonzero's mode-m coordinate is drawn Zipf(α_m) over the mode
    /// range, then label-scattered by a fixed odd multiplier so "hot" rows
    /// are spread across the index space (and therefore across cache sets)
    /// instead of sitting at 0..k; values are log-normal (positive, heavy
    /// tailed, like real count data).
    pub fn generate(&self, seed: u64) -> SparseTensor {
        let mut rng = Rng::new(seed ^ 0x5eed_7e45_0f00);
        let mut t = SparseTensor::new(&self.name, self.dims.clone());
        let zipfs: Vec<Zipf> =
            self.dims.iter().zip(&self.alpha).map(|(&d, &a)| Zipf::new(d as usize, a)).collect();
        // Per-mode odd multipliers for the label scatter (golden-ratio
        // derived, coprime with any power-of-two and almost any dim).
        let scatter: Vec<u64> = (0..self.dims.len() as u64)
            .map(|m| 0x9E3779B97F4A7C15u64.rotate_left(7 * m as u32) | 1)
            .collect();
        let n_modes = self.dims.len();
        let mut coords = vec![0u32; n_modes];
        let nnz = self.nnz.min(usize::MAX as u64) as usize;
        t.values.reserve(nnz);
        for col in &mut t.indices {
            col.reserve(nnz);
        }
        for _ in 0..nnz {
            for m in 0..n_modes {
                let raw = zipfs[m].sample(&mut rng) as u64;
                let dim = self.dims[m];
                coords[m] = ((raw.wrapping_mul(scatter[m])) % dim) as u32;
            }
            let v = rng.lognormal(0.0, 1.0) as f32;
            t.push(&coords, v);
        }
        t
    }
}

/// Uniform-random tensor for tests (α = 0 everywhere, unit-ish values).
pub fn random(dims: &[u64], nnz: usize, seed: u64) -> SparseTensor {
    TensorSpec::custom("random", dims.to_vec(), nnz as u64, 0.0).generate(seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hypergraph::Hypergraph;

    #[test]
    fn presets_match_table_ii() {
        // exact dims and nnz from the paper's Table II
        let n1 = preset(FrosttTensor::Nell1);
        assert_eq!(n1.dims, vec![2_900_000, 2_100_000, 25_500_000]);
        assert_eq!(n1.nnz, 143_600_000);
        let p = preset(FrosttTensor::Patents);
        assert_eq!(p.dims[0], 46);
        assert_eq!(p.nnz, 3_600_000_000);
        let l = preset(FrosttTensor::Lbnl);
        assert_eq!(l.dims.len(), 5);
        let d = preset(FrosttTensor::Delicious);
        assert_eq!(d.dims.len(), 4);
        // density column ordering: patents ≫ nell-2 ≫ the web-scale ones
        assert!(p.density() > preset(FrosttTensor::Nell2).density());
        assert!(preset(FrosttTensor::Nell2).density() > n1.density());
    }

    #[test]
    fn all_names_roundtrip() {
        for t in FrosttTensor::ALL {
            assert_eq!(FrosttTensor::from_name(t.name()), Some(t));
        }
        assert_eq!(FrosttTensor::from_name("nope"), None);
    }

    #[test]
    fn scaling_preserves_density_ordering() {
        let s = 1.0 / 1024.0;
        let scaled: Vec<TensorSpec> =
            FrosttTensor::ALL.iter().map(|&t| preset(t).scaled(s)).collect();
        let full: Vec<TensorSpec> = FrosttTensor::ALL.iter().map(|&t| preset(t)).collect();
        for i in 0..full.len() {
            for j in 0..full.len() {
                if full[i].density() > 10.0 * full[j].density() {
                    assert!(
                        scaled[i].density() > scaled[j].density(),
                        "{} vs {}",
                        full[i].name,
                        full[j].name
                    );
                }
            }
        }
    }

    #[test]
    fn scaled_nnz_and_dims_shrink() {
        let s = preset(FrosttTensor::Nell2).scaled(1.0 / 256.0);
        assert_eq!(s.nnz, (76_900_000f64 / 256.0).round() as u64);
        assert!(s.dims[0] < 12_100 && s.dims[0] >= 4);
        assert!(s.name.contains("nell-2@"));
    }

    #[test]
    fn tiny_dims_clamp() {
        let s = preset(FrosttTensor::Patents).scaled(1e-6);
        assert!(s.dims[0] >= 4, "mode-0 dim clamped: {:?}", s.dims);
        assert!(s.nnz >= 1);
    }

    #[test]
    fn scaled_preserves_density_within_rounding() {
        // the scaling rule (nnz × s, dims × s^(1/N)) keeps density
        // invariant up to dim rounding: nnz·s / (Πdims · s) = density.
        // Use dims large enough that the ≥4 clamp never engages.
        let spec = TensorSpec::custom("d", vec![20_000, 30_000, 40_000], 5_000_000, 0.5);
        let d0 = spec.density();
        for s in [1.0 / 8.0, 1.0 / 64.0, 1.0 / 512.0] {
            let sc = spec.clone().scaled(s);
            let ratio = sc.density() / d0;
            assert!(
                (0.8..1.25).contains(&ratio),
                "scale {s}: density ratio {ratio} drifted (got {}, want ~{d0})",
                sc.density()
            );
        }
        // and scaling is what the name says: strictly fewer nonzeros,
        // strictly smaller dims
        let sc = spec.scaled(1.0 / 64.0);
        assert_eq!(sc.nnz, 5_000_000 / 64);
        assert!(sc.dims.iter().zip(&[20_000u64, 30_000, 40_000]).all(|(&a, &b)| a < b));
    }

    #[test]
    fn generate_is_deterministic_per_seed_for_every_preset() {
        // identical (spec, seed) ⇒ identical tensors, for all seven
        // Table II fingerprints — the sweep's workload-sharing and the
        // cross-engine comparisons both assume it
        let s = 1.0 / 262_144.0;
        for ft in FrosttTensor::ALL {
            let spec = preset(ft).scaled(s);
            let a = spec.generate(42);
            let b = spec.generate(42);
            assert_eq!(a, b, "{}", spec.name);
            let c = spec.generate(43);
            assert_ne!(a, c, "{} must vary with the seed", spec.name);
        }
        // the uniform helper too
        assert_eq!(random(&[30, 30], 500, 9), random(&[30, 30], 500, 9));
    }

    #[test]
    fn generation_is_deterministic_and_valid() {
        let spec = preset(FrosttTensor::Nell2).scaled(1.0 / 8192.0);
        let a = spec.generate(7);
        let b = spec.generate(7);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert_eq!(a.nnz() as u64, spec.nnz);
        let c = spec.generate(8);
        assert_ne!(a, c);
    }

    #[test]
    fn locality_fingerprint_orders_head_mass() {
        // NELL-2 must concentrate accesses far more than NELL-1 at equal
        // relative head size — this is the property Fig. 7 rests on.
        let s = 1.0 / 32768.0;
        let hot = preset(FrosttTensor::Nell2).scaled(s).generate(1);
        let cold = preset(FrosttTensor::Nell1).scaled(s * 8.0).generate(1);
        let hh = Hypergraph::build(&hot);
        let hc = Hypergraph::build(&cold);
        // head = top 1% of rows of mode 1
        let mh = hh.modes[1].head_mass((hot.dims[1] as usize / 100).max(1));
        let mc = hc.modes[1].head_mass((cold.dims[1] as usize / 100).max(1));
        assert!(
            mh > mc + 0.2,
            "nell-2 head mass {mh:.3} should dominate nell-1 {mc:.3}"
        );
    }

    #[test]
    fn values_are_positive_lognormal() {
        let t = random(&[50, 50], 2000, 3);
        // uniform generator: values come from lognormal(0,1) > 0
        let spec = TensorSpec::custom("v", vec![100], 500, 0.5);
        let t2 = spec.generate(1);
        assert!(t2.values.iter().all(|&v| v > 0.0));
        assert_eq!(t.nnz(), 2000);
    }

    #[test]
    fn custom_spec_generates_requested_shape() {
        let t = TensorSpec::custom("c", vec![10, 20, 30, 40], 123, 0.7).generate(9);
        assert_eq!(t.n_modes(), 4);
        assert_eq!(t.nnz(), 123);
        t.validate().unwrap();
    }
}
