//! Sparse tensor substrate.
//!
//! The paper evaluates spMTTKRP over seven FROSTT tensors (Table II). This
//! module provides everything the simulator and the numeric driver need:
//!
//! * [`coo`] — N-mode coordinate-format sparse tensors with FROSTT `.tns`
//!   text I/O and validation.
//! * [`csf`] — per-output-mode compressed slice ordering (Algorithm 1 walks
//!   nonzeros grouped by the output-mode index, so no intermediate partial
//!   sums leave the PE).
//! * [`gen`] — synthetic generators that reproduce each Table II tensor's
//!   shape / density / per-mode locality fingerprint at configurable scale,
//!   plus generic random tensors for tests.
//! * [`hypergraph`] — the paper's hypergraph view H=(V,E) of a tensor
//!   (§IV-A): vertices = mode indices, hyperedges = nonzeros.
//! * [`remap`] — locality-enhancing index remapping derived from the
//!   hypergraph (degree-sorted relabeling), the "mapping of X into memory"
//!   the paper optimizes per mode.

pub mod coo;
pub mod csf;
pub mod gen;
pub mod hypergraph;
pub mod remap;
