//! DMA engines of the PE memory controller (§IV-A access types 2 and 3).
//!
//! * [`stream`] — double-buffered streaming DMA for sequential transfers
//!   (tensor nonzeros in, output factor rows out).
//! * [`elementwise`] — element-wise DMA for accesses with no spatial or
//!   temporal locality (bypasses the cache entirely).

pub mod elementwise;
pub mod stream;
