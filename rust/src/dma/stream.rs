//! Streaming DMA with double-buffered on-chip staging (§IV-A type 2:
//! "Load/store operations on all requested data with minimum latency from
//! memory").
//!
//! A stream moves `bytes` sequentially between DRAM and the PE. The DMA
//! stages data through its on-chip buffer (64 KB, Table I), so the
//! sustained rate is the *minimum* of the DRAM channel's stream bandwidth
//! and the buffer array's port bandwidth — with E-SRAM buffers the port
//! can genuinely throttle a DDR4-2400 stream (8 words × 4 B = 32 B/cycle
//! vs 32.64 B/cycle DRAM), one of the second-order effects the paper's
//! "minimum latency" claim glosses over; with O-SRAM the buffer is never
//! the limit. Double buffering overlaps fill and drain, so no ×2.

use crate::cache::pipeline::ArrayTiming;
use crate::mem::dram::DramConfig;

/// Timing/occupancy model of one streaming DMA engine.
#[derive(Clone, Debug)]
pub struct StreamDma {
    /// Staging-buffer array timing (technology-dependent).
    pub buffer: ArrayTiming,
    /// Staging-buffer capacity, bytes.
    pub buffer_bytes: usize,
}

/// Cycles + traffic produced by one stream transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamCharge {
    /// Occupancy on the DRAM channel, fabric cycles.
    pub dram_cycles: f64,
    /// Occupancy on the staging buffer's ports, fabric cycles.
    pub buffer_cycles: f64,
    /// Words moved through the on-chip buffer (×2: fill + drain) — feeds
    /// the switching-energy accounting (`S_active` of Eq. 3).
    pub buffer_words: u64,
}

impl StreamDma {
    pub fn new(buffer: ArrayTiming, buffer_bytes: usize) -> Self {
        StreamDma { buffer, buffer_bytes }
    }

    /// Effective sustained stream rate, bytes per fabric cycle.
    pub fn effective_bytes_per_cycle(&self, dram: &DramConfig) -> f64 {
        let dram_rate = dram.stream_bytes_per_cycle();
        let buf_rate = self.buffer.words_per_fabric_cycle * 4.0;
        dram_rate.min(buf_rate)
    }

    /// Charge a sequential transfer of `bytes`.
    pub fn stream(&self, dram: &DramConfig, bytes: u64) -> StreamCharge {
        let words = bytes.div_ceil(4);
        StreamCharge {
            dram_cycles: dram.stream_cycles(bytes),
            // fill + drain both touch the buffer, double-buffering overlaps
            // them with the transfer, so occupancy = words / rate (not ×2)
            // but the energy sees both passes:
            buffer_cycles: self.buffer.occupancy_cycles(words as f64),
            buffer_words: words * 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;
    use crate::mem::tech::{MemTechnology, FABRIC_HZ};

    fn dma(tech: &MemTechnology, banks: usize) -> StreamDma {
        let t = ArrayTiming::new(tech, FABRIC_HZ, banks);
        StreamDma::new(t, 64 * 1024)
    }

    #[test]
    fn esram_buffer_throttles_ddr4_slightly() {
        let d = DramConfig::default();
        let s = dma(&esram(), 4);
        let eff = s.effective_bytes_per_cycle(&d);
        // 8 words × 4 B = 32 B/cycle < 32.64 B/cycle DRAM
        assert!((eff - 32.0).abs() < 1e-9, "eff={eff}");
        assert!(eff < d.stream_bytes_per_cycle());
    }

    #[test]
    fn osram_buffer_never_the_limit() {
        let d = DramConfig::default();
        let s = dma(&osram(), 1);
        let eff = s.effective_bytes_per_cycle(&d);
        assert!((eff - d.stream_bytes_per_cycle()).abs() < 1e-9);
    }

    #[test]
    fn charge_accounts_dram_buffer_and_energy_words() {
        let d = DramConfig::default();
        let s = dma(&osram(), 1);
        let c = s.stream(&d, 64 * 1024);
        assert!((c.dram_cycles - d.stream_cycles(64 * 1024)).abs() < 1e-9);
        assert_eq!(c.buffer_words, 2 * 16 * 1024);
        assert!(c.buffer_cycles > 0.0);
        // O-SRAM buffer occupancy is far below the DRAM time
        assert!(c.buffer_cycles < c.dram_cycles / 10.0);
    }

    #[test]
    fn zero_and_odd_sizes() {
        let d = DramConfig::default();
        let s = dma(&esram(), 4);
        let c0 = s.stream(&d, 0);
        assert_eq!(c0.buffer_words, 0);
        assert_eq!(c0.dram_cycles, 0.0);
        let c5 = s.stream(&d, 5); // rounds to 2 words
        assert_eq!(c5.buffer_words, 4);
    }
}
