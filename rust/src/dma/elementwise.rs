//! Element-wise DMA (§IV-A type 3: "DMAs can also be used to access data
//! with no spatial and temporal locality").
//!
//! Every request is an independent DRAM random access staged through the
//! DMA's on-chip buffer; no reuse is attempted. The memory controller
//! routes a factor matrix here when its measured reuse potential is too
//! low for the cache to pay off (the cold alternative of the three access
//! types) and routes output-row stores here when the output mode is too
//! scattered to stream.

use crate::cache::pipeline::ArrayTiming;
use crate::mem::dram::DramConfig;

/// Timing/occupancy model of one element-wise DMA engine.
#[derive(Clone, Debug)]
pub struct ElementDma {
    pub buffer: ArrayTiming,
}

/// Cycles + traffic of one element-wise transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElementCharge {
    pub dram_cycles: f64,
    pub buffer_cycles: f64,
    pub buffer_words: u64,
}

impl ElementDma {
    pub fn new(buffer: ArrayTiming) -> Self {
        ElementDma { buffer }
    }

    /// Charge one independent access of `bytes` (≥ one DRAM burst).
    pub fn access(&self, dram: &DramConfig, bytes: u64) -> ElementCharge {
        let words = bytes.div_ceil(4);
        ElementCharge {
            dram_cycles: dram.random_access_cycles(bytes),
            buffer_cycles: self.buffer.occupancy_cycles(words as f64),
            buffer_words: words * 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;

    #[test]
    fn elementwise_pays_random_access_cost() {
        let d = DramConfig::default();
        let e = ElementDma::new(ArrayTiming::new(&esram(), FABRIC_HZ, 4));
        let c = e.access(&d, 64);
        assert!((c.dram_cycles - d.random_access_cycles(64)).abs() < 1e-12);
        assert_eq!(c.buffer_words, 32);
        // element-wise is slower per byte than streaming even with
        // bank-level overlap
        assert!(c.dram_cycles > 2.0 * d.stream_cycles(64));
    }

    #[test]
    fn technology_changes_buffer_not_dram() {
        let d = DramConfig::default();
        let ee = ElementDma::new(ArrayTiming::new(&esram(), FABRIC_HZ, 4));
        let eo = ElementDma::new(ArrayTiming::new(&osram(), FABRIC_HZ, 1));
        let ce = ee.access(&d, 64);
        let co = eo.access(&d, 64);
        assert_eq!(ce.dram_cycles, co.dram_cycles); // DRAM identical
        assert!(co.buffer_cycles < ce.buffer_cycles); // buffer is not
    }
}
