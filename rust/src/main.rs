//! `photon-mttkrp` — CLI for the O-SRAM spMTTKRP performance model.
//!
//! ```text
//! photon-mttkrp info [--tensors]          platform + Table I/II echo
//! photon-mttkrp simulate --tensor nell-2 [--scale S] [--tech both] [--mode M]
//! photon-mttkrp reproduce [--scale S]     all paper tables + figures
//! photon-mttkrp cpals [--rank R] [--iters N] [--artifacts]
//! photon-mttkrp mttkrp <file.tns> [--mode M] [--artifacts]
//! ```

use photon_mttkrp::accel::config::AcceleratorConfig;
use photon_mttkrp::coordinator::cpals::{cp_als, low_rank_tensor, CpAlsConfig};
use photon_mttkrp::coordinator::driver::{compare_technologies, simulate_mode, Compute};
use photon_mttkrp::mem::tech::MemTech;
use photon_mttkrp::mttkrp::reference::FactorMatrix;
use photon_mttkrp::report::paper;
use photon_mttkrp::runtime::client::Runtime;
use photon_mttkrp::tensor::coo::SparseTensor;
use photon_mttkrp::tensor::gen::{preset, FrosttTensor};
use photon_mttkrp::util::cli::{CliError, Command, Parsed};
use photon_mttkrp::util::configfile::Config;

fn cli() -> Command {
    Command::new("photon-mttkrp", "O-SRAM vs E-SRAM spMTTKRP performance model")
        .subcommand(
            Command::new("info", "show platform, Table I config and the tensor suite")
                .flag("tensors", 't', "also print Table II")
                .opt("config", "FILE", "accelerator config file (TOML subset)", None),
        )
        .subcommand(
            Command::new("simulate", "simulate one tensor on one or both technologies")
                .opt("tensor", "NAME", "FROSTT preset name (e.g. nell-2)", Some("nell-2"))
                .opt("scale", "S", "workload scale factor", Some("0.001"))
                .opt("seed", "N", "generator seed", Some("42"))
                .opt("mode", "M", "single output mode (default: all)", None)
                .opt("tech", "T", "e-sram | o-sram | both", Some("both"))
                .opt("config", "FILE", "accelerator config file", None),
        )
        .subcommand(
            Command::new("reproduce", "regenerate every paper table and figure")
                .opt("scale", "S", "workload scale factor", Some("0.001"))
                .opt("seed", "N", "generator seed", Some("42"))
                .flag("markdown", 'm', "emit Markdown instead of ASCII"),
        )
        .subcommand(
            Command::new("cpals", "run CP-ALS end-to-end (fit curve)")
                .opt("rank", "R", "decomposition rank", Some("16"))
                .opt("iters", "N", "max ALS iterations", Some("20"))
                .opt("nnz", "N", "synthetic tensor nonzeros", Some("50000"))
                .opt("dim", "D", "mode dimension", Some("200"))
                .opt("seed", "N", "seed", Some("42"))
                .flag("artifacts", 'a', "use the PJRT artifacts (default: CPU reference)"),
        )
        .subcommand(
            Command::new("mttkrp", "run spMTTKRP on a FROSTT .tns file")
                .positional("input", "path to .tns file", true)
                .opt("mode", "M", "output mode", Some("0"))
                .opt("rank", "R", "rank (16 or 32 for --artifacts)", Some("16"))
                .flag("artifacts", 'a', "use the PJRT artifacts"),
        )
}

fn load_config(p: &Parsed) -> Result<AcceleratorConfig, String> {
    let mut cfg = AcceleratorConfig::paper_default();
    if let Some(path) = p.get("config") {
        let file = Config::load(std::path::Path::new(path)).map_err(|e| e.to_string())?;
        cfg.apply_config(&file)?;
    }
    Ok(cfg)
}

fn run() -> Result<(), String> {
    let cmd = cli();
    let p = cmd.parse_env().map_err(|e: CliError| e.to_string())?;
    if p.help_requested || p.subcommand().is_none() {
        println!("{}", cmd.help());
        return Ok(());
    }
    match p.subcommand().unwrap() {
        "info" => {
            let cfg = load_config(&p)?;
            println!("{}", paper::table_i(&cfg).render_ascii());
            println!("{}", paper::table_iii().render_ascii());
            println!("{}", paper::table_iv(&cfg).render_ascii());
            if p.flag("tensors") {
                println!("{}", paper::table_ii(1.0).render_ascii());
            }
        }
        "simulate" => {
            let cfg_base = load_config(&p)?;
            let scale = p.get_f64("scale").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let name = p.get("tensor").unwrap();
            let ft = FrosttTensor::from_name(name)
                .ok_or_else(|| format!("unknown tensor `{name}`"))?;
            let cfg = cfg_base.scaled(scale);
            let tensor = preset(ft).scaled(scale).generate(seed);
            eprintln!("generated {} ({} nnz)", tensor.name, tensor.nnz());
            match p.get("tech").unwrap() {
                "both" => {
                    let c = compare_technologies(&tensor, &cfg);
                    for (m, s) in c.mode_speedups().iter().enumerate() {
                        println!(
                            "M{m}: e-sram {:.3e}s  o-sram {:.3e}s  speedup {s:.2}x  (hit {:.1}% / bottleneck {})",
                            c.esram.modes[m].runtime_s(),
                            c.osram.modes[m].runtime_s(),
                            c.osram.modes[m].hit_rate() * 100.0,
                            c.esram.modes[m].bottleneck().name(),
                        );
                    }
                    println!(
                        "total: speedup {:.2}x  energy savings {:.2}x",
                        c.total_speedup(),
                        c.energy_savings()
                    );
                }
                t @ ("e-sram" | "o-sram") => {
                    let tech = if t == "e-sram" { MemTech::ESram } else { MemTech::OSram };
                    let modes: Vec<usize> = match p.get("mode") {
                        Some(m) => vec![m.parse().map_err(|e| format!("--mode: {e}"))?],
                        None => (0..tensor.n_modes()).collect(),
                    };
                    for m in modes {
                        let r = simulate_mode(&tensor, m, &cfg, tech);
                        println!(
                            "M{m} [{}]: {:.3e}s  ({:.0} cycles, hit {:.1}%, bottleneck {})",
                            tech.name(),
                            r.runtime_s(),
                            r.runtime_cycles(),
                            r.hit_rate() * 100.0,
                            r.bottleneck().name()
                        );
                    }
                }
                other => return Err(format!("unknown tech `{other}`")),
            }
        }
        "reproduce" => {
            let scale = p.get_f64("scale").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let cfg = AcceleratorConfig::paper_default();
            let render = |t: &photon_mttkrp::util::table::Table| {
                if p.flag("markdown") {
                    t.render_markdown()
                } else {
                    t.render_ascii()
                }
            };
            println!("{}", render(&paper::table_i(&cfg)));
            println!("{}", render(&paper::table_ii(scale)));
            println!("{}", render(&paper::table_iii()));
            println!("{}", render(&paper::table_iv(&cfg)));
            eprintln!("running the 7-tensor suite at scale {scale:.1e} ...");
            let results = paper::evaluate_suite(scale, seed);
            println!("{}", render(&paper::fig7(&results)));
            println!("{}", render(&paper::fig8(&results)));
        }
        "cpals" => {
            let rank = p.get_usize("rank").map_err(|e| e.to_string())?;
            let iters = p.get_usize("iters").map_err(|e| e.to_string())?;
            let nnz = p.get_usize("nnz").map_err(|e| e.to_string())?;
            let dim = p.get_u64("dim").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let tensor = low_rank_tensor(&[dim, dim, dim], rank / 2, nnz, 0.01, seed);
            let cfg = CpAlsConfig { rank, max_iters: iters, tol: 1e-6, seed };
            let rt;
            let compute = if p.flag("artifacts") {
                rt = Runtime::from_default_dir().map_err(|e| e.to_string())?;
                Compute::Artifacts(&rt)
            } else {
                Compute::Reference
            };
            let model = cp_als(&tensor, &cfg, &compute).map_err(|e| e.to_string())?;
            for s in &model.history {
                println!("iter {:>3}: fit {:.6} (delta {:.2e})", s.iter, s.fit, s.fit_delta);
            }
            println!("final fit: {:.6}", model.final_fit());
        }
        "mttkrp" => {
            let path = &p.positionals[0];
            let mode = p.get_usize("mode").map_err(|e| e.to_string())?;
            let rank = p.get_usize("rank").map_err(|e| e.to_string())?;
            let tensor = SparseTensor::load_tns(std::path::Path::new(path))
                .map_err(|e| e.to_string())?;
            let factors: Vec<FactorMatrix> = tensor
                .dims
                .iter()
                .enumerate()
                .map(|(m, &d)| FactorMatrix::random(d as usize, rank, 7 + m as u64))
                .collect();
            let rt;
            let compute = if p.flag("artifacts") {
                rt = Runtime::from_default_dir().map_err(|e| e.to_string())?;
                Compute::Artifacts(&rt)
            } else {
                Compute::Reference
            };
            let t0 = std::time::Instant::now();
            let out = photon_mttkrp::coordinator::driver::compute_mode(
                &compute, &tensor, mode, &factors,
            )
            .map_err(|e| e.to_string())?;
            println!(
                "mttkrp mode {mode}: {} nnz -> {}x{} output in {:.3}s (frobenius {:.4})",
                tensor.nnz(),
                out.rows,
                out.rank,
                t0.elapsed().as_secs_f64(),
                out.frobenius()
            );
        }
        other => return Err(format!("unknown subcommand `{other}`")),
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
