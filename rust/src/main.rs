//! `photon-mttkrp` — CLI for the multi-technology spMTTKRP performance
//! model.
//!
//! ```text
//! photon-mttkrp info [--tensors] [--config FILE]
//!     platform + Table I/III/IV echo + the technology registry listing
//! photon-mttkrp simulate --tensor nell-2 [--scale S] [--seed N]
//!     [--tech both|all|<name>] [--mode M] [--engine analytic|event]
//!     [--kernel spmttkrp|spttm|spmm] [--levels SPEC] [--threads T]
//!     [--chunk-nnz N] [--sample-rate R] [--sample-seed N] [--json]
//!     [--trace-out FILE] [--config FILE]
//!     one tensor on one/both/all technologies; with --engine event it
//!     also prints the analytic-vs-event cycle delta (per mode for a
//!     single technology, per technology for both/all); --json emits
//!     the machine-readable comparison instead of the tables
//! photon-mttkrp sweep [--tensor N]... [--tech T]... [--scale S]... [--mode M]...
//!     [--engine analytic|event] [--kernel K] [--seed N] [--threads T]
//!     [--chunk-nnz N] [--sample-rate R] [--sample-seed N]
//!     [--trace-out FILE] [--config FILE]
//!     parallel {tensor x mode x tech x scale} design-space sweep
//! photon-mttkrp explore [--tensor N] [--scale S] [--seed N] [--tech T]...
//!     [--kernel K]... [--axes KNOB=V1,V2,...]... [--budget-mm2 X]
//!     [--exclude-wafer-scale] [--objective runtime|energy|edp|area]
//!     [--top N] [--threads T] [--chunk-nnz N] [--sample-rate R]
//!     [--sample-seed N] [--json FILE] [--cache-dir DIR] [--no-profile]
//!     [--compact-cache] [--trace-out FILE] [--config FILE]
//!     Pareto-frontier search over {config knobs x tech x kernel}:
//!     analytic screen of the full grid (reuse-distance profiled — one
//!     stream walk prices every cache geometry; --no-profile screens
//!     each candidate with its own walk instead), sampled event-engine
//!     confirmation of the whole grid, exact event pass over the
//!     frontier, any rank flip reported as a delta line; --cache-dir
//!     persists every evaluation, so a warm re-run answers from disk
//!     with a bit-identical frontier; --compact-cache rewrites the
//!     persistent log without dead (key-shadowed) records and exits
//! photon-mttkrp serve [--socket PATH] [--cache-dir DIR] [--threads T]
//!     [--batch N] [--log-json] [--trace-out FILE]
//!     long-lived NDJSON evaluation daemon (design-space-as-a-service):
//!     simulate/sweep/explore/metrics requests on stdin or a Unix
//!     socket, answered in order; batch windows share workload
//!     preparation, and warm requests are answered from the (optionally
//!     persistent) cache without touching either engine; the metrics
//!     verb snapshots the cache counters and the process metrics
//!     registry
//! photon-mttkrp reproduce [--scale S] [--seed N] [--markdown]
//!     all paper tables + figures + the engine cross-validation table
//!     + the explore frontier table + the hierarchy table
//! photon-mttkrp cpals [--rank R] [--iters N] [--nnz N] [--dim D] [--seed N] [--artifacts]
//! photon-mttkrp mttkrp <file.tns> [--mode M] [--rank R] [--artifacts]
//! ```
//!
//! `--tech` accepts any name registered in the technology registry
//! (builtin: `e-sram`, `o-sram`, `o-sram-imc`, `e-uram`; config files add
//! more via `[tech.<name>]` sections). `--engine` selects the simulation
//! backend: `analytic` (the paper's roofline model, the default) or
//! `event` (the cycle-level contention replay that bounds its error —
//! see docs/ARCHITECTURE.md and EXPERIMENTS.md §Cross-validation).
//! `--kernel` selects the sparse workload streamed through the engines:
//! `spmttkrp` (the paper's CP-ALS kernel, the default), `spttm` (Tucker
//! TTM-chain) or `spmm` (sparse × dense matrix — see EXPERIMENTS.md
//! §Kernels). `--levels` configures the multi-level on-chip memory
//! hierarchy between the PE caches and DRAM, outermost first — e.g.
//! `--levels sram:256KiB:8banks,local:4KiB:db` (capacity, optional
//! `Nbanks`/`lineN`/`db` double-buffer tokens; EXPERIMENTS.md
//! §Hierarchy); omitted, the model is the paper's degenerate
//! single-level stack, bit-identical to the pre-hierarchy output.
//! `--threads` and `--chunk-nnz` are host-execution knobs
//! (per-PE thread budget, access-stream chunk granularity): they change
//! how fast the simulator runs, never what it reports. `--sample-rate`
//! (with `--sample-seed`) is the one estimate-changing speed knob: below
//! 1.0 the event engine times only a seeded subset of chunks and
//! extrapolates stall cycles with a reported confidence band (functional
//! counts stay exact); 1.0 is bit-identical to the full replay, and the
//! analytic engine ignores it. `explore` defaults to 0.25 for its
//! grid-wide event confirmation but always pins the printed frontier
//! numbers with an exact pass.
//!
//! `--trace-out FILE` (simulate / sweep / explore / serve) arms the
//! span recorder for the run and writes a Chrome trace-event JSON file
//! on exit — load it in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing` to see explore phases, profiler stream walks,
//! per-engine mode runs and serve batch windows on a timeline.
//! Recording is off by default and never changes what the model
//! reports (see docs/ARCHITECTURE.md §Observability). Daemon stderr is
//! structured: `PHOTON_LOG=error|warn|info|debug` filters it and
//! `serve --log-json` switches it to NDJSON.

use photon_mttkrp::accel::config::AcceleratorConfig;
use photon_mttkrp::coordinator::cpals::{cp_als, low_rank_tensor, CpAlsConfig};
use photon_mttkrp::coordinator::driver::{
    apply_memory_mapping, compare_technologies_on_engines, paper_pair, Compute, EngineDelta,
    TechComparison,
};
use photon_mttkrp::explore::{
    self, frontier_table, run_explore, run_explore_with_cache, Axis, DesignSpace, EvalCache,
    ExploreSpec, ObjectiveKind,
};
use photon_mttkrp::kernel::KernelKind;
use photon_mttkrp::mem::registry;
use photon_mttkrp::mem::tech::MemTechnology;
use photon_mttkrp::mttkrp::reference::FactorMatrix;
use photon_mttkrp::obs;
use photon_mttkrp::report::export::comparison_json;
use photon_mttkrp::report::paper;
use photon_mttkrp::serve::ServeOptions;
use photon_mttkrp::runtime::client::Runtime;
use photon_mttkrp::sim::sweep::{self, SweepSpec};
use photon_mttkrp::sim::{EngineKind, SampleSpec, SimBudget};
use photon_mttkrp::tensor::coo::SparseTensor;
use photon_mttkrp::tensor::csf::ModeView;
use photon_mttkrp::tensor::gen::{preset, FrosttTensor};
use photon_mttkrp::util::cli::{CliError, Command, Parsed};
use photon_mttkrp::util::configfile::Config;

fn cli() -> Command {
    Command::new("photon-mttkrp", "multi-technology spMTTKRP performance model")
        .subcommand(
            Command::new("info", "show platform, Table I config, tensors and the tech registry")
                .flag("tensors", 't', "also print Table II")
                .opt("config", "FILE", "accelerator config file (TOML subset)", None),
        )
        .subcommand(
            Command::new("simulate", "simulate one tensor on one, both or all technologies")
                .opt("tensor", "NAME", "FROSTT preset name (e.g. nell-2)", Some("nell-2"))
                .opt("scale", "S", "workload scale factor", Some("0.001"))
                .opt("seed", "N", "generator seed", Some("42"))
                .opt("mode", "M", "single output mode (default: all)", None)
                .opt(
                    "tech",
                    "T",
                    "both | all | any registered technology name",
                    Some("both"),
                )
                .opt("engine", "E", "simulation engine: analytic | event", Some("analytic"))
                .opt(
                    "kernel",
                    "K",
                    "sparse kernel: spmttkrp | spttm | spmm",
                    Some("spmttkrp"),
                )
                .opt(
                    "levels",
                    "SPEC",
                    "memory-hierarchy stack, outermost first: \
                     name:capacity[:Nbanks][:lineN][:db],... (default: none)",
                    None,
                )
                .opt("threads", "T", "per-PE simulator threads (0 = all cores)", Some("0"))
                .opt(
                    "chunk-nnz",
                    "N",
                    "access-stream chunk granularity in nonzeros",
                    Some("65536"),
                )
                .opt(
                    "sample-rate",
                    "R",
                    "event-replay chunk sampling rate in (0, 1]; 1 = exact",
                    Some("1.0"),
                )
                .opt("sample-seed", "N", "chunk-sampling seed", Some("0"))
                .flag("json", 'j', "emit the comparison as JSON instead of tables")
                .opt("trace-out", "FILE", "record spans; write a Chrome trace on exit", None)
                .opt("config", "FILE", "accelerator config file", None),
        )
        .subcommand(
            Command::new("sweep", "parallel {tensor x mode x tech x scale} design-space sweep")
                .opt_repeated(
                    "tensor",
                    "NAME",
                    "FROSTT preset (repeatable; default: nell-2 nell-1 patents)",
                )
                .opt_repeated("tech", "T", "technology name or `all` (repeatable; default: all)")
                .opt_repeated("scale", "S", "workload scale (repeatable; default: 0.001)")
                .opt_repeated("mode", "M", "output mode (repeatable; default: every mode)")
                .opt("engine", "E", "simulation engine: analytic | event", Some("analytic"))
                .opt(
                    "kernel",
                    "K",
                    "sparse kernel: spmttkrp | spttm | spmm",
                    Some("spmttkrp"),
                )
                .opt(
                    "levels",
                    "SPEC",
                    "memory-hierarchy stack, outermost first: \
                     name:capacity[:Nbanks][:lineN][:db],... (default: none)",
                    None,
                )
                .opt("seed", "N", "generator seed", Some("42"))
                .opt("threads", "T", "OS threads (0 = all cores)", Some("0"))
                .opt(
                    "chunk-nnz",
                    "N",
                    "access-stream chunk granularity in nonzeros",
                    Some("65536"),
                )
                .opt(
                    "sample-rate",
                    "R",
                    "event-replay chunk sampling rate in (0, 1]; 1 = exact",
                    Some("1.0"),
                )
                .opt("sample-seed", "N", "chunk-sampling seed", Some("0"))
                .opt("trace-out", "FILE", "record spans; write a Chrome trace on exit", None)
                .opt("config", "FILE", "accelerator config file (may define [tech.*])", None),
        )
        .subcommand(
            Command::new("explore", "Pareto-frontier search over accelerator configurations")
                .opt("tensor", "NAME", "FROSTT preset name (e.g. nell-2)", Some("nell-2"))
                .opt("scale", "S", "workload scale factor (tensor only)", Some("0.001"))
                .opt("seed", "N", "generator seed", Some("42"))
                .opt_repeated("tech", "T", "technology name or `all` (repeatable; default: all)")
                .opt_repeated(
                    "kernel",
                    "K",
                    "sparse kernel or `all` (repeatable; default: spmttkrp)",
                )
                .opt_repeated(
                    "axes",
                    "KNOB=V1,V2,...",
                    "design-space axis (n_pes | cache_lines | cache_assoc | bank_factor | \
                     rank | sram_kib | local_kib); default: n_pes=2,4,8 cache_lines=4096,8192",
                )
                .opt(
                    "levels",
                    "SPEC",
                    "base memory-hierarchy stack every candidate inherits, outermost first: \
                     name:capacity[:Nbanks][:lineN][:db],... (default: none)",
                    None,
                )
                .opt("budget-mm2", "MM2", "drop candidates whose design area exceeds this", None)
                .flag(
                    "exclude-wafer-scale",
                    'w',
                    "drop candidates larger than one reticle (858 mm^2)",
                )
                .opt(
                    "objective",
                    "O",
                    "frontier ranking: runtime | energy | edp | area",
                    Some("edp"),
                )
                .opt("top", "N", "frontier rows to print (0 = all)", Some("10"))
                .opt("threads", "T", "OS threads (0 = all cores)", Some("0"))
                .opt(
                    "chunk-nnz",
                    "N",
                    "access-stream chunk granularity in nonzeros",
                    Some("65536"),
                )
                .opt(
                    "sample-rate",
                    "R",
                    "grid-wide event confirmation sampling rate in (0, 1]; 1 = exact",
                    Some("0.25"),
                )
                .opt("sample-seed", "N", "chunk-sampling seed", Some("0"))
                .opt("json", "FILE", "also write the frontier as JSON", None)
                .opt(
                    "cache-dir",
                    "DIR",
                    "persistent evaluation cache: load it before searching, append every miss",
                    None,
                )
                .flag(
                    "no-profile",
                    '\0',
                    "screen each candidate with its own stream walk instead of the \
                     reuse-distance profiled screen (same frontier, more walks)",
                )
                .flag(
                    "compact-cache",
                    '\0',
                    "rewrite the persistent cache log without dead records, then exit \
                     (needs --cache-dir or the default cache directory)",
                )
                .opt("trace-out", "FILE", "record spans; write a Chrome trace on exit", None)
                .opt("config", "FILE", "accelerator config file (may define [tech.*])", None),
        )
        .subcommand(
            Command::new("serve", "long-lived NDJSON evaluation daemon")
                .flag("stdin", 'i', "serve one request stream on stdin/stdout (the default)")
                .opt(
                    "socket",
                    "PATH",
                    "serve Unix-socket connections at PATH instead of stdin",
                    None,
                )
                .opt(
                    "cache-dir",
                    "DIR",
                    "persistent evaluation cache directory (default: in-memory)",
                    None,
                )
                .opt("threads", "T", "OS threads for cold evaluations (0 = all cores)", Some("0"))
                .opt("batch", "N", "requests per batch window", Some("16"))
                .flag("log-json", '\0', "structured NDJSON logs on stderr instead of text")
                .opt("trace-out", "FILE", "record spans; write a Chrome trace on exit", None),
        )
        .subcommand(
            Command::new("reproduce", "regenerate every paper table and figure")
                .opt("scale", "S", "workload scale factor", Some("0.001"))
                .opt("seed", "N", "generator seed", Some("42"))
                .flag("markdown", 'm', "emit Markdown instead of ASCII"),
        )
        .subcommand(
            Command::new("cpals", "run CP-ALS end-to-end (fit curve)")
                .opt("rank", "R", "decomposition rank", Some("16"))
                .opt("iters", "N", "max ALS iterations", Some("20"))
                .opt("nnz", "N", "synthetic tensor nonzeros", Some("50000"))
                .opt("dim", "D", "mode dimension", Some("200"))
                .opt("seed", "N", "seed", Some("42"))
                .flag("artifacts", 'a', "use the PJRT artifacts (default: CPU reference)"),
        )
        .subcommand(
            Command::new("mttkrp", "run spMTTKRP on a FROSTT .tns file")
                .positional("input", "path to .tns file", true)
                .opt("mode", "M", "output mode", Some("0"))
                .opt("rank", "R", "rank (16 or 32 for --artifacts)", Some("16"))
                .flag("artifacts", 'a', "use the PJRT artifacts"),
        )
}

/// Load `--config`: accelerator overrides + `[tech.*]` registry entries.
fn load_config(p: &Parsed) -> Result<AcceleratorConfig, String> {
    let mut cfg = AcceleratorConfig::paper_default();
    if let Some(path) = p.get("config") {
        let file = Config::load(std::path::Path::new(path)).map_err(|e| e.to_string())?;
        let added = registry::load_config(&file)?;
        if !added.is_empty() {
            eprintln!("registered technologies from {path}: {}", added.join(", "));
        }
        cfg.apply_config(&file)?;
    }
    Ok(cfg)
}

/// Apply `--levels` (the memory-hierarchy stack grammar) on top of the
/// loaded configuration. Absent flag ⇒ whatever the config file set —
/// by default the degenerate (empty) stack, bit-identical to the
/// pre-hierarchy model.
fn apply_levels(p: &Parsed, cfg: &mut AcceleratorConfig) -> Result<(), String> {
    if let Some(spec) = p.get("levels") {
        cfg.levels = photon_mttkrp::mem::hierarchy::parse_levels(spec)
            .map_err(|e| format!("--levels: {e}"))?;
        cfg.validate().map_err(|e| format!("--levels: {e}"))?;
    }
    Ok(())
}

/// Resolve the repeatable `--tech` selection shared by `sweep` and
/// `explore`: nothing given or `all` ⇒ every registered technology;
/// otherwise each name resolves through the registry.
fn resolve_tech_list(p: &Parsed) -> Result<Vec<MemTechnology>, String> {
    let given = p.get_all("tech");
    let names: Vec<String> = if given.contains(&"all") {
        if given.len() > 1 {
            return Err(
                "--tech all already selects every registered technology; \
                 drop the other --tech values"
                    .into(),
            );
        }
        registry::names()
    } else if given.is_empty() {
        registry::names()
    } else {
        given.iter().map(|s| s.to_string()).collect()
    };
    names.iter().map(|n| registry::resolve(n)).collect()
}

/// Resolve a repeatable `--kernel` selection (the `explore` axis):
/// nothing given ⇒ the paper's spMTTKRP; `all` ⇒ every builtin.
fn resolve_kernel_list(p: &Parsed) -> Result<Vec<KernelKind>, String> {
    let given = p.get_all("kernel");
    if given.contains(&"all") {
        if given.len() > 1 {
            return Err(
                "--kernel all already selects every registered kernel; \
                 drop the other --kernel values"
                    .into(),
            );
        }
        return Ok(KernelKind::ALL.to_vec());
    }
    if given.is_empty() {
        return Ok(vec![KernelKind::Spmttkrp]);
    }
    given.iter().map(|s| KernelKind::parse(s)).collect()
}

/// Parse the shared `--sample-rate` / `--sample-seed` pair. Range
/// violations surface the valid interval, mirroring the engine listing
/// an unknown `--engine` prints.
fn parse_sample(p: &Parsed) -> Result<SampleSpec, String> {
    let rate = p.get_f64("sample-rate").map_err(|e| e.to_string())?;
    let seed = p.get_u64("sample-seed").map_err(|e| e.to_string())?;
    SampleSpec::new(rate, seed).map_err(|e| format!("--sample-rate: {e}"))
}

fn parse_f64_list(p: &Parsed, name: &str, default: &[f64]) -> Result<Vec<f64>, String> {
    let given = p.get_all(name);
    if given.is_empty() {
        return Ok(default.to_vec());
    }
    given
        .iter()
        .map(|s| s.parse::<f64>().map_err(|e| format!("--{name} `{s}`: {e}")))
        .collect()
}

fn run() -> Result<(), String> {
    let cmd = cli();
    let p = cmd.parse_env().map_err(|e: CliError| e.to_string())?;
    if p.help_requested || p.subcommand().is_none() {
        println!("{}", cmd.help());
        return Ok(());
    }
    // --trace-out arms the span recorder around the whole subcommand,
    // so the early returns inside dispatch (--json, --compact-cache)
    // still get their trace written on the way out
    let trace_out = matches!(p.subcommand(), Some("simulate" | "sweep" | "explore" | "serve"))
        .then(|| p.get("trace-out").map(std::path::PathBuf::from))
        .flatten();
    if trace_out.is_some() {
        obs::span::Recorder::global().enable();
    }
    let result = dispatch(&cmd, &p);
    if let Some(path) = &trace_out {
        let rec = obs::span::Recorder::global();
        rec.disable();
        let events = rec.take();
        if result.is_ok() {
            obs::export::write_chrome_trace(path, &events)
                .map_err(|e| format!("--trace-out {}: {e}", path.display()))?;
            eprintln!("wrote {} trace event(s) to {}", events.len(), path.display());
        }
    }
    result
}

fn dispatch(cmd: &Command, p: &Parsed) -> Result<(), String> {
    match p.subcommand().unwrap() {
        "info" => {
            let cfg = load_config(p)?;
            println!("{}", paper::table_i(&cfg).render_ascii());
            println!("{}", paper::table_iii().render_ascii());
            println!("{}", paper::table_iv(&cfg).render_ascii());
            println!(
                "{}",
                paper::table_technologies(&registry::global().read().unwrap()).render_ascii()
            );
            if p.flag("tensors") {
                println!("{}", paper::table_ii(1.0).render_ascii());
            }
        }
        "simulate" => {
            let mut cfg_base = load_config(p)?;
            apply_levels(p, &mut cfg_base)?;
            let scale = p.get_f64("scale").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let name = p.get("tensor").unwrap();
            let ft = FrosttTensor::from_name(name)
                .ok_or_else(|| format!("unknown tensor `{name}`"))?;
            // validate cheap arguments before the expensive generation
            let engine = EngineKind::parse(p.get("engine").unwrap())?;
            let kernel = KernelKind::parse(p.get("kernel").unwrap())?;
            let budget = SimBudget {
                threads: p.get_usize("threads").map_err(|e| e.to_string())?,
                chunk_nnz: p.get_usize("chunk-nnz").map_err(|e| e.to_string())?,
                sample: parse_sample(p)?,
            };
            if budget.chunk_nnz == 0 {
                return Err("--chunk-nnz must be positive".into());
            }
            let tech_arg = p.get("tech").unwrap();
            if matches!(tech_arg, "both" | "all") && p.get("mode").is_some() {
                return Err(format!(
                    "--mode needs a single technology (use `--tech <name> --mode M`, \
                     or the sweep subcommand's --mode filter); got --tech {tech_arg}"
                ));
            }
            let cfg = cfg_base.scaled(scale);
            let tensor = preset(ft).scaled(scale).generate(seed);
            eprintln!("generated {} ({} nnz), kernel {}", tensor.name, tensor.nnz(), kernel);
            if p.flag("json") {
                if p.get("mode").is_some() {
                    return Err(
                        "--json emits the whole comparison; drop --mode (its per-mode \
                         reports are inside the JSON already)"
                            .into(),
                    );
                }
                let techs = match tech_arg {
                    "both" => paper_pair(),
                    "all" => registry::all(),
                    t => vec![registry::resolve(t)?],
                };
                let mut cs = compare_technologies_on_engines(
                    &tensor,
                    &cfg,
                    &techs,
                    &[engine],
                    kernel,
                    budget,
                );
                let c = cs.pop().expect("one comparison per engine");
                println!("{}", comparison_json(&c, engine.name()));
                return Ok(());
            }
            // With --engine event, every variant also prints the
            // analytic-vs-event delta (the roofline error bound), derived
            // from the event comparison already in hand plus one analytic
            // pass — nothing is simulated twice on the same engine.
            let print_deltas = |c_event: &TechComparison, c_analytic: &TechComparison| {
                for (er, ar) in c_event.runs.iter().zip(&c_analytic.runs) {
                    let d = EngineDelta {
                        tech: er.name().to_string(),
                        analytic_cycles: ar.report.total_runtime_cycles(),
                        event_cycles: er.report.total_runtime_cycles(),
                    };
                    println!(
                        "{:<12} engine event: analytic {:.4e} cycles, event {:.4e} cycles, delta +{:.1}%",
                        d.tech, d.analytic_cycles, d.event_cycles, d.delta_pct(),
                    );
                }
            };
            // With --engine event the analytic delta pass rides along in
            // the same memoized comparison, so the §IV-A mapping and the
            // per-mode views are prepared once, not once per engine.
            let engines: Vec<EngineKind> = if engine == EngineKind::Event {
                vec![EngineKind::Event, EngineKind::Analytic]
            } else {
                vec![engine]
            };
            match tech_arg {
                "both" => {
                    let mut cs = compare_technologies_on_engines(
                        &tensor,
                        &cfg,
                        &paper_pair(),
                        &engines,
                        kernel,
                        budget,
                    );
                    let ca = if cs.len() > 1 { cs.pop() } else { None };
                    let c = cs.pop().expect("one comparison per engine");
                    let e = &c.require("e-sram").report;
                    let o = &c.require("o-sram").report;
                    for (m, s) in c.mode_speedups("o-sram").iter().enumerate() {
                        println!(
                            "M{m}: e-sram {:.3e}s  o-sram {:.3e}s  speedup {s:.2}x  (hit {:.1}% / bottleneck {})",
                            e.modes[m].runtime_s(),
                            o.modes[m].runtime_s(),
                            o.modes[m].hit_rate() * 100.0,
                            e.modes[m].bottleneck().name(),
                        );
                    }
                    println!(
                        "total [{kernel}]: speedup {:.2}x  energy savings {:.2}x",
                        c.total_speedup("o-sram"),
                        c.energy_savings("o-sram")
                    );
                    if let Some(ca) = &ca {
                        print_deltas(&c, ca);
                    }
                }
                "all" => {
                    let mut cs = compare_technologies_on_engines(
                        &tensor,
                        &cfg,
                        &registry::all(),
                        &engines,
                        kernel,
                        budget,
                    );
                    let ca = if cs.len() > 1 { cs.pop() } else { None };
                    let c = cs.pop().expect("one comparison per engine");
                    let base = c.baseline().name().to_string();
                    for run in &c.runs {
                        println!(
                            "{:<12} total {:.3e}s  speedup vs {base} {:.2}x  energy savings {:.2}x",
                            run.name(),
                            run.report.total_runtime_s(),
                            c.total_speedup(run.name()),
                            c.energy_savings(run.name()),
                        );
                    }
                    if let Some(ca) = &ca {
                        print_deltas(&c, ca);
                    }
                }
                t => {
                    let tech = registry::resolve(t)?;
                    let modes: Vec<usize> = match p.get("mode") {
                        Some(m) => vec![m.parse().map_err(|e| format!("--mode: {e}"))?],
                        None => (0..tensor.n_modes()).collect(),
                    };
                    // the §IV-A mapping is mode-independent: apply it once
                    // instead of once per (mode × engine) simulation
                    let mapped = apply_memory_mapping(&tensor);
                    let k = kernel.kernel();
                    for m in modes {
                        // one view per mode, shared by both engine passes
                        let view = ModeView::build(&mapped, m);
                        let r = engine.simulate_kernel_mode_with_view_budget(
                            k,
                            &mapped,
                            &view,
                            m,
                            &cfg,
                            &tech,
                            budget,
                        );
                        println!(
                            "M{m} [{}] {kernel}: {:.3e}s  ({:.0} cycles, hit {:.1}%, bottleneck {})",
                            tech.name,
                            r.runtime_s(),
                            r.runtime_cycles(),
                            r.hit_rate() * 100.0,
                            r.bottleneck().name()
                        );
                        for l in r.levels() {
                            println!(
                                "    level {:<10} hit {:>5.1}%  traffic {} B  busy {:.3e} cyc{}",
                                l.name,
                                l.hit_rate() * 100.0,
                                l.traffic_bytes,
                                l.busy_cycles,
                                if l.double_buffer { "  (db)" } else { "" },
                            );
                        }
                        if engine == EngineKind::Event {
                            // the event replay's headline deliverable: how
                            // far off the roofline abstraction is here
                            let a = EngineKind::Analytic.simulate_kernel_mode_with_view_budget(
                                k,
                                &mapped,
                                &view,
                                m,
                                &cfg,
                                &tech,
                                budget,
                            );
                            let d = EngineDelta {
                                tech: tech.name.clone(),
                                analytic_cycles: a.runtime_cycles(),
                                event_cycles: r.runtime_cycles(),
                            };
                            println!(
                                "    engine event: analytic {:.0} cycles, event {:.0} cycles, delta +{:.1}%",
                                d.analytic_cycles,
                                d.event_cycles,
                                d.delta_pct(),
                            );
                        }
                    }
                }
            }
        }
        "sweep" => {
            let mut cfg_base = load_config(p)?;
            apply_levels(p, &mut cfg_base)?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let threads = p.get_usize("threads").map_err(|e| e.to_string())?;
            let scales = parse_f64_list(p, "scale", &[0.001])?;
            let tensor_names: Vec<String> = {
                let given = p.get_all("tensor");
                if given.is_empty() {
                    vec!["nell-2".into(), "nell-1".into(), "patents".into()]
                } else {
                    given.iter().map(|s| s.to_string()).collect()
                }
            };
            let tensors = tensor_names
                .iter()
                .map(|n| {
                    FrosttTensor::from_name(n)
                        .map(preset)
                        .ok_or_else(|| format!("unknown tensor `{n}`"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            let techs = resolve_tech_list(p)?;
            let modes: Vec<usize> = p
                .get_all("mode")
                .iter()
                .map(|s| s.parse::<usize>().map_err(|e| format!("--mode `{s}`: {e}")))
                .collect::<Result<Vec<_>, _>>()?;
            let mut spec = SweepSpec::new(tensors, scales, techs);
            spec.base_cfg = cfg_base;
            spec.seed = seed;
            spec.threads = threads;
            spec.engine = EngineKind::parse(p.get("engine").unwrap())?;
            spec.kernel = KernelKind::parse(p.get("kernel").unwrap())?;
            spec.chunk_nnz = p.get_usize("chunk-nnz").map_err(|e| e.to_string())?;
            spec.sample = parse_sample(p)?;
            if !modes.is_empty() {
                spec.modes = Some(modes);
            }
            let n_threads = sweep::effective_threads(spec.threads);
            eprintln!(
                "sweeping {} scenarios ({} tensors x {} scales x {} techs) on {} threads ...",
                spec.n_points(),
                spec.tensors.len(),
                spec.scales.len(),
                spec.techs.len(),
                n_threads,
            );
            let t0 = std::time::Instant::now();
            let points = sweep::run_sweep(&spec)?;
            let dt = t0.elapsed().as_secs_f64();
            println!("{}", sweep::summary_table(&spec, &points).render_ascii());
            let sim_nnz: u64 = points.iter().map(|p| p.nnz).sum();
            eprintln!(
                "swept {} scenarios ({} simulated nonzero-events) in {:.2}s on {} threads",
                points.len(),
                sim_nnz,
                dt,
                n_threads,
            );
        }
        "explore" => {
            if p.flag("compact-cache") {
                // maintenance verb: rewrite the log and exit without
                // searching anything
                let dir = p
                    .get("cache-dir")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(photon_mttkrp::explore::EvalStore::default_dir);
                let r = photon_mttkrp::explore::EvalStore::compact(&dir)
                    .map_err(|e| format!("--compact-cache {}: {e}", dir.display()))?;
                eprintln!(
                    "compacted {}: kept {} live records, dropped {} dead ({} -> {} bytes)",
                    r.path.display(),
                    r.live,
                    r.dropped,
                    r.bytes_before,
                    r.bytes_after,
                );
                return Ok(());
            }
            let mut cfg_base = load_config(p)?;
            apply_levels(p, &mut cfg_base)?;
            let scale = p.get_f64("scale").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let name = p.get("tensor").unwrap();
            let ft = FrosttTensor::from_name(name)
                .ok_or_else(|| format!("unknown tensor `{name}`"))?;
            // validate cheap arguments before anything expensive
            let objective = ObjectiveKind::parse(p.get("objective").unwrap())?;
            let top = p.get_usize("top").map_err(|e| e.to_string())?;
            let axes: Vec<Axis> = p
                .get_all("axes")
                .iter()
                .map(|s| Axis::parse(s))
                .collect::<Result<Vec<_>, _>>()?;
            let techs = resolve_tech_list(p)?;
            let kernels = resolve_kernel_list(p)?;
            let budget_mm2 = match p.get("budget-mm2") {
                Some(s) => {
                    Some(s.parse::<f64>().map_err(|e| format!("--budget-mm2 `{s}`: {e}"))?)
                }
                None => None,
            };
            let mut space = DesignSpace::paper_grid(techs, kernels);
            space.base_cfg = cfg_base;
            if !axes.is_empty() {
                space.axes = axes;
            }
            space.budget_mm2 = budget_mm2;
            space.exclude_wafer_scale = p.flag("exclude-wafer-scale");
            let mut spec = ExploreSpec::new(space, preset(ft));
            spec.scale = scale;
            spec.seed = seed;
            spec.objective = objective;
            spec.threads = p.get_usize("threads").map_err(|e| e.to_string())?;
            spec.chunk_nnz = p.get_usize("chunk-nnz").map_err(|e| e.to_string())?;
            spec.sample = parse_sample(p)?;
            spec.profile = !p.flag("no-profile");
            let n_threads = sweep::effective_threads(spec.threads);
            eprintln!(
                "exploring up to {} candidates ({} techs x {} kernels) by {} on {} threads ...",
                spec.space.n_points(),
                spec.space.techs.len(),
                spec.space.kernels.len(),
                spec.objective,
                n_threads,
            );
            let t0 = std::time::Instant::now();
            let result = match p.get("cache-dir") {
                Some(dir) => {
                    let cache = EvalCache::with_store(std::path::Path::new(dir))
                        .map_err(|e| format!("--cache-dir {dir}: {e}"))?;
                    eprintln!(
                        "loaded {} cached evaluations from {}",
                        cache.loaded(),
                        cache.store_path().expect("persistent cache has a path").display(),
                    );
                    run_explore_with_cache(&spec, &cache)?
                }
                None => run_explore(&spec)?,
            };
            println!("{}", frontier_table(&result, top).render_ascii());
            if result.deltas.is_empty() {
                println!(
                    "event confirmation agrees with the analytic screen on all {} \
                     frontier members",
                    result.frontier.len()
                );
            } else {
                for d in &result.deltas {
                    println!("{}", d.describe());
                }
            }
            eprintln!(
                "screened {} candidates ({} invalid, {} constraint-filtered) in {:.2}s on \
                 {} threads; {} frontier members, cache {} miss / {} hit \
                 ({} loaded, {} appended)",
                result.candidates.len(),
                result.n_invalid,
                result.n_filtered,
                t0.elapsed().as_secs_f64(),
                n_threads,
                result.frontier.len(),
                result.cache_misses,
                result.cache_hits,
                result.cache_loaded,
                result.cache_appended,
            );
            eprintln!(
                "phase wall time: screen {:.3}s / pareto {:.3}s / sampled confirm {:.3}s / \
                 exact pin {:.3}s (total {:.3}s); {} functional stream walk(s) priced \
                 {} candidates",
                result.timing.screen_s,
                result.timing.pareto_s,
                result.timing.sampled_s,
                result.timing.exact_s,
                result.timing.total_s(),
                result.functional_walks,
                result.candidates.len(),
            );
            if let Some(path) = p.get("json") {
                explore::write_frontier_json(&result, std::path::Path::new(path))
                    .map_err(|e| format!("--json {path}: {e}"))?;
                eprintln!("wrote {path}");
            }
        }
        "serve" => {
            if p.flag("log-json") {
                obs::log::set_json(true);
            }
            let opts = ServeOptions {
                threads: p.get_usize("threads").map_err(|e| e.to_string())?,
                batch: p.get_usize("batch").map_err(|e| e.to_string())?,
                cache_dir: p.get("cache-dir").map(std::path::PathBuf::from),
            };
            if opts.batch == 0 {
                return Err("--batch must be positive".into());
            }
            match p.get("socket") {
                Some(path) => {
                    if p.flag("stdin") {
                        return Err("--stdin and --socket are mutually exclusive".into());
                    }
                    #[cfg(unix)]
                    photon_mttkrp::serve::run_socket(&opts, std::path::Path::new(path))?;
                    #[cfg(not(unix))]
                    return Err(format!(
                        "--socket {path}: Unix sockets are unavailable on this platform; \
                         use --stdin"
                    ));
                }
                None => photon_mttkrp::serve::run_stdin(&opts)?,
            }
        }
        "reproduce" => {
            let scale = p.get_f64("scale").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let cfg = AcceleratorConfig::paper_default();
            let render = |t: &photon_mttkrp::util::table::Table| {
                if p.flag("markdown") {
                    t.render_markdown()
                } else {
                    t.render_ascii()
                }
            };
            println!("{}", render(&paper::table_i(&cfg)));
            println!("{}", render(&paper::table_ii(scale)));
            println!("{}", render(&paper::table_iii()));
            println!("{}", render(&paper::table_iv(&cfg)));
            eprintln!("running the 7-tensor suite at scale {scale:.1e} ...");
            let results = paper::evaluate_suite(scale, seed);
            println!("{}", render(&paper::fig7(&results)));
            println!("{}", render(&paper::fig8(&results)));
            eprintln!("cross-validating the analytic engine against the event engine ...");
            println!("{}", render(&paper::table_cross_validation(scale, seed)));
            eprintln!("pricing every registered sparse kernel on the paper pair ...");
            println!("{}", render(&paper::table_kernels(scale, seed)));
            eprintln!("searching the default design-space grid for the EDP frontier ...");
            println!("{}", render(&paper::table_frontier(scale, seed)));
            eprintln!("replaying the two-level hierarchy stack (db on vs off) ...");
            println!("{}", render(&paper::table_hierarchy(scale, seed)));
        }
        "cpals" => {
            let rank = p.get_usize("rank").map_err(|e| e.to_string())?;
            let iters = p.get_usize("iters").map_err(|e| e.to_string())?;
            let nnz = p.get_usize("nnz").map_err(|e| e.to_string())?;
            let dim = p.get_u64("dim").map_err(|e| e.to_string())?;
            let seed = p.get_u64("seed").map_err(|e| e.to_string())?;
            let tensor = low_rank_tensor(&[dim, dim, dim], rank / 2, nnz, 0.01, seed);
            let cfg = CpAlsConfig { rank, max_iters: iters, tol: 1e-6, seed };
            let rt;
            let compute = if p.flag("artifacts") {
                rt = Runtime::from_default_dir().map_err(|e| e.to_string())?;
                Compute::Artifacts(&rt)
            } else {
                Compute::Reference
            };
            let model = cp_als(&tensor, &cfg, &compute).map_err(|e| e.to_string())?;
            for s in &model.history {
                println!("iter {:>3}: fit {:.6} (delta {:.2e})", s.iter, s.fit, s.fit_delta);
            }
            println!("final fit: {:.6}", model.final_fit());
        }
        "mttkrp" => {
            let path = &p.positionals[0];
            let mode = p.get_usize("mode").map_err(|e| e.to_string())?;
            let rank = p.get_usize("rank").map_err(|e| e.to_string())?;
            let tensor = SparseTensor::load_tns(std::path::Path::new(path))
                .map_err(|e| e.to_string())?;
            let factors: Vec<FactorMatrix> = tensor
                .dims
                .iter()
                .enumerate()
                .map(|(m, &d)| FactorMatrix::random(d as usize, rank, 7 + m as u64))
                .collect();
            let rt;
            let compute = if p.flag("artifacts") {
                rt = Runtime::from_default_dir().map_err(|e| e.to_string())?;
                Compute::Artifacts(&rt)
            } else {
                Compute::Reference
            };
            let t0 = std::time::Instant::now();
            let out = photon_mttkrp::coordinator::driver::compute_mode(
                &compute, &tensor, mode, &factors,
            )
            .map_err(|e| e.to_string())?;
            println!(
                "mttkrp mode {mode}: {} nnz -> {}x{} output in {:.3}s (frobenius {:.4})",
                tensor.nnz(),
                out.rows,
                out.rank,
                t0.elapsed().as_secs_f64(),
                out.frobenius()
            );
        }
        // unreachable through parse_env (the parser rejects unknown
        // subcommands with the same listing), but a dispatch arm added
        // without a parser entry must fail just as helpfully
        other => {
            return Err(format!(
                "unknown subcommand `{other}` (expected one of: {})",
                cmd.subcommand_names().join(", ")
            ))
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
