//! Process-wide metrics registry: named counters, gauges and
//! log2-bucketed histograms.
//!
//! Handles are `Arc`-backed atomics: resolve a [`Counter`] once (one
//! `BTreeMap` lock), then increment it from any thread with a relaxed
//! `fetch_add` — cheap enough for the engines' per-PE loops. The
//! [`Registry::global`] instance is what the serve `metrics` verb and
//! the Prometheus exposition snapshot; tests use their own
//! [`Registry::new`] instances so parallel test binaries never race on
//! shared counts.
//!
//! [`Histogram`] buckets by the bit width of the observed value — 65
//! buckets cover all of `u64` — so p50/p90/p99 come back as the
//! enclosing bucket's upper bound: for any true quantile `v > 0` the
//! reported value lies in `[v, 2v)`. Factor-two resolution at O(1)
//! memory is the right trade for request latencies spanning six
//! decades (pinned against the exact [`crate::util::stats::percentile`]
//! in `rust/tests/obs.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing named count.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A named point-in-time `f64` (stored as bits in an atomic).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

const N_BUCKETS: usize = 65;

#[derive(Debug)]
struct HistogramInner {
    /// `buckets[k]` counts observations whose bit width is `k`:
    /// bucket 0 holds exactly the value 0, bucket `k > 0` holds
    /// `[2^(k-1), 2^k)`.
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// A log2-bucketed histogram of `u64` observations (latencies in
/// nanoseconds, batch sizes, ...).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramInner>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistogramInner {
            buckets: [const { AtomicU64::new(0) }; N_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }
}

/// Bucket index for a value: its bit width (0 for 0).
fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `k` — what quantiles report.
pub fn bucket_upper(k: usize) -> u64 {
    match k {
        0 => 0,
        1..=63 => (1u64 << k) - 1,
        _ => u64::MAX,
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.0.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// The quantile `q` in `[0, 1]`, reported as the upper bound of the
    /// enclosing log2 bucket (so for the true order statistic `v > 0`
    /// the result lies in `[v, 2v)`); 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        // rank of the order statistic: ceil(q * total), clamped to [1, total]
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (k, b) in self.0.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_upper(k);
            }
        }
        u64::MAX
    }

    /// Every non-empty bucket as `(inclusive upper bound, count)`, in
    /// ascending bound order.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.0
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(k, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((bucket_upper(k), n))
            })
            .collect()
    }

    /// A consistent-enough point-in-time read of the whole histogram.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            buckets: self.buckets(),
        }
    }
}

/// Point-in-time view of one [`Histogram`], as exported.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    /// Non-empty buckets as `(inclusive upper bound, count)`.
    pub buckets: Vec<(u64, u64)>,
}

/// A name-keyed registry of metrics. Lookup interns the name; the
/// returned handle is lock-free thereafter.
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

static GLOBAL: Registry = Registry::new();

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// An empty registry (`const`, so the global instance needs no
    /// lazy init).
    pub const fn new() -> Self {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }

    /// The process-wide registry every production call site uses.
    pub fn global() -> &'static Registry {
        &GLOBAL
    }

    /// The counter named `name`, created at zero on first use.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap();
        match m.get(name) {
            Some(c) => c.clone(),
            None => {
                let c = Counter::default();
                m.insert(name.to_string(), c.clone());
                c
            }
        }
    }

    /// The gauge named `name`, created at zero on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap();
        match m.get(name) {
            Some(g) => g.clone(),
            None => {
                let g = Gauge::default();
                m.insert(name.to_string(), g.clone());
                g
            }
        }
    }

    /// The histogram named `name`, created empty on first use.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.histograms.lock().unwrap();
        match m.get(name) {
            Some(h) => h.clone(),
            None => {
                let h = Histogram::default();
                m.insert(name.to_string(), h.clone());
                h
            }
        }
    }

    /// Every counter as `(name, value)`, name-sorted.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Every gauge as `(name, value)`, name-sorted.
    pub fn gauges(&self) -> Vec<(String, f64)> {
        self.gauges.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// Every histogram as `(name, snapshot)`, name-sorted.
    pub fn histograms(&self) -> Vec<(String, HistogramSnapshot)> {
        self.histograms.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.snapshot())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_share_state_through_the_registry() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(4);
        assert_eq!(r.counter("x").get(), 5);
        assert_eq!(r.counters(), vec![("x".to_string(), 5)]);
    }

    #[test]
    fn gauges_store_floats() {
        let r = Registry::new();
        r.gauge("frac").set(0.25);
        assert_eq!(r.gauge("frac").get(), 0.25);
        // a fresh gauge reads 0.0, not garbage bits
        assert_eq!(r.gauge("new").get(), 0.0);
    }

    #[test]
    fn histogram_buckets_by_bit_width() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
        // every value sits inside its own bucket's bounds
        for v in [0u64, 1, 2, 3, 7, 8, 1000, u64::MAX] {
            let k = bucket_of(v);
            assert!(v <= bucket_upper(k), "{v}");
            if k > 0 {
                assert!(v > bucket_upper(k - 1), "{v}");
            }
        }
    }

    #[test]
    fn quantiles_bound_the_true_order_statistic() {
        let h = Histogram::default();
        for v in 1..=1000u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        for (q, true_v) in [(0.5, 500u64), (0.9, 900), (0.99, 990)] {
            let got = h.quantile(q);
            assert!(got >= true_v, "q={q}: {got} < {true_v}");
            assert!(got < 2 * true_v, "q={q}: {got} >= {}", 2 * true_v);
        }
        assert_eq!(h.quantile(0.0), h.quantile(1e-9));
    }

    #[test]
    fn empty_and_degenerate_histograms() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.count(), 0);
        assert!(h.buckets().is_empty());
        h.observe(0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.buckets(), vec![(0, 1)]);
    }

    #[test]
    fn snapshot_orders_quantiles() {
        let h = Histogram::default();
        for v in [1u64, 10, 100, 1000, 10_000] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
        assert_eq!(s.buckets.iter().map(|&(_, n)| n).sum::<u64>(), 5);
    }

    #[test]
    fn registry_lists_are_name_sorted() {
        let r = Registry::new();
        r.counter("zeta").inc();
        r.counter("alpha").inc();
        r.histogram("h").observe(3);
        let names: Vec<String> = r.counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, ["alpha", "zeta"]);
        assert_eq!(r.histograms()[0].0, "h");
    }
}
