//! Unified observability: spans, a metrics registry, structured logs,
//! and trace/metrics exporters — all dependency-free.
//!
//! The simulator models performance; this module watches the
//! simulator's *own* performance without ever changing what it
//! computes. The pieces:
//!
//! * [`clock`] — a process-anchored monotonic nanosecond clock shared
//!   by every span and log line.
//! * [`span`] — RAII spans over that clock with nested parent
//!   tracking, recorded into a thread-safe [`span::Recorder`]. The
//!   global recorder starts **disabled** ([`span::Recorder::disabled`]
//!   is `const`, so the off path is a single relaxed atomic load and
//!   golden reports stay byte-identical); `--trace-out` enables it.
//!   Work fanned out through [`crate::sim::par`] captures events into
//!   per-worker buffers that merge **slot-ordered** after the join, so
//!   recording never perturbs the deterministic parallel map.
//! * [`metrics`] — a process-wide registry of named counters, gauges
//!   and log2-bucketed histograms (p50/p90/p99 derivation), onto which
//!   the crate's ad-hoc counters migrate: eval-cache
//!   hits/misses/loaded/appended, functional-memo walks and profiled
//!   geometries, serve batch sizes and request latencies, per-engine
//!   chunk and nonzero counts.
//! * [`log`] — one structured stderr log helper (text or NDJSON via
//!   `--log-json`, level-filtered via the `PHOTON_LOG` env var) that
//!   the serve daemon routes all its stderr through.
//! * [`export`] — Chrome trace-event JSON (open the `--trace-out`
//!   file in Perfetto / `chrome://tracing`) and a Prometheus-style
//!   text exposition of the registry, plus the JSON snapshot the
//!   serve `metrics` verb answers with.
//!
//! **Determinism contract.** Observation is strictly read-beside:
//! spans time code without reordering it, counters accumulate with
//! relaxed atomics off the result path, and the traced parallel-map
//! merge happens after all slots are joined. With the recorder enabled
//! and every counter live, all golden bit-identity tests and
//! parallel-determinism tests pass unchanged (pinned by
//! `rust/tests/golden.rs` and `rust/tests/obs.rs`).

pub mod clock;
pub mod export;
pub mod log;
pub mod metrics;
pub mod span;

pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use span::{Recorder, Span, SpanEvent};
