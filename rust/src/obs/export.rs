//! Exporters: Chrome trace-event JSON, Prometheus-style text
//! exposition, and the registry JSON snapshot the serve `metrics` verb
//! answers with.
//!
//! All hand-rolled writers in the crate's house style (`{:e}` is not
//! needed here — span times are integers in nanoseconds, rendered as
//! microseconds with fixed sub-µs digits; names pass through
//! [`crate::util::bench::json_escape`]). [`chrome_trace`] output loads
//! directly in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`; validity is pinned by parsing it back through
//! [`crate::util::json::Value`] in `rust/tests/obs.rs`.

use std::io;
use std::path::Path;

use crate::obs::metrics::Registry;
use crate::obs::span::SpanEvent;
use crate::util::bench::json_escape;

/// Nanoseconds rendered as the microsecond decimal Chrome's `ts`/`dur`
/// fields expect, without going through `f64` (exact at any
/// magnitude).
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1_000, ns % 1_000)
}

/// Render completed spans as one Chrome trace-event JSON document
/// (`ph: "X"` complete events, one `pid`, span ids and parent links in
/// `args`).
pub fn chrome_trace(events: &[SpanEvent]) -> String {
    let mut out = String::from("{\"traceEvents\": [");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {}, \
             \"dur\": {}, \"pid\": 1, \"tid\": {}, \
             \"args\": {{\"id\": {}, \"parent\": {}}}}}",
            json_escape(ev.name),
            json_escape(ev.cat),
            us(ev.start_ns),
            us(ev.dur_ns),
            ev.tid,
            ev.id,
            ev.parent,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// Write [`chrome_trace`] to `path`, creating parent directories.
pub fn write_chrome_trace(path: &Path, events: &[SpanEvent]) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, chrome_trace(events))
}

/// Render a registry in the Prometheus text exposition style:
/// `# TYPE` comments, counters and gauges as plain samples, histograms
/// as cumulative `_bucket{le="..."}` series plus `_sum`/`_count`.
pub fn prometheus(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in reg.counters() {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in reg.gauges() {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in reg.histograms() {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (upper, n) in &h.buckets {
            cum += n;
            out.push_str(&format!("{name}_bucket{{le=\"{upper}\"}} {cum}\n"));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// The registry as one JSON object — the `result` body of the serve
/// `metrics` verb (minus the daemon's own cache section):
///
/// ```json
/// {"counters": {"a": 1}, "gauges": {"g": 0.5},
///  "histograms": {"h": {"count": 2, "sum": 7, "p50": 3, "p90": 7, "p99": 7}}}
/// ```
pub fn registry_json(reg: &Registry) -> String {
    let mut out = String::from("{\"counters\": {");
    for (i, (name, v)) in reg.counters().iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("\"{}\": {v}", json_escape(name)));
    }
    out.push_str("}, \"gauges\": {");
    for (i, (name, v)) in reg.gauges().iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("\"{}\": {v:e}", json_escape(name)));
    }
    out.push_str("}, \"histograms\": {");
    for (i, (name, h)) in reg.histograms().iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "\"{}\": {{\"count\": {}, \"sum\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}}}",
            json_escape(name),
            h.count,
            h.sum,
            h.p50,
            h.p90,
            h.p99,
        ));
    }
    out.push_str("}}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    fn sample_events() -> Vec<SpanEvent> {
        vec![
            SpanEvent {
                name: "outer",
                cat: "test",
                start_ns: 1_000,
                dur_ns: 3_500,
                tid: 1,
                id: 1,
                parent: 0,
            },
            SpanEvent {
                name: "inner",
                cat: "test",
                start_ns: 1_500,
                dur_ns: 1_250,
                tid: 1,
                id: 2,
                parent: 1,
            },
        ]
    }

    #[test]
    fn chrome_trace_parses_and_keeps_fields() {
        let json = chrome_trace(&sample_events());
        let v = Value::parse(&json).expect("trace is valid JSON");
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("name").unwrap().as_str(), Some("outer"));
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(evs[0].get("dur").unwrap().as_f64(), Some(3.5));
        assert_eq!(evs[1].get("args").unwrap().get("parent").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn empty_trace_is_still_a_document() {
        let v = Value::parse(&chrome_trace(&[])).unwrap();
        assert_eq!(v.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn microsecond_rendering_is_exact() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1_000), "1.000");
        assert_eq!(us(1_234_567), "1234.567");
    }

    #[test]
    fn prometheus_exposition_has_cumulative_buckets() {
        let r = Registry::new();
        r.counter("req_total").add(7);
        r.gauge("frac").set(0.5);
        let h = r.histogram("lat_ns");
        for v in [1u64, 2, 3, 1000] {
            h.observe(v);
        }
        let text = prometheus(&r);
        assert!(text.contains("# TYPE req_total counter\nreq_total 7\n"), "{text}");
        assert!(text.contains("# TYPE frac gauge\nfrac 0.5\n"), "{text}");
        assert!(text.contains("lat_ns_bucket{le=\"1\"} 1\n"), "{text}");
        // cumulative: the le="3" bucket includes the le="1" count
        assert!(text.contains("lat_ns_bucket{le=\"3\"} 3\n"), "{text}");
        assert!(text.contains("lat_ns_bucket{le=\"+Inf\"} 4\n"), "{text}");
        assert!(text.contains("lat_ns_sum 1006\n"), "{text}");
        assert!(text.contains("lat_ns_count 4\n"), "{text}");
    }

    #[test]
    fn registry_json_round_trips() {
        let r = Registry::new();
        r.counter("hits").add(3);
        r.gauge("rate").set(0.25);
        r.histogram("h").observe(5);
        let v = Value::parse(&registry_json(&r)).expect("registry JSON parses");
        assert_eq!(v.get("counters").unwrap().get("hits").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("gauges").unwrap().get("rate").unwrap().as_f64(), Some(0.25));
        let h = v.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_u64(), Some(1));
        assert_eq!(h.get("p50").unwrap().as_u64(), Some(7));
    }
}
