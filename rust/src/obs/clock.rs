//! Process-anchored monotonic clock.
//!
//! Every span and structured log line stamps time from the same
//! anchor — the first call in the process — so a Chrome trace built
//! from [`crate::obs::span::SpanEvent`]s has one coherent timeline
//! across threads, subcommands and daemon batch windows. Nanoseconds
//! in a `u64` cover ~584 years of process uptime.

use std::sync::OnceLock;
use std::time::Instant;

fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// Nanoseconds since the process clock anchor (monotonic, never
/// decreasing across threads).
pub fn now_ns() -> u64 {
    anchor().elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn clock_actually_advances() {
        let a = now_ns();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(now_ns() > a);
    }
}
