//! One structured stderr log helper for the daemon and CLI.
//!
//! Every daemon message — the startup announcement, per-request access
//! logs, accept/connection errors — goes through [`log`], so each line
//! carries the same shape: a level, a target, a message and typed
//! `key=value` fields (request id, verb, cache hit/miss, wall time,
//! batch size). Two renderings:
//!
//! * text (default): `[info] serve: request id=3 verb=simulate ...`
//! * NDJSON (`--log-json` / [`set_json`]): one JSON object per line,
//!   machine-tailable.
//!
//! The level filter reads the `PHOTON_LOG` env var once
//! (`error|warn|info|debug`, default `info`); [`set_level`] overrides
//! it programmatically. Filtering happens before any formatting, so a
//! suppressed `debug` line costs one atomic load.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use crate::util::bench::json_escape;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// The stable lowercase name used on the wire and in `PHOTON_LOG`.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `PHOTON_LOG` spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_index(i: usize) -> Level {
        match i {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Sentinel meaning "not yet resolved from the environment".
const LEVEL_UNSET: usize = usize::MAX;

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LEVEL_UNSET);
static JSON: AtomicBool = AtomicBool::new(false);

/// Override the level filter (wins over `PHOTON_LOG`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Switch between text (false, default) and NDJSON (true) rendering —
/// the daemon's `--log-json` flag.
pub fn set_json(on: bool) {
    JSON.store(on, Ordering::Relaxed);
}

fn max_level() -> Level {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return Level::from_index(v);
    }
    let level = std::env::var("PHOTON_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
    level
}

/// Would a line at `level` currently be emitted?
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Render one log line without emitting it (what the tests pin).
pub fn render(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) -> String {
    if JSON.load(Ordering::Relaxed) {
        let mut out = format!(
            "{{\"ts_ns\": {}, \"level\": \"{}\", \"target\": \"{}\", \"msg\": \"{}\"",
            crate::obs::clock::now_ns(),
            level.name(),
            json_escape(target),
            json_escape(msg),
        );
        for (k, v) in fields {
            out.push_str(&format!(", \"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        out.push('}');
        out
    } else {
        let mut out = format!("[{}] {target}: {msg}", level.name());
        for (k, v) in fields {
            out.push_str(&format!(" {k}={v}"));
        }
        out
    }
}

/// Emit one structured line to stderr if `level` passes the filter.
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    eprintln!("{}", render(level, target, msg, fields));
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    /// The JSON/level switches are process globals; serialize the
    /// tests that flip them so parallel test threads never interleave.
    static GLOBALS: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Info);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert_eq!(Level::Info.name(), "info");
    }

    #[test]
    fn text_rendering_is_single_line_key_value() {
        let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
        // rendering is independent of the level filter; JSON mode is a
        // process-global toggle, so force the text side explicitly
        set_json(false);
        let line = render(
            Level::Info,
            "serve",
            "request",
            &[("id", "3".to_string()), ("cache", "hit".to_string())],
        );
        assert_eq!(line, "[info] serve: request id=3 cache=hit");
        set_json(true);
        let line = render(Level::Warn, "serve", "accept error", &[("err", "boom".to_string())]);
        set_json(false);
        let v = Value::parse(&line).expect("JSON log lines parse");
        assert_eq!(v.get("level").unwrap().as_str(), Some("warn"));
        assert_eq!(v.get("target").unwrap().as_str(), Some("serve"));
        assert_eq!(v.get("msg").unwrap().as_str(), Some("accept error"));
        assert_eq!(v.get("err").unwrap().as_str(), Some("boom"));
        assert!(v.get("ts_ns").unwrap().as_f64().is_some());
    }

    #[test]
    fn json_rendering_escapes_hostile_values() {
        let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
        set_json(true);
        let line =
            render(Level::Error, "serve", "oops", &[("path", "a\"b\\c\n".to_string())]);
        set_json(false);
        let v = Value::parse(&line).expect("escaped JSON parses");
        assert_eq!(v.get("path").unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn filter_respects_explicit_level() {
        let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Warn);
        assert!(enabled(Level::Error) && enabled(Level::Warn));
        assert!(!enabled(Level::Info) && !enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
    }
}
