//! RAII spans and the thread-safe span recorder.
//!
//! A [`Span`] measures one region of code against
//! [`crate::obs::clock`] and, when recording is active, emits one
//! [`SpanEvent`] on drop (or [`Span::finish`]). Nesting is tracked per
//! thread: a span opened while another is live records that span's id
//! as its parent, which is what lets the Chrome-trace exporter show
//! explore phases containing stream walks containing engine runs.
//!
//! **The off path costs one relaxed atomic load.** The global
//! [`Recorder`] is a `const`-constructed static that starts disabled;
//! [`Span::enter`] on the disabled path reads no clock, takes no lock
//! and allocates nothing, so instrumenting the engines cannot perturb
//! golden bit-identity runs. [`Span::timed`] is the variant for call
//! sites that need the elapsed seconds *themselves* (explore's
//! `PhaseTimings`): it always reads the clock, and still records only
//! when recording is active.
//!
//! **Determinism under `sim::par`.** Worker threads never push to the
//! global recorder directly. [`capture`] installs a thread-local
//! buffer; the traced parallel map wraps each item in it and appends
//! the per-item buffers **in slot order** after the join
//! ([`sink_append`] routes to the caller's own buffer when maps nest).
//! Event order in the recorder is therefore a pure function of the
//! work list, not of thread scheduling.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::clock;

/// One completed span: a closed interval on the process timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Static span name (e.g. `explore.screen`, `engine.event.mode`).
    pub name: &'static str,
    /// Coarse category for trace grouping (`explore`, `engine`, ...).
    pub cat: &'static str,
    /// Start, nanoseconds on the [`crate::obs::clock`] timeline.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Small per-thread ordinal (1-based, assigned on first span).
    pub tid: u64,
    /// Process-unique span id.
    pub id: u64,
    /// Id of the enclosing span on the same thread, 0 for roots.
    pub parent: u64,
}

/// Thread-safe sink for completed [`SpanEvent`]s.
///
/// The process-wide instance ([`Recorder::global`]) is what
/// `--trace-out` enables; it is `const`-constructed disabled so the
/// instrumented-but-off path stays branch-predictable and free of
/// locks.
pub struct Recorder {
    enabled: AtomicBool,
    events: Mutex<Vec<SpanEvent>>,
    next_id: AtomicU64,
}

static GLOBAL: Recorder = Recorder::disabled();

impl Recorder {
    /// A disabled recorder. `const`, so it can back a `static` with no
    /// lazy-init branch on the hot path.
    pub const fn disabled() -> Self {
        Recorder {
            enabled: AtomicBool::new(false),
            events: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// The process-wide recorder.
    pub fn global() -> &'static Recorder {
        &GLOBAL
    }

    /// Start accepting events (idempotent).
    pub fn enable(&self) {
        self.enabled.store(true, Ordering::SeqCst);
    }

    /// Stop accepting events; already-recorded events are kept.
    pub fn disable(&self) {
        self.enabled.store(false, Ordering::SeqCst);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Drain every recorded event, leaving the recorder empty.
    pub fn take(&self) -> Vec<SpanEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn push(&self, ev: SpanEvent) {
        self.events.lock().unwrap().push(ev);
    }

    fn extend(&self, evs: Vec<SpanEvent>) {
        self.events.lock().unwrap().extend(evs);
    }
}

thread_local! {
    /// When installed, this thread's events buffer here instead of the
    /// global recorder — the traced parallel map's per-item capture.
    static LOCAL_SINK: RefCell<Option<Vec<SpanEvent>>> = const { RefCell::new(None) };
    /// Ids of the live spans enclosing the current point, innermost
    /// last.
    static PARENTS: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// This thread's ordinal (0 = not yet assigned).
    static TID: Cell<u64> = const { Cell::new(0) };
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            v
        } else {
            let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(v);
            v
        }
    })
}

/// Is any sink live for this thread — a local capture buffer or the
/// enabled global recorder?
pub fn recording_active() -> bool {
    LOCAL_SINK.with(|s| s.borrow().is_some()) || GLOBAL.is_enabled()
}

fn sink_push(ev: SpanEvent) {
    let buffered = LOCAL_SINK.with(|s| {
        if let Some(buf) = s.borrow_mut().as_mut() {
            buf.push(ev);
            true
        } else {
            false
        }
    });
    if !buffered && GLOBAL.is_enabled() {
        GLOBAL.push(ev);
    }
}

/// Append a batch of already-completed events to this thread's sink —
/// the local capture buffer when one is installed (nested parallel
/// maps), else the global recorder. The traced parallel map calls this
/// once per slot, in slot order, after the join.
pub fn sink_append(evs: Vec<SpanEvent>) {
    if evs.is_empty() {
        return;
    }
    let buffered = LOCAL_SINK.with(|s| {
        if let Some(buf) = s.borrow_mut().as_mut() {
            buf.extend(evs.iter().copied());
            true
        } else {
            false
        }
    });
    if !buffered && GLOBAL.is_enabled() {
        GLOBAL.extend(evs);
    }
}

/// Run `f` with a fresh thread-local event buffer installed and return
/// its result together with every event `f`'s spans emitted, in
/// completion order. Re-entrant: a capture inside a capture restores
/// the outer buffer when it finishes.
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<SpanEvent>) {
    let prev = LOCAL_SINK.with(|s| s.borrow_mut().replace(Vec::new()));
    let r = f();
    let taken = LOCAL_SINK.with(|s| {
        let mut slot = s.borrow_mut();
        std::mem::replace(&mut *slot, prev)
    });
    (r, taken.unwrap_or_default())
}

/// An RAII span. Construct with [`Span::enter`] (fully inert when
/// recording is off) or [`Span::timed`] (always measures; the call
/// site reads the elapsed seconds from [`Span::finish`]). Dropping a
/// span closes it.
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    id: u64,
    parent: u64,
    /// Will this span emit a [`SpanEvent`] when it closes?
    record: bool,
    /// Was the clock read at construction (so elapsed is meaningful)?
    timed: bool,
    done: bool,
}

impl Span {
    /// Open a span that records only if recording is active right now.
    /// On the disabled path this reads no clock and takes no lock.
    pub fn enter(name: &'static str, cat: &'static str) -> Span {
        let record = recording_active();
        Span::open(name, cat, record, record)
    }

    /// Open a span that always reads the clock, for call sites that
    /// consume the elapsed time themselves (explore's phase timings).
    /// Still emits a [`SpanEvent`] only when recording is active.
    pub fn timed(name: &'static str, cat: &'static str) -> Span {
        Span::open(name, cat, recording_active(), true)
    }

    fn open(name: &'static str, cat: &'static str, record: bool, timed: bool) -> Span {
        let (start_ns, id, parent) = if record {
            let id = GLOBAL.next_id.fetch_add(1, Ordering::Relaxed);
            let parent = PARENTS.with(|p| {
                let mut p = p.borrow_mut();
                let parent = p.last().copied().unwrap_or(0);
                p.push(id);
                parent
            });
            (clock::now_ns(), id, parent)
        } else {
            (if timed { clock::now_ns() } else { 0 }, 0, 0)
        };
        Span { name, cat, start_ns, id, parent, record, timed, done: false }
    }

    /// Close the span now and return the elapsed wall time in seconds
    /// (0.0 for an untimed, unrecorded span).
    pub fn finish(mut self) -> f64 {
        if self.done {
            return 0.0;
        }
        let end_ns = if self.record || self.timed { clock::now_ns() } else { self.start_ns };
        let dur = end_ns.saturating_sub(self.start_ns);
        self.close(dur);
        if self.timed {
            dur as f64 * 1e-9
        } else {
            0.0
        }
    }

    fn close(&mut self, dur_ns: u64) {
        self.done = true;
        if self.record {
            PARENTS.with(|p| {
                let mut p = p.borrow_mut();
                debug_assert_eq!(p.last(), Some(&self.id), "span drop order is LIFO");
                p.pop();
            });
            sink_push(SpanEvent {
                name: self.name,
                cat: self.cat,
                start_ns: self.start_ns,
                dur_ns,
                tid: tid(),
                id: self.id,
                parent: self.parent,
            });
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.done {
            let dur = if self.record || self.timed {
                clock::now_ns().saturating_sub(self.start_ns)
            } else {
                0
            };
            self.close(dur);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests use `capture` (thread-local sinks) only, so they are
    // immune to other tests toggling the global recorder in parallel.

    #[test]
    fn disabled_spans_emit_nothing_and_cost_no_ids() {
        let (_, evs) = capture(|| {
            // a capture buffer *is* a sink, so open the inert spans on
            // a thread with no sink at all
            std::thread::scope(|s| {
                s.spawn(|| {
                    let sp = Span::enter("noop", "test");
                    drop(sp);
                })
                .join()
                .unwrap();
            });
        });
        assert!(evs.is_empty());
    }

    #[test]
    fn capture_collects_nested_spans_with_parent_links() {
        let ((), evs) = capture(|| {
            let _outer = Span::enter("outer", "test");
            {
                let _inner = Span::enter("inner", "test");
                let _leaf = Span::enter("leaf", "test");
            }
            let _sibling = Span::enter("sibling", "test");
        });
        // completion order: innermost first
        let names: Vec<&str> = evs.iter().map(|e| e.name).collect();
        assert_eq!(names, ["leaf", "inner", "sibling", "outer"]);
        let by_name =
            |n: &str| evs.iter().find(|e| e.name == n).unwrap_or_else(|| panic!("{n} missing"));
        let outer = by_name("outer");
        let inner = by_name("inner");
        let leaf = by_name("leaf");
        let sibling = by_name("sibling");
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.parent, outer.id);
        assert_eq!(leaf.parent, inner.id);
        assert_eq!(sibling.parent, outer.id);
        // ids are unique
        let mut ids: Vec<u64> = evs.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), evs.len());
    }

    #[test]
    fn span_intervals_nest_on_the_timeline() {
        let ((), evs) = capture(|| {
            let _outer = Span::enter("outer", "test");
            let _inner = Span::enter("inner", "test");
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let outer = evs.iter().find(|e| e.name == "outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "inner").unwrap();
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns);
        assert!(outer.dur_ns > 0);
    }

    #[test]
    fn timed_spans_return_elapsed_even_without_a_sink() {
        std::thread::scope(|s| {
            s.spawn(|| {
                let sp = Span::timed("phase", "test");
                std::thread::sleep(std::time::Duration::from_millis(2));
                let secs = sp.finish();
                assert!(secs >= 0.001, "elapsed {secs}");
            })
            .join()
            .unwrap();
        });
    }

    #[test]
    fn capture_is_reentrant_and_keeps_outer_events() {
        let ((), outer_evs) = capture(|| {
            let _a = Span::enter("a", "test");
            let ((), inner_evs) = capture(|| {
                let _b = Span::enter("b", "test");
            });
            assert_eq!(inner_evs.len(), 1);
            assert_eq!(inner_evs[0].name, "b");
            // the inner batch can be re-appended to the outer buffer
            sink_append(inner_evs);
        });
        let names: Vec<&str> = outer_evs.iter().map(|e| e.name).collect();
        assert_eq!(names, ["b", "a"]);
    }

    #[test]
    fn global_recorder_starts_disabled() {
        // must hold for golden bit-identity: nothing records unless a
        // front-end opted in
        assert!(!Recorder::disabled().is_enabled());
    }
}
