//! The sparse-MTTKRP builtin kernel (the paper's workload).
//!
//! For output mode `d` of an N-mode tensor at rank R, each nonzero
//! `x(i_0..i_{N−1})` reads the N−1 input factor rows `U_m(i_m, :)` for
//! every `m ≠ d`, performs `R·(N−1)` multiplies into the psum row
//! `A(i_d, :)` (read-modify-write of 2R psum words), and each completed
//! output slice drains R words and streams one R-element output row out.
//!
//! This file is the single owner of the paper's §IV-A closed forms —
//! [`crate::mttkrp::trace::mode_totals`] delegates here — and the
//! bit-identity baseline of the kernel layer: its access stream, charges
//! and totals reproduce the pre-IR engines' numbers exactly (pinned by
//! `rust/tests/engine_agreement.rs`).

use crate::kernel::{input_modes, KernelTotals, SparseKernel};
use crate::pe::exec::{ExecCharge, ExecUnit};
use crate::tensor::coo::SparseTensor;

/// Sparse MTTKRP: `A(i_d,:) += x · ⊙_{m≠d} U_m(i_m,:)` per nonzero.
pub struct SpMttkrp;

impl SparseKernel for SpMttkrp {
    fn name(&self) -> &'static str {
        "spmttkrp"
    }

    fn summary(&self) -> &'static str {
        "sparse matricized tensor times Khatri-Rao product (CP-ALS, the paper's kernel)"
    }

    fn read_modes(&self, tensor: &SparseTensor, mode: usize) -> Vec<usize> {
        input_modes(tensor, mode)
    }

    fn nnz_exec(&self, exec: &ExecUnit, n_modes: usize) -> ExecCharge {
        exec.nonzero(n_modes)
    }

    fn drain_exec(&self, exec: &ExecUnit, _n_modes: usize) -> ExecCharge {
        exec.drain_slice()
    }

    fn out_row_bytes(&self, rank: usize, _n_modes: usize) -> u64 {
        4 * rank as u64
    }

    /// The §IV-A formulas: compute `N·|T|·R`, transfer
    /// `|T| + (N−1)·|T|·R + I_out·R` elements, `(N−1)·|T|` factor-row
    /// requests.
    fn totals(&self, tensor: &SparseTensor, mode: usize, rank: usize) -> KernelTotals {
        let n = tensor.n_modes() as u64;
        let t = tensor.nnz() as u64;
        let r = rank as u64;
        let i_out = tensor.dims[mode];
        KernelTotals {
            compute_ops: n * t * r,
            transfer_elements: t + (n - 1) * t * r + i_out * r,
            factor_requests: (n - 1) * t,
            output_rows_written: crate::kernel::output_rows_written(tensor, mode),
            output_rows_bound: i_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::pipeline::ArrayTiming;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;
    use crate::tensor::gen;

    #[test]
    fn reads_every_input_mode_in_ascending_order() {
        let t = gen::random(&[10, 12, 14, 16], 500, 2);
        assert_eq!(SpMttkrp.read_modes(&t, 0), vec![1, 2, 3]);
        assert_eq!(SpMttkrp.read_modes(&t, 2), vec![0, 1, 3]);
        assert_eq!(SpMttkrp.read_modes(&t, 3), vec![0, 1, 2]);
    }

    #[test]
    fn charges_delegate_to_the_exec_unit() {
        let exec = ExecUnit::new(80, 16, ArrayTiming::new(&osram(), FABRIC_HZ, 1), 8);
        assert_eq!(SpMttkrp.nnz_exec(&exec, 3), exec.nonzero(3));
        assert_eq!(SpMttkrp.drain_exec(&exec, 3), exec.drain_slice());
        assert_eq!(SpMttkrp.out_row_bytes(16, 3), 64);
    }

    #[test]
    fn totals_match_the_paper_formulas() {
        let t = gen::random(&[10, 20, 30], 500, 1);
        let m = SpMttkrp.totals(&t, 0, 16);
        assert_eq!(m.compute_ops, 3 * 500 * 16);
        assert_eq!(m.transfer_elements, 500 + 2 * 500 * 16 + 10 * 16);
        assert_eq!(m.factor_requests, 2 * 500);
        assert_eq!(m.output_rows_bound, 10);
        assert!(m.output_rows_written <= 10);
    }
}
