//! The sparse Tucker TTM-chain builtin kernel (TTMc).
//!
//! The Tucker decomposition's hot loop contracts a sparse tensor with
//! the dense factor matrices of every mode but one (the "TTM chain",
//! e.g. the Sparse Tucker FPGA accelerator literature): per nonzero
//! `x(i_0..i_{N−1})` of output mode `d`,
//!
//! ```text
//! Y(i_d, :) += x · ⊗_{m≠d} U_m(i_m, :)        (Kronecker, not Khatri-Rao)
//! ```
//!
//! The **memory-access pattern is identical to spMTTKRP** — the same
//! N−1 factor-row reads per nonzero, the same slice-grouped output — which
//! is exactly the reuse arXiv:2207.08298 argues for and why the kernel IR
//! can serve both. What changes is the arithmetic intensity and the
//! output width: the Kronecker chain runs `R + R² + … + R^{N−1}`
//! multiplies per nonzero and the output row widens to `R^{N−1}`
//! elements, so TTMc is psum/compute-bound where MTTKRP is cache-bound —
//! a genuinely different operating point for the same memory system.

use crate::kernel::{input_modes, KernelTotals, SparseKernel};
use crate::pe::exec::{ExecCharge, ExecUnit};
use crate::tensor::coo::SparseTensor;

/// Output-row width: `R^{N−1}` core elements (the contracted-core slice).
fn core_row_elems(rank: usize, n_modes: usize) -> u64 {
    (rank as u64).pow(n_modes as u32 - 1)
}

/// Kronecker-chain multiplies per nonzero: scaling `U_{m_1}` by `x` costs
/// `R`, then each further factor row widens the partial product by `R×`:
/// `R + R² + … + R^{N−1}`.
fn kron_mults(rank: usize, n_modes: usize) -> u64 {
    (1..n_modes as u32).map(|j| (rank as u64).pow(j)).sum()
}

/// Sparse TTM chain: `Y(i_d,:) += x · ⊗_{m≠d} U_m(i_m,:)` per nonzero.
pub struct SpTtm;

impl SparseKernel for SpTtm {
    fn name(&self) -> &'static str {
        "spttm"
    }

    fn summary(&self) -> &'static str {
        "sparse tensor times dense-matrix chain (Tucker TTMc mode product)"
    }

    fn validate(&self, tensor: &SparseTensor, mode: usize) -> Result<(), String> {
        if mode >= tensor.n_modes() {
            return Err(format!("mode {mode} out of range for {}-mode tensor", tensor.n_modes()));
        }
        if tensor.n_modes() < 2 {
            return Err("spttm needs a tensor with at least 2 modes".into());
        }
        Ok(())
    }

    fn read_modes(&self, tensor: &SparseTensor, mode: usize) -> Vec<usize> {
        input_modes(tensor, mode)
    }

    fn nnz_exec(&self, exec: &ExecUnit, n_modes: usize) -> ExecCharge {
        let psum_words = 2 * core_row_elems(exec.rank, n_modes);
        ExecCharge {
            pipeline_cycles: kron_mults(exec.rank, n_modes) as f64 / exec.n_pipelines as f64,
            psum_cycles: psum_words as f64 / exec.psum_words_per_cycle(),
            psum_words,
        }
    }

    fn drain_exec(&self, exec: &ExecUnit, n_modes: usize) -> ExecCharge {
        let words = core_row_elems(exec.rank, n_modes);
        ExecCharge {
            pipeline_cycles: 0.0,
            psum_cycles: words as f64 / exec.psum_words_per_cycle(),
            psum_words: words,
        }
    }

    fn out_row_bytes(&self, rank: usize, n_modes: usize) -> u64 {
        4 * core_row_elems(rank, n_modes)
    }

    /// Closed forms: compute `|T|·(R + R² + … + R^{N−1} + R^{N−1})`
    /// (chain multiplies + the final accumulate), transfer
    /// `|T| + (N−1)·|T|·R + I_out·R^{N−1}` elements, `(N−1)·|T|`
    /// factor-row requests — read traffic identical to spMTTKRP, output
    /// traffic widened to the core slice.
    fn totals(&self, tensor: &SparseTensor, mode: usize, rank: usize) -> KernelTotals {
        let n = tensor.n_modes() as u64;
        let t = tensor.nnz() as u64;
        let r = rank as u64;
        let i_out = tensor.dims[mode];
        let core = core_row_elems(rank, tensor.n_modes());
        KernelTotals {
            compute_ops: t * (kron_mults(rank, tensor.n_modes()) + core),
            transfer_elements: t + (n - 1) * t * r + i_out * core,
            factor_requests: (n - 1) * t,
            output_rows_written: crate::kernel::output_rows_written(tensor, mode),
            output_rows_bound: i_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::pipeline::ArrayTiming;
    use crate::kernel::spmttkrp::SpMttkrp;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;
    use crate::tensor::gen;

    fn exec() -> ExecUnit {
        ExecUnit::new(80, 16, ArrayTiming::new(&osram(), FABRIC_HZ, 1), 8)
    }

    #[test]
    fn core_widths_and_chain_costs() {
        assert_eq!(core_row_elems(16, 2), 16);
        assert_eq!(core_row_elems(16, 3), 256);
        assert_eq!(core_row_elems(4, 5), 256);
        assert_eq!(kron_mults(16, 2), 16);
        assert_eq!(kron_mults(16, 3), 16 + 256);
        assert_eq!(kron_mults(2, 4), 2 + 4 + 8);
    }

    #[test]
    fn two_mode_ttm_degenerates_to_mttkrp() {
        // on a matrix, the TTM chain IS the MTTKRP row update — the
        // charges and totals must coincide exactly
        let e = exec();
        assert_eq!(SpTtm.nnz_exec(&e, 2), SpMttkrp.nnz_exec(&e, 2));
        assert_eq!(SpTtm.drain_exec(&e, 2), SpMttkrp.drain_exec(&e, 2));
        assert_eq!(SpTtm.out_row_bytes(16, 2), SpMttkrp.out_row_bytes(16, 2));
        let t = gen::random(&[50, 60], 800, 4);
        for mode in 0..2 {
            assert_eq!(SpTtm.totals(&t, mode, 16), SpMttkrp.totals(&t, mode, 16));
        }
    }

    #[test]
    fn three_mode_ttm_is_compute_and_psum_heavier_than_mttkrp() {
        let e = exec();
        let ttm = SpTtm.nnz_exec(&e, 3);
        let mtt = SpMttkrp.nnz_exec(&e, 3);
        assert!(ttm.pipeline_cycles > mtt.pipeline_cycles);
        assert!(ttm.psum_words > mtt.psum_words);
        assert_eq!(ttm.psum_words, 2 * 256);
        let t = gen::random(&[30, 30, 30], 1_000, 6);
        let tt = SpTtm.totals(&t, 0, 16);
        let mt = SpMttkrp.totals(&t, 0, 16);
        // identical read-side traffic, widened output
        assert_eq!(tt.factor_requests, mt.factor_requests);
        assert!(tt.compute_ops > mt.compute_ops);
        assert!(tt.transfer_elements > mt.transfer_elements);
    }

    #[test]
    fn validates_arity() {
        let m = SparseTensor::new("vec", vec![8]);
        assert!(SpTtm.validate(&m, 0).is_err());
        let t = gen::random(&[8, 8], 10, 1);
        assert!(SpTtm.validate(&t, 0).is_ok());
        assert!(SpTtm.validate(&t, 2).is_err());
    }
}
