//! The sparse-matrix × dense-matrix (SpMM) builtin kernel.
//!
//! `Y = X_(d) · U`: per nonzero, read **one** dense-operand row
//! `U(i_c, :)` (where `i_c` is the first non-output coordinate), run `R`
//! multiplies into the psum row `Y(i_d, :)` and drain R words per
//! completed output slice. On a 2-mode tensor this is literal SpMM — the
//! degenerate case of the MTTKRP family with a single input slot. On an
//! N-mode tensor it prices the *matricized, batched* SpMM: the remaining
//! coordinates ride along in the nonzero stream as batch indices and
//! touch no factor matrix, so the cache subsystem sees exactly one
//! request per nonzero — the lightest read-side workload the memory
//! system serves, and the sharpest contrast to [`crate::kernel::spttm`]'s
//! compute-heavy chain on the identical streaming machinery.

use crate::kernel::{KernelTotals, SparseKernel};
use crate::pe::exec::{ExecCharge, ExecUnit};
use crate::tensor::coo::SparseTensor;

/// The dense-operand mode: the first tensor mode that is not the output
/// mode (mode 1 when `mode == 0`, mode 0 otherwise).
fn dense_mode(mode: usize) -> usize {
    usize::from(mode == 0)
}

/// Sparse matrix × dense matrix: `Y(i_d,:) += x · U(i_c,:)` per nonzero.
pub struct SpMm;

impl SparseKernel for SpMm {
    fn name(&self) -> &'static str {
        "spmm"
    }

    fn summary(&self) -> &'static str {
        "sparse matrix times dense matrix (2-mode degenerate case; batched when N>2)"
    }

    fn validate(&self, tensor: &SparseTensor, mode: usize) -> Result<(), String> {
        if mode >= tensor.n_modes() {
            return Err(format!("mode {mode} out of range for {}-mode tensor", tensor.n_modes()));
        }
        if tensor.n_modes() < 2 {
            return Err("spmm needs a tensor with at least 2 modes".into());
        }
        Ok(())
    }

    fn read_modes(&self, _tensor: &SparseTensor, mode: usize) -> Vec<usize> {
        vec![dense_mode(mode)]
    }

    fn nnz_exec(&self, exec: &ExecUnit, _n_modes: usize) -> ExecCharge {
        // one scaled row: R multiplies (accumulate fused), 2R psum words
        exec.nonzero(2)
    }

    fn drain_exec(&self, exec: &ExecUnit, _n_modes: usize) -> ExecCharge {
        exec.drain_slice()
    }

    fn out_row_bytes(&self, rank: usize, _n_modes: usize) -> u64 {
        4 * rank as u64
    }

    /// Closed forms: compute `2·|T|·R` (R multiplies + R accumulates),
    /// transfer `|T| + |T|·R + I_out·R` elements, `|T|` factor-row
    /// requests.
    fn totals(&self, tensor: &SparseTensor, mode: usize, rank: usize) -> KernelTotals {
        let t = tensor.nnz() as u64;
        let r = rank as u64;
        let i_out = tensor.dims[mode];
        KernelTotals {
            compute_ops: 2 * t * r,
            transfer_elements: t + t * r + i_out * r,
            factor_requests: t,
            output_rows_written: crate::kernel::output_rows_written(tensor, mode),
            output_rows_bound: i_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::pipeline::ArrayTiming;
    use crate::kernel::spmttkrp::SpMttkrp;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;
    use crate::tensor::gen;

    #[test]
    fn reads_exactly_one_dense_row_per_nonzero() {
        let t = gen::random(&[10, 20, 30], 400, 3);
        assert_eq!(SpMm.read_modes(&t, 0), vec![1]);
        assert_eq!(SpMm.read_modes(&t, 1), vec![0]);
        assert_eq!(SpMm.read_modes(&t, 2), vec![0]);
    }

    #[test]
    fn two_mode_spmm_equals_two_mode_mttkrp() {
        // the advertised degeneracy: on a matrix the three-way family
        // collapses and spmm must price identically to spmttkrp
        let e = ExecUnit::new(80, 16, ArrayTiming::new(&osram(), FABRIC_HZ, 1), 8);
        assert_eq!(SpMm.nnz_exec(&e, 2), SpMttkrp.nnz_exec(&e, 2));
        assert_eq!(SpMm.drain_exec(&e, 2), SpMttkrp.drain_exec(&e, 2));
        let t = gen::random(&[40, 50], 700, 9);
        for mode in 0..2 {
            assert_eq!(SpMm.read_modes(&t, mode), SpMttkrp.read_modes(&t, mode));
            assert_eq!(SpMm.totals(&t, mode, 16), SpMttkrp.totals(&t, mode, 16));
        }
    }

    #[test]
    fn totals_count_a_single_request_per_nonzero() {
        let t = gen::random(&[10, 20, 30], 400, 5);
        let m = SpMm.totals(&t, 0, 16);
        assert_eq!(m.factor_requests, 400);
        assert_eq!(m.compute_ops, 2 * 400 * 16);
        assert_eq!(m.transfer_elements, 400 + 400 * 16 + 10 * 16);
    }

    #[test]
    fn validates_arity() {
        let v = SparseTensor::new("vec", vec![8]);
        assert!(SpMm.validate(&v, 0).is_err());
        let t = gen::random(&[8, 8], 10, 1);
        assert!(SpMm.validate(&t, 1).is_ok());
    }
}
