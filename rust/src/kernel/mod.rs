//! Sparse-kernel layer: the workload axis of the simulator.
//!
//! "Towards Programmable Memory Controller for Tensor Decomposition"
//! (arXiv:2207.08298) observes that what a tensor accelerator actually
//! reuses across workloads is the **memory-access pattern**, not the
//! kernel arithmetic. This module makes that the architecture: a
//! [`SparseKernel`] describes one sparse workload as
//!
//! 1. a chunked **access-stream IR** ([`ir`]): per nonzero, which factor
//!    rows are read; per output slice, where the psum drain / output-row
//!    write falls — generated lazily in O(chunk) memory and delivered
//!    through the zero-allocation [`AccessStream::fill`] scratch-reuse
//!    API (the engines' hot path) or the owned-chunk iterator;
//! 2. per-nonzero / per-slice **execution charges** against the PE's
//!    pipelines and psum buffer;
//! 3. its own **closed-form totals** ([`KernelTotals`], the §IV-A-style
//!    compute/traffic formulas) the tests cross-check the simulated
//!    traffic against.
//!
//! Both simulation engines ([`crate::sim::engine`], [`crate::sim::event`])
//! consume only this interface, so any kernel runs on either backend, on
//! any registry technology, with no per-kernel code in the engines.
//!
//! Builtins ([`KernelKind`], `--kernel` on the CLI):
//!
//! | name       | workload                                                    |
//! |------------|-------------------------------------------------------------|
//! | `spmttkrp` | sparse MTTKRP (CP-ALS) — the paper's kernel, bit-identical  |
//! | `spttm`    | sparse TTM-chain (Tucker mode product, TTMc)                |
//! | `spmm`     | sparse matrix × dense matrix (the 2-mode degenerate case)   |

pub mod ir;
pub mod spmm;
pub mod spmttkrp;
pub mod spttm;

use crate::pe::exec::{ExecCharge, ExecUnit};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

pub use ir::{AccessChunk, AccessStream, FactorRead, DEFAULT_CHUNK_NNZ};

/// Closed-form per-mode totals of a kernel (the generalization of the
/// paper's §IV-A MTTKRP formulas; see each builtin for its derivation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelTotals {
    /// Multiply/accumulate operations for the whole mode.
    pub compute_ops: u64,
    /// Elements transferred from/to external memory.
    pub transfer_elements: u64,
    /// Factor-row *requests* the cache subsystem sees.
    pub factor_requests: u64,
    /// Output rows actually written (non-empty slices).
    pub output_rows_written: u64,
    /// The paper-style bound: the full output-mode dimension.
    pub output_rows_bound: u64,
}

/// One sparse workload, described entirely by its access stream, its
/// execution charges and its closed-form totals.
///
/// Contract (the engines rely on it):
/// * [`stream`](Self::stream) yields every nonzero of the slice range
///   exactly once, in mode-view order, with exactly
///   `read_modes().len()` [`FactorRead`]s per nonzero in slot order;
/// * slot `j` reads rows of the factor matrix for tensor mode
///   `read_modes()[j]` (its row count bounds the bypass decision);
/// * each chunk's memory is bounded by the requested chunk size — a
///   kernel never materializes the full trace.
pub trait SparseKernel: Send + Sync {
    /// Short stable name (`spmttkrp`, `spttm`, `spmm`) used by the CLI,
    /// reports and sweep tables.
    fn name(&self) -> &'static str;

    /// One-line human description for listings.
    fn summary(&self) -> &'static str;

    /// Is this kernel defined for `tensor` / `mode`? The engines check
    /// this before simulating; the CLI surfaces the message.
    fn validate(&self, tensor: &SparseTensor, mode: usize) -> Result<(), String> {
        if mode >= tensor.n_modes() {
            return Err(format!("mode {mode} out of range for {}-mode tensor", tensor.n_modes()));
        }
        Ok(())
    }

    /// Tensor modes whose factor matrix is read per nonzero, in slot
    /// order. Slot `j` of every [`FactorRead`] refers to entry `j` here.
    fn read_modes(&self, tensor: &SparseTensor, mode: usize) -> Vec<usize>;

    /// Per-nonzero execution charge (pipelines + psum) on `exec`.
    fn nnz_exec(&self, exec: &ExecUnit, n_modes: usize) -> ExecCharge;

    /// Per-completed-slice psum drain charge on `exec`.
    fn drain_exec(&self, exec: &ExecUnit, n_modes: usize) -> ExecCharge;

    /// Bytes of one output row streamed out per completed slice.
    fn out_row_bytes(&self, rank: usize, n_modes: usize) -> u64;

    /// The kernel's closed-form totals for `tensor` / `mode` at `rank`.
    fn totals(&self, tensor: &SparseTensor, mode: usize, rank: usize) -> KernelTotals;

    /// Chunked access-program stream for one PE's slice range of `view`
    /// (which must be `ModeView::build(tensor, view.mode)`). Drive it
    /// with [`AccessStream::fill`] for the zero-allocation scratch-reuse
    /// loop, or iterate it for owned chunks.
    fn stream<'a>(
        &self,
        tensor: &'a SparseTensor,
        view: &'a ModeView,
        slices: (usize, usize),
        chunk_nnz: usize,
    ) -> AccessStream<'a> {
        AccessStream::new(tensor, view, slices, self.read_modes(tensor, view.mode), chunk_nnz)
    }
}

/// All tensor modes except the output mode, ascending — the read set of
/// the MTTKRP / TTM-chain family (shared by their `read_modes`).
pub fn input_modes(tensor: &SparseTensor, mode: usize) -> Vec<usize> {
    (0..tensor.n_modes()).filter(|&m| m != mode).collect()
}

/// Non-empty output slices (distinct `mode` coordinates) of a tensor —
/// the `output_rows_written` term of every builtin's closed forms,
/// counted in one O(nnz) pass without sorting or materializing a
/// [`ModeView`] (whose `n_slices()` this must always equal; the kernel
/// tests cross-check the two). Dense modes use a dim-sized bitmap,
/// sparse (dim ≫ nnz) modes a hash set, mirroring the view builder's
/// own strategy split.
pub fn output_rows_written(tensor: &SparseTensor, mode: usize) -> u64 {
    let dim = tensor.dims[mode] as usize;
    let nnz = tensor.nnz();
    if dim <= 4 * nnz + 1024 {
        let mut seen = vec![false; dim];
        let mut n = 0u64;
        for &i in &tensor.indices[mode] {
            if !seen[i as usize] {
                seen[i as usize] = true;
                n += 1;
            }
        }
        n
    } else {
        let distinct: std::collections::HashSet<u32> =
            tensor.indices[mode].iter().copied().collect();
        distinct.len() as u64
    }
}

/// Kernel selector: every builtin workload, by name (the workload
/// counterpart of [`crate::sim::EngineKind`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Sparse MTTKRP (the paper's kernel) — the default.
    #[default]
    Spmttkrp,
    /// Sparse Tucker TTM-chain (TTMc).
    Spttm,
    /// Sparse matrix × dense matrix.
    Spmm,
}

impl KernelKind {
    /// Every builtin kernel, in CLI listing order.
    pub const ALL: [KernelKind; 3] = [KernelKind::Spmttkrp, KernelKind::Spttm, KernelKind::Spmm];

    /// The kernel implementation this selector names.
    pub fn kernel(self) -> &'static dyn SparseKernel {
        match self {
            KernelKind::Spmttkrp => &spmttkrp::SpMttkrp,
            KernelKind::Spttm => &spttm::SpTtm,
            KernelKind::Spmm => &spmm::SpMm,
        }
    }

    /// The stable CLI/report name.
    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    /// Parse a CLI spelling; the error lists every registered kernel.
    pub fn parse(s: &str) -> Result<Self, String> {
        Self::ALL.into_iter().find(|k| k.name() == s).ok_or_else(|| {
            let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
            format!("unknown kernel `{s}` (registered kernels: {})", names.join(", "))
        })
    }
}

impl std::str::FromStr for KernelKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s)
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;

    #[test]
    fn kernel_kinds_parse_and_display() {
        assert_eq!(KernelKind::parse("spmttkrp"), Ok(KernelKind::Spmttkrp));
        assert_eq!(KernelKind::parse("spttm"), Ok(KernelKind::Spttm));
        assert_eq!("spmm".parse::<KernelKind>(), Ok(KernelKind::Spmm));
        let err = KernelKind::parse("mttkrp").unwrap_err();
        for name in ["spmttkrp", "spttm", "spmm"] {
            assert!(err.contains(name), "{err}");
        }
        assert_eq!(KernelKind::default(), KernelKind::Spmttkrp);
        assert_eq!(KernelKind::Spttm.to_string(), "spttm");
    }

    #[test]
    fn builtin_names_are_unique_and_stable() {
        let names: Vec<&str> = KernelKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(names, vec!["spmttkrp", "spttm", "spmm"]);
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Ok(k));
            assert!(!k.kernel().summary().is_empty());
        }
    }

    #[test]
    fn every_builtin_streams_every_nonzero_once() {
        let t = gen::random(&[20, 30, 40], 1_500, 4);
        let view = crate::tensor::csf::ModeView::build(&t, 1);
        for k in KernelKind::ALL {
            let kernel = k.kernel();
            let rpn = kernel.read_modes(&t, 1).len();
            let mut nnz = 0usize;
            let mut slices = 0usize;
            for c in kernel.stream(&t, &view, (0, view.n_slices()), 128) {
                assert_eq!(c.reads.len(), c.n_nnz * rpn, "{k}");
                nnz += c.n_nnz;
                slices += c.slice_ends.len();
            }
            assert_eq!(nnz, t.nnz(), "{k}");
            assert_eq!(slices, view.n_slices(), "{k}");
        }
    }

    #[test]
    fn totals_are_consistent_with_the_stream() {
        // factor_requests must equal the number of FactorRead ops the
        // stream emits — the IR and the closed forms may never diverge
        let t = gen::random(&[25, 35, 45], 2_000, 8);
        for k in KernelKind::ALL {
            let kernel = k.kernel();
            for mode in 0..t.n_modes() {
                let view = crate::tensor::csf::ModeView::build(&t, mode);
                let reads: u64 = kernel
                    .stream(&t, &view, (0, view.n_slices()), 256)
                    .map(|c| c.reads.len() as u64)
                    .sum();
                let totals = kernel.totals(&t, mode, 16);
                assert_eq!(reads, totals.factor_requests, "{k} mode {mode}");
                assert_eq!(totals.output_rows_written, view.n_slices() as u64);
                assert_eq!(totals.output_rows_bound, t.dims[mode]);
                assert!(totals.compute_ops > 0);
                assert!(totals.transfer_elements > totals.factor_requests);
            }
        }
    }
}
