//! The access-stream intermediate representation.
//!
//! A sparse kernel's memory behaviour is a *stream of access programs*:
//! per nonzero, which factor-matrix rows are read (the cache-routed §IV-A
//! type-1/type-3 traffic), and where the output-slice boundaries fall
//! (each completed slice drains the psum buffer and emits one output row
//! through the stream DMA). Both simulation engines consume exactly this
//! stream — nothing kernel-specific survives inside them.
//!
//! The stream is **chunked**: [`AccessStream`] yields [`AccessChunk`]s of
//! at most `chunk_nnz` nonzeros, so a PE's walk over a multi-hundred-
//! million-nonzero tensor needs O(chunk) live memory — the full trace is
//! never materialized. A chunk may end mid-slice; a slice boundary is
//! recorded only in the chunk where the slice's last nonzero retires, so
//! slices larger than a chunk (a single hot output row) stream correctly.
//!
//! Op ordering is part of the cross-engine bit-identity contract: within
//! a chunk, nonzeros appear in mode-view order and each nonzero's factor
//! reads appear in ascending slot order — the exact order the
//! pre-refactor engines issued [`MemoryController::factor_row_load`]
//! calls in, so the functional caches see an identical request sequence.
//!
//! [`MemoryController::factor_row_load`]: crate::controller::mc::MemoryController::factor_row_load

use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

/// Default chunk granularity, in nonzeros. Large enough to amortize the
/// per-chunk `Vec` allocation and the index-copy pass over the ≥ 64 Ki
/// cache lookups each chunk funds (the copy is the deliberate cost of a
/// kernel-agnostic owned-chunk iterator — a scratch-reuse fill API would
/// save it at the price of lending semantics every consumer must thread),
/// small enough that a chunk (≤ `64 Ki × reads_per_nnz` 8-byte ops)
/// stays cache/memory friendly.
pub const DEFAULT_CHUNK_NNZ: usize = 65_536;

/// One factor-row read op: load row `row` of input slot `slot` (the
/// engine routes the slot through its cache / bypass policy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FactorRead {
    pub slot: u32,
    pub row: u32,
}

/// A chunk of one PE's access stream.
///
/// `reads` is flat and nonzero-major: nonzero `i` of the chunk owns
/// `reads[i*rpn .. (i+1)*rpn]` where `rpn` is the kernel's fixed
/// reads-per-nonzero count ([`super::SparseKernel::read_modes`] length).
/// `slice_ends` holds strictly-ascending nonzero positions (chunk-local,
/// 0-based) after which an output slice completes.
#[derive(Clone, Debug, Default)]
pub struct AccessChunk {
    /// Nonzeros retired by this chunk.
    pub n_nnz: usize,
    /// Flattened factor-read ops, `rpn` per nonzero.
    pub reads: Vec<FactorRead>,
    /// Chunk-local positions whose nonzero completes an output slice.
    pub slice_ends: Vec<u32>,
}

/// Chunked iterator over one PE's slice range `[slo, shi)` of a mode
/// view: the default [`super::SparseKernel::stream`] implementation. Each
/// nonzero emits one [`FactorRead`] per entry of `read_modes`, in order.
pub struct AccessStream<'a> {
    tensor: &'a SparseTensor,
    view: &'a ModeView,
    read_modes: Vec<usize>,
    chunk_nnz: usize,
    /// Next slice to drain from, and the position already consumed
    /// within it (a slice may span chunks).
    s: usize,
    shi: usize,
    k_in_slice: usize,
}

impl<'a> AccessStream<'a> {
    /// Stream `view`'s slices `[slices.0, slices.1)`, reading the listed
    /// tensor modes per nonzero, `chunk_nnz` nonzeros per chunk.
    pub fn new(
        tensor: &'a SparseTensor,
        view: &'a ModeView,
        slices: (usize, usize),
        read_modes: Vec<usize>,
        chunk_nnz: usize,
    ) -> Self {
        let (slo, shi) = slices;
        assert!(slo <= shi && shi <= view.n_slices(), "slice range ({slo},{shi}) out of bounds");
        assert!(chunk_nnz > 0, "chunk size must be positive");
        AccessStream { tensor, view, read_modes, chunk_nnz, s: slo, shi, k_in_slice: 0 }
    }
}

impl Iterator for AccessStream<'_> {
    type Item = AccessChunk;

    fn next(&mut self) -> Option<AccessChunk> {
        if self.s >= self.shi {
            return None;
        }
        let rpn = self.read_modes.len();
        // allocation bounded by min(chunk size, remaining work) — the
        // O(chunk)-memory contract, robust to caller-supplied huge sizes
        let remaining = (self.view.slice_ptr[self.shi] - self.view.slice_ptr[self.s]) as usize
            - self.k_in_slice;
        let take_cap = self.chunk_nnz.min(remaining);
        let mut chunk = AccessChunk {
            n_nnz: 0,
            reads: Vec::with_capacity(take_cap * rpn),
            slice_ends: Vec::new(),
        };
        while self.s < self.shi && chunk.n_nnz < self.chunk_nnz {
            let slice = self.view.slice(self.s);
            let take = (self.chunk_nnz - chunk.n_nnz).min(slice.len() - self.k_in_slice);
            for &k in &slice[self.k_in_slice..self.k_in_slice + take] {
                for (j, &m) in self.read_modes.iter().enumerate() {
                    let row = self.tensor.indices[m][k as usize];
                    chunk.reads.push(FactorRead { slot: j as u32, row });
                }
            }
            chunk.n_nnz += take;
            self.k_in_slice += take;
            if self.k_in_slice == slice.len() {
                // the slice's last nonzero retired inside this chunk
                chunk.slice_ends.push((chunk.n_nnz - 1) as u32);
                self.s += 1;
                self.k_in_slice = 0;
            }
        }
        Some(chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;

    fn stream_all(
        t: &SparseTensor,
        view: &ModeView,
        modes: Vec<usize>,
        chunk: usize,
    ) -> Vec<AccessChunk> {
        AccessStream::new(t, view, (0, view.n_slices()), modes, chunk).collect()
    }

    #[test]
    fn covers_every_nonzero_and_slice_exactly_once() {
        let t = gen::random(&[40, 30, 20], 2_000, 5);
        let view = ModeView::build(&t, 0);
        for chunk_nnz in [1, 7, 64, 10_000] {
            let chunks = stream_all(&t, &view, vec![1, 2], chunk_nnz);
            let nnz: usize = chunks.iter().map(|c| c.n_nnz).sum();
            let slices: usize = chunks.iter().map(|c| c.slice_ends.len()).sum();
            assert_eq!(nnz, t.nnz(), "chunk {chunk_nnz}");
            assert_eq!(slices, view.n_slices(), "chunk {chunk_nnz}");
            for c in &chunks {
                assert!(c.n_nnz <= chunk_nnz);
                assert_eq!(c.reads.len(), c.n_nnz * 2);
                // slice_ends strictly ascending and in range
                for w in c.slice_ends.windows(2) {
                    assert!(w[0] < w[1]);
                }
                for &p in &c.slice_ends {
                    assert!((p as usize) < c.n_nnz);
                }
            }
        }
    }

    #[test]
    fn chunking_never_changes_the_op_sequence() {
        let t = gen::random(&[16, 64, 64], 3_000, 9);
        let view = ModeView::build(&t, 0);
        let whole: Vec<FactorRead> = stream_all(&t, &view, vec![1, 2], usize::MAX / 2)
            .into_iter()
            .flat_map(|c| c.reads)
            .collect();
        for chunk_nnz in [1, 3, 100] {
            let split: Vec<FactorRead> = stream_all(&t, &view, vec![1, 2], chunk_nnz)
                .into_iter()
                .flat_map(|c| c.reads)
                .collect();
            assert_eq!(whole, split, "chunk {chunk_nnz}");
        }
    }

    #[test]
    fn reads_follow_mode_view_order() {
        let t = gen::random(&[8, 32], 200, 3);
        let view = ModeView::build(&t, 0);
        let chunks = stream_all(&t, &view, vec![1], 64);
        let mut it = chunks.iter().flat_map(|c| c.reads.iter());
        for s in 0..view.n_slices() {
            for &k in view.slice(s) {
                let r = it.next().unwrap();
                assert_eq!(r.slot, 0);
                assert_eq!(r.row, t.indices[1][k as usize]);
            }
        }
        assert!(it.next().is_none());
    }

    #[test]
    fn slices_spanning_chunks_end_in_the_right_chunk() {
        // one giant slice (single output row) must stream across many
        // chunks and record exactly one slice end, in the last chunk
        let mut t = SparseTensor::new("hot", vec![4, 64]);
        for k in 0..1_000u32 {
            t.push(&[2, k % 64], 1.0);
        }
        let view = ModeView::build(&t, 0);
        assert_eq!(view.n_slices(), 1);
        let chunks = stream_all(&t, &view, vec![1], 64);
        assert!(chunks.len() > 10);
        for c in &chunks[..chunks.len() - 1] {
            assert!(c.slice_ends.is_empty());
        }
        assert_eq!(chunks.last().unwrap().slice_ends.len(), 1);
    }

    #[test]
    fn empty_range_yields_no_chunks() {
        let t = gen::random(&[8, 8], 100, 1);
        let view = ModeView::build(&t, 0);
        let n = view.n_slices();
        assert_eq!(AccessStream::new(&t, &view, (n, n), vec![1], 16).count(), 0);
        let e = SparseTensor::new("e", vec![4, 4]);
        let ev = ModeView::build(&e, 0);
        assert_eq!(AccessStream::new(&e, &ev, (0, 0), vec![1], 16).count(), 0);
    }
}
