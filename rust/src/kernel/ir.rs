//! The access-stream intermediate representation.
//!
//! A sparse kernel's memory behaviour is a *stream of access programs*:
//! per nonzero, which factor-matrix rows are read (the cache-routed §IV-A
//! type-1/type-3 traffic), and where the output-slice boundaries fall
//! (each completed slice drains the psum buffer and emits one output row
//! through the stream DMA). Both simulation engines consume exactly this
//! stream — nothing kernel-specific survives inside them.
//!
//! The stream is **chunked**: [`AccessStream`] produces [`AccessChunk`]s
//! of at most `chunk_nnz` nonzeros, so a PE's walk over a multi-hundred-
//! million-nonzero tensor needs O(chunk) live memory — the full trace is
//! never materialized. A chunk may end mid-slice; a slice boundary is
//! recorded only in the chunk where the slice's last nonzero retires, so
//! slices larger than a chunk (a single hot output row) stream correctly.
//!
//! Chunks are delivered two ways, off one shared generator loop:
//!
//! * [`AccessStream::fill`] — the engines' hot path: refills a
//!   caller-owned scratch [`AccessChunk`] in place. After the first fill
//!   sizes the scratch, the steady-state chunk loop performs **zero heap
//!   allocation** (the buffer pointer and capacity are stable across
//!   chunks — the IR tests pin this).
//! * the owned-chunk [`Iterator`] — a thin wrapper over `fill` for
//!   tests, examples and one-shot consumers that want plain `for` loops.
//!
//! Each [`FactorRead`] op is packed into a single `u64`, so a chunk's
//! `reads` buffer is one flat word array the engines stream through at
//! memory speed.
//!
//! Op ordering is part of the cross-engine bit-identity contract: within
//! a chunk, nonzeros appear in mode-view order and each nonzero's factor
//! reads appear in ascending slot order — the exact order the
//! pre-refactor engines issued [`MemoryController::factor_row_load`]
//! calls in, so the functional caches see an identical request sequence.
//!
//! [`MemoryController::factor_row_load`]: crate::controller::mc::MemoryController::factor_row_load

use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

/// Default chunk granularity, in nonzeros. Large enough to amortize the
/// per-chunk stream bookkeeping over the ≥ 64 Ki cache lookups each
/// chunk funds, small enough that a chunk (≤ `64 Ki × reads_per_nnz`
/// 8-byte ops) stays cache/memory friendly. Overridable per run via
/// [`crate::sim::SimBudget::chunk_nnz`] (`--chunk-nnz` on the CLI).
pub const DEFAULT_CHUNK_NNZ: usize = 65_536;

/// One factor-row read op — load row `row()` of input slot `slot()` (the
/// engine routes the slot through its cache / bypass policy) — packed
/// into a single `u64` word: slot in the high 32 bits, row in the low 32.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(transparent)]
pub struct FactorRead(u64);

impl FactorRead {
    /// Pack a (slot, row) op.
    #[inline]
    pub fn new(slot: u32, row: u32) -> Self {
        FactorRead(((slot as u64) << 32) | row as u64)
    }

    /// Input slot this op addresses (index into the kernel's
    /// [`super::SparseKernel::read_modes`] list).
    #[inline]
    pub fn slot(self) -> u32 {
        (self.0 >> 32) as u32
    }

    /// Factor-matrix row this op loads.
    #[inline]
    pub fn row(self) -> u32 {
        self.0 as u32
    }

    /// The raw packed word (slot ≪ 32 | row).
    #[inline]
    pub fn packed(self) -> u64 {
        self.0
    }
}

impl std::fmt::Debug for FactorRead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FactorRead").field("slot", &self.slot()).field("row", &self.row()).finish()
    }
}

/// A chunk of one PE's access stream.
///
/// `reads` is flat and nonzero-major: nonzero `i` of the chunk owns
/// `reads[i*rpn .. (i+1)*rpn]` where `rpn` is the kernel's fixed
/// reads-per-nonzero count ([`super::SparseKernel::read_modes`] length).
/// `slice_ends` holds strictly-ascending nonzero positions (chunk-local,
/// 0-based) after which an output slice completes.
#[derive(Clone, Debug, Default)]
pub struct AccessChunk {
    /// Nonzeros retired by this chunk.
    pub n_nnz: usize,
    /// Flattened packed factor-read ops, `rpn` per nonzero.
    pub reads: Vec<FactorRead>,
    /// Chunk-local positions whose nonzero completes an output slice.
    pub slice_ends: Vec<u32>,
}

impl AccessChunk {
    /// A scratch chunk pre-sized for `chunk_nnz` nonzeros at
    /// `reads_per_nnz` ops each, so even the first
    /// [`AccessStream::fill`] into it allocates nothing.
    pub fn with_capacity(chunk_nnz: usize, reads_per_nnz: usize) -> Self {
        AccessChunk {
            n_nnz: 0,
            reads: Vec::with_capacity(chunk_nnz * reads_per_nnz),
            slice_ends: Vec::with_capacity(chunk_nnz),
        }
    }

    /// Empty the chunk, keeping its buffers (capacity is preserved — the
    /// scratch-reuse contract `fill` relies on).
    pub fn clear(&mut self) {
        self.n_nnz = 0;
        self.reads.clear();
        self.slice_ends.clear();
    }
}

/// Chunked generator over one PE's slice range `[slo, shi)` of a mode
/// view: the default [`super::SparseKernel::stream`] implementation. Each
/// nonzero emits one [`FactorRead`] per entry of `read_modes`, in order.
pub struct AccessStream<'a> {
    tensor: &'a SparseTensor,
    view: &'a ModeView,
    read_modes: Vec<usize>,
    chunk_nnz: usize,
    /// Next slice to drain from, and the position already consumed
    /// within it (a slice may span chunks).
    s: usize,
    shi: usize,
    k_in_slice: usize,
}

impl<'a> AccessStream<'a> {
    /// Stream `view`'s slices `[slices.0, slices.1)`, reading the listed
    /// tensor modes per nonzero, `chunk_nnz` nonzeros per chunk.
    pub fn new(
        tensor: &'a SparseTensor,
        view: &'a ModeView,
        slices: (usize, usize),
        read_modes: Vec<usize>,
        chunk_nnz: usize,
    ) -> Self {
        let (slo, shi) = slices;
        assert!(slo <= shi && shi <= view.n_slices(), "slice range ({slo},{shi}) out of bounds");
        assert!(chunk_nnz > 0, "chunk size must be positive");
        AccessStream { tensor, view, read_modes, chunk_nnz, s: slo, shi, k_in_slice: 0 }
    }

    /// Ops emitted per nonzero (`read_modes` length) — the scratch-chunk
    /// sizing factor for [`AccessChunk::with_capacity`].
    pub fn reads_per_nnz(&self) -> usize {
        self.read_modes.len()
    }

    /// Refill `chunk` with the next chunk of the stream, reusing its
    /// buffers. Returns `false` (leaving `chunk` empty) once the stream
    /// is exhausted.
    ///
    /// This is the engines' zero-allocation hot path: both buffers get
    /// an exact reservation bounded by `min(chunk size, remaining work)`
    /// — `slice_ends` too, since a later chunk can close far more slices
    /// than any earlier one (many tiny slices after one giant slice) and
    /// must not regrow mid-stream — and the first chunk of a stream is
    /// its largest, so after the first fill into a given scratch the
    /// buffer pointers and capacities never change: no per-chunk heap
    /// traffic in steady state.
    pub fn fill(&mut self, chunk: &mut AccessChunk) -> bool {
        chunk.clear();
        if self.s >= self.shi {
            return false;
        }
        let rpn = self.read_modes.len();
        let remaining = (self.view.slice_ptr[self.shi] - self.view.slice_ptr[self.s]) as usize
            - self.k_in_slice;
        let take_cap = self.chunk_nnz.min(remaining);
        chunk.reads.reserve_exact(take_cap * rpn);
        chunk.slice_ends.reserve_exact(take_cap);
        while self.s < self.shi && chunk.n_nnz < self.chunk_nnz {
            let slice = self.view.slice(self.s);
            let take = (self.chunk_nnz - chunk.n_nnz).min(slice.len() - self.k_in_slice);
            for &k in &slice[self.k_in_slice..self.k_in_slice + take] {
                for (j, &m) in self.read_modes.iter().enumerate() {
                    let row = self.tensor.indices[m][k as usize];
                    chunk.reads.push(FactorRead::new(j as u32, row));
                }
            }
            chunk.n_nnz += take;
            self.k_in_slice += take;
            if self.k_in_slice == slice.len() {
                // the slice's last nonzero retired inside this chunk
                chunk.slice_ends.push((chunk.n_nnz - 1) as u32);
                self.s += 1;
                self.k_in_slice = 0;
            }
        }
        true
    }
}

/// Owned-chunk convenience path: allocates a fresh [`AccessChunk`] per
/// step and delegates to [`AccessStream::fill`], so the two delivery
/// modes can never diverge. Engines use `fill` directly.
impl Iterator for AccessStream<'_> {
    type Item = AccessChunk;

    fn next(&mut self) -> Option<AccessChunk> {
        let mut chunk = AccessChunk::default();
        if self.fill(&mut chunk) {
            Some(chunk)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;

    fn stream_all(
        t: &SparseTensor,
        view: &ModeView,
        modes: Vec<usize>,
        chunk: usize,
    ) -> Vec<AccessChunk> {
        AccessStream::new(t, view, (0, view.n_slices()), modes, chunk).collect()
    }

    #[test]
    fn packed_reads_round_trip() {
        for (slot, row) in [(0u32, 0u32), (1, 7), (2, u32::MAX), (u32::MAX, 12_345)] {
            let r = FactorRead::new(slot, row);
            assert_eq!(r.slot(), slot);
            assert_eq!(r.row(), row);
            assert_eq!(r.packed(), ((slot as u64) << 32) | row as u64);
        }
        assert_eq!(std::mem::size_of::<FactorRead>(), 8);
        let dbg = format!("{:?}", FactorRead::new(1, 42));
        assert!(dbg.contains("slot") && dbg.contains("42"), "{dbg}");
    }

    #[test]
    fn covers_every_nonzero_and_slice_exactly_once() {
        let t = gen::random(&[40, 30, 20], 2_000, 5);
        let view = ModeView::build(&t, 0);
        for chunk_nnz in [1, 7, 64, 10_000] {
            let chunks = stream_all(&t, &view, vec![1, 2], chunk_nnz);
            let nnz: usize = chunks.iter().map(|c| c.n_nnz).sum();
            let slices: usize = chunks.iter().map(|c| c.slice_ends.len()).sum();
            assert_eq!(nnz, t.nnz(), "chunk {chunk_nnz}");
            assert_eq!(slices, view.n_slices(), "chunk {chunk_nnz}");
            for c in &chunks {
                assert!(c.n_nnz <= chunk_nnz);
                assert_eq!(c.reads.len(), c.n_nnz * 2);
                // slice_ends strictly ascending and in range
                for w in c.slice_ends.windows(2) {
                    assert!(w[0] < w[1]);
                }
                for &p in &c.slice_ends {
                    assert!((p as usize) < c.n_nnz);
                }
            }
        }
    }

    #[test]
    fn chunking_never_changes_the_op_sequence() {
        let t = gen::random(&[16, 64, 64], 3_000, 9);
        let view = ModeView::build(&t, 0);
        let whole: Vec<FactorRead> = stream_all(&t, &view, vec![1, 2], usize::MAX / 2)
            .into_iter()
            .flat_map(|c| c.reads)
            .collect();
        for chunk_nnz in [1, 3, 100] {
            let split: Vec<FactorRead> = stream_all(&t, &view, vec![1, 2], chunk_nnz)
                .into_iter()
                .flat_map(|c| c.reads)
                .collect();
            assert_eq!(whole, split, "chunk {chunk_nnz}");
        }
    }

    #[test]
    fn reads_follow_mode_view_order() {
        let t = gen::random(&[8, 32], 200, 3);
        let view = ModeView::build(&t, 0);
        let chunks = stream_all(&t, &view, vec![1], 64);
        let mut it = chunks.iter().flat_map(|c| c.reads.iter());
        for s in 0..view.n_slices() {
            for &k in view.slice(s) {
                let r = it.next().unwrap();
                assert_eq!(r.slot(), 0);
                assert_eq!(r.row(), t.indices[1][k as usize]);
            }
        }
        assert!(it.next().is_none());
    }

    #[test]
    fn slices_spanning_chunks_end_in_the_right_chunk() {
        // one giant slice (single output row) must stream across many
        // chunks and record exactly one slice end, in the last chunk
        let mut t = SparseTensor::new("hot", vec![4, 64]);
        for k in 0..1_000u32 {
            t.push(&[2, k % 64], 1.0);
        }
        let view = ModeView::build(&t, 0);
        assert_eq!(view.n_slices(), 1);
        let chunks = stream_all(&t, &view, vec![1], 64);
        assert!(chunks.len() > 10);
        for c in &chunks[..chunks.len() - 1] {
            assert!(c.slice_ends.is_empty());
        }
        assert_eq!(chunks.last().unwrap().slice_ends.len(), 1);
    }

    #[test]
    fn empty_range_yields_no_chunks() {
        let t = gen::random(&[8, 8], 100, 1);
        let view = ModeView::build(&t, 0);
        let n = view.n_slices();
        assert_eq!(AccessStream::new(&t, &view, (n, n), vec![1], 16).count(), 0);
        let e = SparseTensor::new("e", vec![4, 4]);
        let ev = ModeView::build(&e, 0);
        assert_eq!(AccessStream::new(&e, &ev, (0, 0), vec![1], 16).count(), 0);
        // the fill path agrees: false immediately, chunk left empty
        let mut s = AccessStream::new(&t, &view, (n, n), vec![1], 16);
        let mut c = AccessChunk::with_capacity(16, 1);
        assert!(!s.fill(&mut c));
        assert_eq!(c.n_nnz, 0);
    }

    #[test]
    fn fill_reuses_the_scratch_buffer_without_reallocating() {
        // the zero-allocation contract: across every chunk of a
        // multi-chunk stream the scratch's buffer pointer and capacity
        // never change — steady state does no heap allocation at all
        let t = gen::random(&[64, 256, 256], 50_000, 7);
        let view = ModeView::build(&t, 0);
        let mut s = AccessStream::new(&t, &view, (0, view.n_slices()), vec![1, 2], 1024);
        let mut chunk = AccessChunk::with_capacity(1024, s.reads_per_nnz());
        let reads_ptr = chunk.reads.as_ptr();
        let reads_cap = chunk.reads.capacity();
        let ends_ptr = chunk.slice_ends.as_ptr();
        let ends_cap = chunk.slice_ends.capacity();
        let mut chunks = 0usize;
        let mut nnz = 0usize;
        while s.fill(&mut chunk) {
            assert_eq!(chunk.reads.as_ptr(), reads_ptr, "chunk {chunks} reallocated reads");
            assert_eq!(chunk.reads.capacity(), reads_cap, "chunk {chunks} regrew reads");
            assert_eq!(chunk.slice_ends.as_ptr(), ends_ptr, "chunk {chunks} reallocated ends");
            assert_eq!(chunk.slice_ends.capacity(), ends_cap, "chunk {chunks} regrew ends");
            nnz += chunk.n_nnz;
            chunks += 1;
        }
        assert!(chunks > 10, "stream must actually chunk ({chunks})");
        assert_eq!(nnz, t.nnz());
        // exhausted: further fills keep returning false, chunk left empty
        assert!(!s.fill(&mut chunk));
        assert_eq!(chunk.n_nnz, 0);
    }

    #[test]
    fn default_scratch_stabilizes_after_the_first_fill() {
        // an unsized scratch is also fine: the first fill (the stream's
        // largest chunk) sizes both buffers exactly once, then they are
        // stable — slice_ends included, even though later chunks close
        // far more slices than the first
        let t = gen::random(&[32, 128, 128], 20_000, 13);
        let view = ModeView::build(&t, 0);
        let mut s = AccessStream::new(&t, &view, (0, view.n_slices()), vec![1, 2], 512);
        let mut chunk = AccessChunk::default();
        assert!(s.fill(&mut chunk));
        let ptr = chunk.reads.as_ptr();
        let cap = chunk.reads.capacity();
        let ends_ptr = chunk.slice_ends.as_ptr();
        let ends_cap = chunk.slice_ends.capacity();
        assert!(cap <= 512 * 2, "over-allocated: {cap}");
        while s.fill(&mut chunk) {
            assert_eq!(chunk.reads.as_ptr(), ptr);
            assert_eq!(chunk.reads.capacity(), cap);
            assert_eq!(chunk.slice_ends.as_ptr(), ends_ptr);
            assert_eq!(chunk.slice_ends.capacity(), ends_cap);
        }
    }

    #[test]
    fn fill_and_iterator_produce_identical_chunks() {
        // the two delivery modes are one generator: op-for-op, chunk
        // boundary-for-chunk boundary identical
        let t = gen::random(&[40, 80, 80], 5_000, 11);
        let view = ModeView::build(&t, 0);
        for chunk_nnz in [1usize, 17, 512, 100_000] {
            let owned: Vec<AccessChunk> =
                AccessStream::new(&t, &view, (0, view.n_slices()), vec![1, 2], chunk_nnz)
                    .collect();
            let mut s = AccessStream::new(&t, &view, (0, view.n_slices()), vec![1, 2], chunk_nnz);
            let mut scratch = AccessChunk::default();
            let mut i = 0usize;
            while s.fill(&mut scratch) {
                assert_eq!(scratch.n_nnz, owned[i].n_nnz, "chunk {i} @ {chunk_nnz}");
                assert_eq!(scratch.reads, owned[i].reads, "chunk {i} @ {chunk_nnz}");
                assert_eq!(scratch.slice_ends, owned[i].slice_ends, "chunk {i} @ {chunk_nnz}");
                i += 1;
            }
            assert_eq!(i, owned.len(), "chunk count @ {chunk_nnz}");
        }
    }
}
