//! Design-space exploration: Pareto-frontier search over accelerator
//! configurations.
//!
//! The paper evaluates O-SRAM vs E-SRAM at one hand-picked design point
//! (Table I); its 1.1×–2.9× / 2.8×–8.1× claims are really claims about
//! where each memory technology lands in a larger hardware design space
//! — the question arXiv:2207.08298 poses for memory-controller
//! configurations and arXiv:2503.18206 for photonic design points. This
//! subsystem *searches* that space instead of replaying one point:
//!
//! * [`space`] — the [`space::DesignSpace`] axis grammar: knob axes over
//!   [`crate::accel::config::AcceleratorConfig`] (`n_pes`, cache
//!   capacity/ways, bank factor, rank) crossed with registry
//!   technologies and kernels, pruned by constraint predicates
//!   (structural validity, mm² area budget, wafer-scale exclusion);
//! * [`objective`] — the (runtime, energy, area) objective vector with
//!   derived EDP, and the [`objective::ObjectiveKind`] ranking selector;
//! * [`eval`] — the multi-objective evaluator: the driver path
//!   (memoized [`crate::tensor::csf::ModeView`]s, Eq. 2–3 pricing) behind
//!   a content-keyed [`eval::EvalCache`] so overlapping candidates
//!   across searches are computed once;
//! * [`key`] — the canonical, versioned cache-key serialization
//!   (every field by name, floats as bit-hex,
//!   [`key::CACHE_SCHEMA_VERSION`] prefix) that gives cache identity a
//!   compatibility contract independent of `Debug` formatting; keys are
//!   two-tier — a functional-geometry component ([`key::functional_key`],
//!   shared by every pricing of the same `{geometry, kernel, workload}`)
//!   followed by the pricing component;
//! * [`store`] — append-only on-disk persistence for the cache
//!   (checksummed records, fsync'd appends, truncate-at-first-bad-record
//!   recovery, last-record-wins key dedup on replay, and an atomic
//!   [`store::EvalStore::compact`] rewrite) so warm traffic survives
//!   the process;
//! * [`pareto`] — strict-dominance frontier extraction, scoped per
//!   kernel;
//! * [`search`] — the four-phase strategy: cheap analytic screen of the
//!   full grid, frontier extraction, **sampled** event-engine
//!   confirmation of the *entire* screened grid
//!   ([`crate::sim::SampleSpec`], default rate
//!   [`search::DEFAULT_EXPLORE_SAMPLE_RATE`]), then an exact event pass
//!   that pins the reported frontier numbers — with every
//!   analytic-vs-event or sampled-vs-exact disagreement surfaced as an
//!   [`search::ExploreDelta`] (mirroring
//!   [`crate::coordinator::driver::cross_validate`]) rather than
//!   silently dropped;
//! * [`export`] — the frontier JSON artifact.
//!
//! Candidate evaluation fans across OS threads through
//! [`crate::sim::par`] under the one-thread-budget rule, and every layer
//! is deterministic: the frontier (members, order, every f64) is
//! bit-identical at any `--threads` value. Front-ends:
//! `photon-mttkrp explore`, the `design_space` example §5, and the
//! frontier table `reproduce` prints (EXPERIMENTS.md §Explore).

pub mod eval;
pub mod export;
pub mod key;
pub mod objective;
pub mod pareto;
pub mod search;
pub mod space;
pub mod store;

pub use eval::{candidate_key, EvalCache, Evaluator};
pub use key::{eval_key, functional_key, CACHE_SCHEMA_VERSION};
pub use store::{CompactReport, EvalStore};
pub use export::{frontier_json, write_frontier_json};
pub use objective::{ObjectiveKind, Objectives};
pub use pareto::{dominates, frontier_indices};
pub use search::{
    frontier_table, run_explore, run_explore_with_cache, ExploreDelta, ExploreResult,
    ExploreSpec, FrontierPoint, PhaseTimings, DEFAULT_EXPLORE_SAMPLE_RATE,
};
pub use space::{Axis, Candidate, DesignSpace, EnumeratedSpace, Knob};
