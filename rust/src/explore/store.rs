//! Append-only on-disk persistence for the evaluation cache.
//!
//! One store = one line-oriented log file,
//! `<dir>/evals-v{CACHE_SCHEMA_VERSION}.log`, living under
//! `~/.photon-mttkrp/cache/` by default or any `--cache-dir`. Each
//! record is a single line:
//!
//! ```text
//! <fnv64:016x> <runtime_bits:016x> <energy_bits:016x> <area_bits:016x> <canonical key>
//! ```
//!
//! The three objective f64s are stored as their IEEE-754 bits, so a
//! loaded entry is bit-identical to the computed one — the same
//! contract the in-memory cache already honours. The leading FNV-1a
//! checksum covers the rest of the line, so a torn write (power loss
//! mid-append) or any editor mangling is detected per record.
//!
//! **Recovery contract:** on open, records are replayed in order until
//! the first invalid line (bad UTF-8, wrong field count, unparseable
//! hex, checksum mismatch, or a final line with no terminating
//! newline); the file is then physically truncated back to the last
//! valid record, keeping the prefix. Corruption costs the suffix, never
//! the store. Duplicate keys can appear (two processes racing on the
//! same miss append twice); replay **dedups** them — the last record
//! for a key wins, at the position of the first — and since duplicate
//! entries are bit-identical by the cache contract this loses nothing.
//! Shadowed (dead) records still occupy file bytes until
//! [`EvalStore::compact`] rewrites the log with exactly the live
//! records (`photon-mttkrp explore --compact-cache` on the CLI); the
//! rewrite goes through a temp file + atomic rename, so a crash
//! mid-compaction leaves either the old or the new log, never a torn
//! one.
//!
//! **Versioning:** the schema version is baked into the *filename*, so
//! a [`CACHE_SCHEMA_VERSION`] bump orphans old files (they are simply
//! never opened again) instead of risking a misread. Appends are
//! `fsync`'d (`sync_data`) one record at a time: an evaluation costs
//! milliseconds to seconds, so one synchronous disk flush per miss is
//! noise, and it guarantees a hit can never be served from a record
//! that would not survive a crash.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::explore::key::CACHE_SCHEMA_VERSION;
use crate::explore::objective::Objectives;

/// FNV-1a over a byte slice — the same hash family the workload tag
/// uses, applied per record as a corruption check.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

/// Serialize one record, terminating newline included.
fn encode_record(key: &str, o: &Objectives) -> String {
    let payload = format!(
        "{:016x} {:016x} {:016x} {key}",
        o.runtime_s.to_bits(),
        o.energy_j.to_bits(),
        o.area_mm2.to_bits()
    );
    format!("{:016x} {payload}\n", fnv64(payload.as_bytes()))
}

/// Parse and verify one record line (no trailing newline). `None` means
/// the line — and by the recovery contract everything after it — is
/// invalid.
fn parse_record(line: &str) -> Option<(String, Objectives)> {
    let (checksum_hex, payload) = line.split_once(' ')?;
    let checksum = u64::from_str_radix(checksum_hex, 16).ok()?;
    if checksum_hex.len() != 16 || checksum != fnv64(payload.as_bytes()) {
        return None;
    }
    let mut it = payload.splitn(4, ' ');
    let runtime = u64::from_str_radix(it.next()?, 16).ok()?;
    let energy = u64::from_str_radix(it.next()?, 16).ok()?;
    let area = u64::from_str_radix(it.next()?, 16).ok()?;
    let key = it.next()?;
    Some((
        key.to_string(),
        Objectives {
            runtime_s: f64::from_bits(runtime),
            energy_j: f64::from_bits(energy),
            area_mm2: f64::from_bits(area),
        },
    ))
}

/// What [`EvalStore::compact`] kept and reclaimed.
#[derive(Clone, Debug)]
pub struct CompactReport {
    /// The log file that was rewritten.
    pub path: PathBuf,
    /// Live records the compacted file holds.
    pub live: u64,
    /// Dead (key-shadowed) records dropped by the rewrite.
    pub dropped: u64,
    /// File size before the rewrite (after any tail recovery).
    pub bytes_before: u64,
    /// File size after the rewrite.
    pub bytes_after: u64,
}

/// The open append-only store: a validated log file plus its append
/// handle. Interior-mutable (`&EvalStore` appends), like the cache it
/// backs.
pub struct EvalStore {
    path: PathBuf,
    writer: Mutex<File>,
    loaded: u64,
    /// Valid records shadowed by a later record with the same key.
    deduped: u64,
    recovered_at: Option<u64>,
    appended: AtomicU64,
}

impl EvalStore {
    /// The default persistent location: `~/.photon-mttkrp/cache/`
    /// (falling back to the working directory when `$HOME` is unset).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HOME")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."))
            .join(".photon-mttkrp")
            .join("cache")
    }

    /// Open (creating if needed) the store under `dir`, replay every
    /// valid record, truncate off any corrupt suffix, and return the
    /// store plus the loaded `(key, objectives)` entries, deduped by
    /// key: the **last** record for a key wins, placed at the position
    /// of the key's first occurrence (so entry order is stable across
    /// re-appends of an existing key).
    pub fn open(dir: &Path) -> std::io::Result<(EvalStore, Vec<(String, Objectives)>)> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("evals-v{CACHE_SCHEMA_VERSION}.log"));
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;

        let mut entries: Vec<(String, Objectives)> = Vec::new();
        let mut index: HashMap<String, usize> = HashMap::new();
        let mut deduped = 0u64;
        let mut offset = 0usize;
        let mut recovered_at = None;
        while offset < bytes.len() {
            match bytes[offset..].iter().position(|&b| b == b'\n') {
                None => {
                    // unterminated final line: a torn append
                    recovered_at = Some(offset as u64);
                    break;
                }
                Some(rel) => {
                    let line = &bytes[offset..offset + rel];
                    match std::str::from_utf8(line).ok().and_then(parse_record) {
                        Some((key, o)) => {
                            match index.get(&key) {
                                Some(&i) => {
                                    entries[i].1 = o;
                                    deduped += 1;
                                }
                                None => {
                                    index.insert(key.clone(), entries.len());
                                    entries.push((key, o));
                                }
                            }
                            offset += rel + 1;
                        }
                        None => {
                            recovered_at = Some(offset as u64);
                            break;
                        }
                    }
                }
            }
        }
        if let Some(at) = recovered_at {
            file.set_len(at)?;
            file.sync_all()?;
        }
        drop(file);

        let writer = OpenOptions::new().append(true).open(&path)?;
        let loaded = entries.len() as u64;
        Ok((
            EvalStore {
                path,
                writer: Mutex::new(writer),
                loaded,
                deduped,
                recovered_at,
                appended: AtomicU64::new(0),
            },
            entries,
        ))
    }

    /// The log file this store reads and appends.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Live (deduped) records replayed at open.
    pub fn loaded(&self) -> u64 {
        self.loaded
    }

    /// Valid records open discarded because a later record carried the
    /// same key. These are the dead bytes [`EvalStore::compact`]
    /// reclaims.
    pub fn deduped(&self) -> u64 {
        self.deduped
    }

    /// Records appended (and fsync'd) since open.
    pub fn appended(&self) -> u64 {
        self.appended.load(Ordering::Relaxed)
    }

    /// Whether open found corruption and truncated the file (the byte
    /// offset it truncated to, when it did).
    pub fn recovered_at(&self) -> Option<u64> {
        self.recovered_at
    }

    /// Rewrite the log under `dir` with exactly the live records: open
    /// (which replays, dedups, and truncates any corrupt tail), then
    /// write the surviving entries to a temp file, fsync it, and
    /// atomically rename it over the log. A crash at any point leaves
    /// either the old or the new file — never a torn one. Returns what
    /// was kept and what was reclaimed.
    pub fn compact(dir: &Path) -> std::io::Result<CompactReport> {
        let (store, entries) = EvalStore::open(dir)?;
        let path = store.path().to_path_buf();
        let dropped = store.deduped();
        drop(store); // release the append handle before replacing the file
        let bytes_before = std::fs::metadata(&path)?.len();

        let tmp = path.with_extension("log.compact");
        {
            let mut f = File::create(&tmp)?;
            for (key, o) in &entries {
                f.write_all(encode_record(key, o).as_bytes())?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        // make the rename itself durable
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
        let bytes_after = std::fs::metadata(&path)?.len();
        Ok(CompactReport {
            path,
            live: entries.len() as u64,
            dropped,
            bytes_before,
            bytes_after,
        })
    }

    /// Append one record and fsync it. Keys are one line by the
    /// canonical-key contract; a key that somehow contains a newline is
    /// unrepresentable and is kept in-memory only.
    pub fn append(&self, key: &str, o: &Objectives) -> std::io::Result<()> {
        if key.contains('\n') || key.contains('\r') {
            return Ok(());
        }
        let record = encode_record(key, o);
        let mut writer = self.writer.lock().unwrap();
        writer.write_all(record.as_bytes())?;
        writer.sync_data()?;
        self.appended.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("photon_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn obj(x: f64) -> Objectives {
        Objectives { runtime_s: x, energy_j: 2.0 * x, area_mm2: 3.0 * x }
    }

    #[test]
    fn records_round_trip_bit_identically() {
        let o = Objectives { runtime_s: 1.0 / 3.0, energy_j: f64::MIN_POSITIVE, area_mm2: 0.0 };
        let rec = encode_record("v1|cfg{x}|wl=a b c", &o);
        let (key, got) = parse_record(rec.trim_end_matches('\n')).expect("valid record");
        assert_eq!(key, "v1|cfg{x}|wl=a b c");
        assert_eq!(got.runtime_s.to_bits(), o.runtime_s.to_bits());
        assert_eq!(got.energy_j.to_bits(), o.energy_j.to_bits());
        assert_eq!(got.area_mm2.to_bits(), o.area_mm2.to_bits());
    }

    #[test]
    fn corrupt_records_are_rejected() {
        let rec = encode_record("k", &obj(1.0));
        let line = rec.trim_end_matches('\n');
        // flip one payload byte: checksum must catch it
        let mut mangled = line.to_string().into_bytes();
        let last = mangled.len() - 1;
        mangled[last] ^= 1;
        assert!(parse_record(std::str::from_utf8(&mangled).unwrap()).is_none());
        assert!(parse_record("").is_none());
        assert!(parse_record("not a record").is_none());
    }

    #[test]
    fn store_persists_across_reopens() {
        let dir = tmp_dir("reopen");
        {
            let (store, entries) = EvalStore::open(&dir).unwrap();
            assert!(entries.is_empty());
            assert_eq!(store.loaded(), 0);
            store.append("ka", &obj(1.0)).unwrap();
            store.append("kb", &obj(2.0)).unwrap();
            assert_eq!(store.appended(), 2);
        }
        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 2);
        assert_eq!(store.recovered_at(), None);
        assert_eq!(entries[0].0, "ka");
        assert_eq!(entries[1].0, "kb");
        assert_eq!(entries[1].1.runtime_s.to_bits(), 2.0f64.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_final_record_recovers_the_prefix() {
        let dir = tmp_dir("torn");
        let path = {
            let (store, _) = EvalStore::open(&dir).unwrap();
            store.append("ka", &obj(1.0)).unwrap();
            store.append("kb", &obj(2.0)).unwrap();
            store.append("kc", &obj(3.0)).unwrap();
            store.path().to_path_buf()
        };
        // tear the last record mid-line (simulated power loss)
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();

        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 2, "the valid prefix survives");
        assert!(store.recovered_at().is_some());
        assert_eq!(entries.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(), ["ka", "kb"]);
        // the file was physically truncated: appends land cleanly after it
        store.append("kd", &obj(4.0)).unwrap();
        drop(store);
        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 3);
        assert_eq!(store.recovered_at(), None);
        assert_eq!(entries[2].0, "kd");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_prefix_empties_the_store_but_keeps_it_usable() {
        let dir = tmp_dir("garbage");
        let path = {
            let (store, _) = EvalStore::open(&dir).unwrap();
            store.append("ka", &obj(1.0)).unwrap();
            store.path().to_path_buf()
        };
        // stomp the front of the file, including invalid UTF-8
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = 0xFF;
        bytes[1] = b'!';
        std::fs::write(&path, &bytes).unwrap();

        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 0, "a corrupt first record keeps nothing");
        assert_eq!(store.recovered_at(), Some(0));
        assert!(entries.is_empty());
        store.append("kb", &obj(2.0)).unwrap();
        drop(store);
        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 1);
        assert_eq!(entries[0].0, "kb");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_dedups_keys_last_record_wins_in_first_position() {
        let dir = tmp_dir("dedup");
        {
            let (store, _) = EvalStore::open(&dir).unwrap();
            store.append("ka", &obj(1.0)).unwrap();
            store.append("kb", &obj(2.0)).unwrap();
            store.append("ka", &obj(3.0)).unwrap();
            store.append("ka", &obj(4.0)).unwrap();
        }
        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 2, "two live keys");
        assert_eq!(store.deduped(), 2, "two shadowed ka records");
        assert_eq!(
            entries.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
            ["ka", "kb"],
            "first-occurrence order is stable"
        );
        assert_eq!(entries[0].1.runtime_s.to_bits(), 4.0f64.to_bits(), "last record wins");
        assert_eq!(entries[1].1.runtime_s.to_bits(), 2.0f64.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_drops_dead_records_and_keeps_live_ones_bit_identical() {
        let dir = tmp_dir("compact");
        {
            let (store, _) = EvalStore::open(&dir).unwrap();
            store.append("ka", &obj(1.0)).unwrap();
            store.append("kb", &obj(2.0)).unwrap();
            store.append("ka", &obj(3.0)).unwrap();
            store.append("kc", &obj(1.0 / 3.0)).unwrap();
            store.append("kb", &obj(5.0)).unwrap();
        }
        let (_, before) = EvalStore::open(&dir).unwrap();

        let report = EvalStore::compact(&dir).unwrap();
        assert_eq!(report.live, 3);
        assert_eq!(report.dropped, 2);
        assert!(report.bytes_after < report.bytes_before, "{report:?}");

        let (store, after) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 3);
        assert_eq!(store.deduped(), 0, "no dead records survive compaction");
        assert_eq!(store.recovered_at(), None);
        assert_eq!(after.len(), before.len());
        for ((ka, oa), (kb, ob)) in before.iter().zip(after.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(oa.runtime_s.to_bits(), ob.runtime_s.to_bits());
            assert_eq!(oa.energy_j.to_bits(), ob.energy_j.to_bits());
            assert_eq!(oa.area_mm2.to_bits(), ob.area_mm2.to_bits());
        }
        // the compacted store appends cleanly
        store.append("kd", &obj(7.0)).unwrap();
        drop(store);
        let (store, _) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_recovers_a_torn_tail_like_open_does() {
        let dir = tmp_dir("compact_torn");
        let path = {
            let (store, _) = EvalStore::open(&dir).unwrap();
            store.append("ka", &obj(1.0)).unwrap();
            store.append("ka", &obj(2.0)).unwrap();
            store.append("kb", &obj(3.0)).unwrap();
            store.path().to_path_buf()
        };
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();

        // the torn kb record is lost to recovery; the duplicate ka is
        // compacted away
        let report = EvalStore::compact(&dir).unwrap();
        assert_eq!(report.live, 1);
        assert_eq!(report.dropped, 1);
        let (store, entries) = EvalStore::open(&dir).unwrap();
        assert_eq!(store.loaded(), 1);
        assert_eq!(store.recovered_at(), None, "compacted file is fully valid");
        assert_eq!(entries[0].0, "ka");
        assert_eq!(entries[0].1.runtime_s.to_bits(), 2.0f64.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_version_names_the_file() {
        let dir = tmp_dir("version");
        let (store, _) = EvalStore::open(&dir).unwrap();
        let name = store.path().file_name().unwrap().to_string_lossy().into_owned();
        assert_eq!(name, format!("evals-v{CACHE_SCHEMA_VERSION}.log"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
