//! Pareto-frontier extraction over the (runtime, energy, area) objective
//! vector.
//!
//! Dominance is *strict*: `a` dominates `b` iff `a` is no worse on every
//! objective and strictly better on at least one. Candidates with
//! identical objective vectors therefore never dominate each other — both
//! survive (e.g. two bank-factor twins of an optical technology, whose
//! bank cascade is structurally 1 either way).
//!
//! Dominance is only meaningful between candidates doing the *same work*,
//! so extraction takes a group key per candidate (the kernel name): a
//! cheap kernel may never "dominate" an expensive one off the frontier.
//! Extraction is deterministic — the returned indices are ascending, and
//! the result depends only on the objective values, never on thread
//! count or iteration order.

use crate::explore::objective::Objectives;

/// Does `a` strictly Pareto-dominate `b` over (runtime, energy, area)?
///
/// Objectives are expected finite (the engines and the area model only
/// produce finite positives); any NaN comparison is `false`, so a NaN
/// vector neither dominates nor is dominated.
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let no_worse =
        a.runtime_s <= b.runtime_s && a.energy_j <= b.energy_j && a.area_mm2 <= b.area_mm2;
    let better = a.runtime_s < b.runtime_s || a.energy_j < b.energy_j || a.area_mm2 < b.area_mm2;
    no_worse && better
}

/// Indices of the Pareto frontier of `objs`, in ascending index order.
/// `groups[i]` is candidate `i`'s comparison group (its kernel name);
/// only same-group candidates can dominate each other.
///
/// O(n²) pairwise — exact, deterministic, and easily fast enough for the
/// grids a design-space search enumerates (hundreds to low thousands).
pub fn frontier_indices<K: PartialEq>(objs: &[Objectives], groups: &[K]) -> Vec<usize> {
    assert_eq!(objs.len(), groups.len(), "one group key per objective vector");
    (0..objs.len())
        .filter(|&i| {
            !(0..objs.len())
                .any(|j| j != i && groups[j] == groups[i] && dominates(&objs[j], &objs[i]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(r: f64, e: f64, a: f64) -> Objectives {
        Objectives { runtime_s: r, energy_j: e, area_mm2: a }
    }

    #[test]
    fn strict_dominance_needs_one_strict_improvement() {
        assert!(dominates(&o(1.0, 1.0, 1.0), &o(2.0, 1.0, 1.0)));
        assert!(dominates(&o(1.0, 0.5, 1.0), &o(1.0, 1.0, 1.0)));
        // identical vectors: neither dominates
        assert!(!dominates(&o(1.0, 1.0, 1.0), &o(1.0, 1.0, 1.0)));
        // trade-offs: neither dominates
        assert!(!dominates(&o(1.0, 2.0, 1.0), &o(2.0, 1.0, 1.0)));
        assert!(!dominates(&o(2.0, 1.0, 1.0), &o(1.0, 2.0, 1.0)));
        // NaN never dominates and is never dominated
        assert!(!dominates(&o(f64::NAN, 1.0, 1.0), &o(1.0, 1.0, 1.0)));
        assert!(!dominates(&o(1.0, 1.0, 1.0), &o(f64::NAN, 1.0, 1.0)));
    }

    #[test]
    fn frontier_keeps_exactly_the_non_dominated() {
        let objs = [
            o(1.0, 4.0, 1.0), // frontier (best runtime)
            o(2.0, 2.0, 1.0), // frontier (trade-off)
            o(4.0, 1.0, 1.0), // frontier (best energy)
            o(3.0, 3.0, 1.0), // dominated by [1]
            o(2.0, 2.0, 2.0), // dominated by [1] (same r/e, worse area)
        ];
        let groups = ["k"; 5];
        assert_eq!(frontier_indices(&objs, &groups), vec![0, 1, 2]);
    }

    #[test]
    fn ties_survive_together() {
        let objs = [o(1.0, 1.0, 1.0), o(1.0, 1.0, 1.0), o(2.0, 2.0, 2.0)];
        assert_eq!(frontier_indices(&objs, &["k"; 3]), vec![0, 1]);
    }

    #[test]
    fn dominance_is_scoped_to_the_group() {
        // a cheap kernel's point must not evict an expensive kernel's
        let objs = [o(1.0, 1.0, 1.0), o(5.0, 5.0, 1.0)];
        assert_eq!(frontier_indices(&objs, &["spmm", "spttm"]), vec![0, 1]);
        assert_eq!(frontier_indices(&objs, &["k", "k"]), vec![0]);
    }

    #[test]
    fn every_excluded_point_is_dominated_by_a_frontier_member() {
        // the invariant the integration tests pin end to end, checked
        // here on a synthetic cloud
        let objs: Vec<Objectives> = (0..40)
            .map(|i| {
                let x = (i % 7) as f64;
                let y = (i % 5) as f64;
                o(1.0 + x, 6.0 - y, 1.0 + ((i % 3) as f64))
            })
            .collect();
        let groups = vec!["k"; objs.len()];
        let front = frontier_indices(&objs, &groups);
        for i in 0..objs.len() {
            if front.contains(&i) {
                assert!(!objs.iter().enumerate().any(|(j, oj)| j != i && dominates(oj, &objs[i])));
            } else {
                assert!(
                    front.iter().any(|&f| dominates(&objs[f], &objs[i])),
                    "excluded point {i} not dominated by any frontier member"
                );
            }
        }
    }
}
