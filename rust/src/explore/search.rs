//! The four-phase Pareto-frontier search.
//!
//! 1. **Screen** — every enumerated candidate gets an analytic-engine
//!    objective vector. By default the screen is **profiled**: cold
//!    candidates are grouped by their functional-geometry key
//!    ([`crate::explore::key::functional_key`]), each kernel's distinct
//!    geometries are answered by **one** reuse-distance stream walk
//!    ([`crate::sim::profile::profile_geometries`], memoized on the
//!    [`EvalCache`]), and every candidate is then *priced* from its
//!    geometry's profile — O(streams) walks for an O(grid) screen,
//!    bit-identical to evaluating each candidate directly (pinned by
//!    the tests below; [`ExploreSpec::profile`] = `false` restores the
//!    direct per-candidate walk, fanned across OS threads with the
//!    slot-ordered [`crate::sim::par`] map under the one thread-budget
//!    rule ([`crate::sim::SimBudget`])).
//! 2. **Extract** — the Pareto frontier over (runtime, energy, area),
//!    per kernel ([`crate::explore::pareto`]). Frontier **membership is
//!    decided by the screen** and never silently revised.
//! 3. **Confirm** — the **entire screened grid** is re-evaluated on the
//!    event-driven contention engine under the spec's
//!    [`SampleSpec`]: the sampled replay keeps functional accounting
//!    exact and estimates stalls from a deterministic subset of chunks
//!    ([`crate::sim::event`]), so every candidate — not just the
//!    survivors — gets a contention-aware objective vector at a fraction
//!    of the exact replay cost.
//! 4. **Pin** — frontier members *only* are re-run with an **exact**
//!    (rate 1.0) event replay; those are the `event` numbers every
//!    report and export carries, so sampling never changes a published
//!    figure. At rate 1.0 phase 3 already computed them and phase 4 is
//!    pure warm-cache reuse.
//!
//! Disagreements are surfaced, never hidden: if the exact event numbers
//! re-rank the members under the chosen objective or dominate a member
//! within the frontier, or the *sampled* ranking disagrees with the
//! exact one, that shows up as an [`ExploreDelta`] (mirroring
//! [`crate::coordinator::driver::cross_validate`]'s `EngineDelta`),
//! with every member still reported.
//!
//! Everything is deterministic: enumeration order is fixed, evaluation
//! results are slot-ordered, chunk admission is a pure hash of
//! (seed, mode, PE, chunk index), and ranks tie-break on the candidate
//! index — the frontier is bit-identical at any thread count (pinned by
//! `rust/tests/explore.rs` and `rust/tests/sampled_replay.rs`).

use crate::accel::config::AcceleratorConfig;
use crate::explore::eval::{candidate_key, EvalCache, Evaluator};
use crate::explore::objective::{ObjectiveKind, Objectives};
use crate::explore::pareto;
use crate::explore::space::{Candidate, DesignSpace};
use crate::kernel::{KernelKind, DEFAULT_CHUNK_NNZ};
use crate::obs::Span;
use crate::sim::par::{effective_threads, parallel_map};
use crate::sim::profile::profile_geometries;
use crate::sim::{EngineKind, SampleSpec, SimBudget};
use crate::tensor::csf::ModeView;
use crate::tensor::gen::TensorSpec;
use crate::util::table::{fmt_sig, Align, Table};

/// Default chunk-sampling rate for the phase-3 grid-wide event
/// confirmation: 1-in-4 timed chunks per (mode, PE) stream keeps the
/// stall estimate within its reported confidence band while cutting
/// replay timing work roughly 4×. `photon-mttkrp explore --sample-rate`
/// overrides it; 1.0 restores the exact replay everywhere.
pub const DEFAULT_EXPLORE_SAMPLE_RATE: f64 = 0.25;

/// One search request: the space, the workload fingerprint and the
/// execution knobs.
#[derive(Clone, Debug)]
pub struct ExploreSpec {
    /// What to enumerate ([`DesignSpace`]).
    pub space: DesignSpace,
    /// Workload fingerprint every candidate is evaluated against.
    pub tensor: TensorSpec,
    /// Workload scale factor — applied to the **tensor only**; the
    /// design space evaluates real (unscaled) configurations, since its
    /// capacity axes must mean something absolute.
    pub scale: f64,
    /// Generator seed.
    pub seed: u64,
    /// Ranking objective (frontier extraction is always over the full
    /// vector; this orders the output and drives the rank-flip check).
    pub objective: ObjectiveKind,
    /// Apply the §IV-A memory mapping before simulating (the driver-path
    /// behaviour).
    pub remap: bool,
    /// OS-thread budget; 0 = all available cores.
    pub threads: usize,
    /// Access-stream chunk granularity (bit-transparent).
    pub chunk_nnz: usize,
    /// Chunk-sampling spec for the phase-3 grid-wide event confirmation
    /// (defaults to [`DEFAULT_EXPLORE_SAMPLE_RATE`]). The phase-4
    /// frontier numbers are always exact regardless of this setting.
    pub sample: SampleSpec,
    /// Run the phase-1 screen through the reuse-distance profiler
    /// (default `true`): one functional stream walk per kernel answers
    /// every cold geometry, and candidates are priced from the memoized
    /// profiles — bit-identical to the direct screen. `false`
    /// (`--no-profile` on the CLI) evaluates every candidate with its
    /// own stream walk.
    pub profile: bool,
}

impl ExploreSpec {
    /// A search over `space` × `tensor` with driver-path defaults:
    /// full-scale tensor, seed 42, EDP ranking, all cores.
    pub fn new(space: DesignSpace, tensor: TensorSpec) -> Self {
        ExploreSpec {
            space,
            tensor,
            scale: 1.0,
            seed: 42,
            objective: ObjectiveKind::Edp,
            remap: true,
            threads: 0,
            chunk_nnz: DEFAULT_CHUNK_NNZ,
            sample: SampleSpec { rate: DEFAULT_EXPLORE_SAMPLE_RATE, seed: 0 },
            profile: true,
        }
    }

    fn validate(&self) -> Result<(), String> {
        if !(self.scale > 0.0 && self.scale <= 1.0) {
            return Err(format!("explore scale {} outside (0, 1]", self.scale));
        }
        if self.chunk_nnz == 0 {
            return Err("chunk_nnz must be positive".into());
        }
        self.sample.validate()?;
        Ok(())
    }
}

/// One confirmed frontier member: both engines' objective vectors plus
/// its rank under each.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    pub candidate: Candidate,
    /// Screening-phase (analytic-engine) objectives.
    pub analytic: Objectives,
    /// Pinning-phase (exact event-engine) objectives; `runtime_s` and
    /// `energy_j` are ≥ their analytic twins by construction, `area_mm2`
    /// is engine-independent.
    pub event: Objectives,
    /// Confirmation-phase (sampled event-engine) objectives, from the
    /// grid-wide phase-3 pass. Bit-identical to [`event`](Self::event)
    /// when the spec's sample rate is 1.0.
    pub event_sampled: Objectives,
    /// 0-based rank by the spec's objective under analytic numbers
    /// (frontier output order).
    pub analytic_rank: usize,
    /// 0-based rank by the same objective under exact event numbers.
    pub event_rank: usize,
    /// 0-based rank by the same objective under sampled event numbers.
    pub sampled_rank: usize,
    /// Under event numbers, is this member dominated by another frontier
    /// member (same kernel)? Membership was decided by the screen; this
    /// flags the disagreement instead of dropping the point.
    pub event_dominated: bool,
}

impl FrontierPoint {
    /// Did the event confirmation disagree with the analytic screen
    /// about this member (re-ranked, or dominated within the frontier)?
    pub fn flipped(&self) -> bool {
        self.analytic_rank != self.event_rank || self.event_dominated
    }

    /// Did the sampled confirmation rank this member differently than
    /// the exact event replay — i.e. would trusting the sampled numbers
    /// alone have mis-ordered it?
    pub fn sample_flipped(&self) -> bool {
        self.sampled_rank != self.event_rank
    }
}

/// One analytic-vs-event disagreement on a frontier member — the explore
/// counterpart of [`crate::coordinator::driver::EngineDelta`].
#[derive(Clone, Debug)]
pub struct ExploreDelta {
    /// The member's knob settings ([`Candidate::label`]).
    pub label: String,
    pub tech: String,
    pub kernel: String,
    /// The objective the ranks are under.
    pub objective: ObjectiveKind,
    pub analytic_value: f64,
    pub event_value: f64,
    /// The same objective under the phase-3 sampled event numbers.
    pub sampled_value: f64,
    pub analytic_rank: usize,
    pub event_rank: usize,
    /// Rank under the sampled event numbers.
    pub sampled_rank: usize,
    pub event_dominated: bool,
}

impl ExploreDelta {
    /// `event / analytic` on the chosen objective (≥ 1.0 for the
    /// time/energy-derived objectives).
    pub fn ratio(&self) -> f64 {
        self.event_value / self.analytic_value
    }

    /// One-line human rendering for the CLI / example output. The
    /// headline names what actually disagreed: a re-ranking is a
    /// "rank flip"; identical ranks with within-frontier domination is
    /// "event dominance"; a member only the *sampled* replay mis-ordered
    /// is a "sampled rank flip".
    pub fn describe(&self) -> String {
        let kind = if self.analytic_rank != self.event_rank {
            "rank flip"
        } else if self.event_dominated {
            "event dominance"
        } else {
            "sampled rank flip"
        };
        let dom = if self.event_dominated { ", event-dominated within frontier" } else { "" };
        let samp = if self.sampled_rank != self.event_rank {
            format!(", sampled rank #{}", self.sampled_rank)
        } else {
            String::new()
        };
        format!(
            "{kind} [{} {} {}]: {} {:.4e} -> {:.4e} under event engine \
             (rank #{} -> #{}{dom}{samp})",
            self.label,
            self.tech,
            self.kernel,
            self.objective,
            self.analytic_value,
            self.event_value,
            self.analytic_rank,
            self.event_rank,
        )
    }
}

/// Wall-clock time spent in each of the four search phases, in seconds
/// (host measurement — the one deliberately non-deterministic part of an
/// [`ExploreResult`]; everything it sits next to is bit-stable). Each
/// field is the elapsed time of one timed [`crate::obs::Span`]
/// (`explore.screen` / `explore.pareto` / `explore.sampled` /
/// `explore.exact`), so `--trace-out` shows the same four intervals.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    /// Phase 1: analytic screen (profiled or direct).
    pub screen_s: f64,
    /// Phase 2: Pareto frontier extraction.
    pub pareto_s: f64,
    /// Phase 3: sampled event confirmation of the grid.
    pub sampled_s: f64,
    /// Phase 4: exact event pin of the frontier members.
    pub exact_s: f64,
}

impl PhaseTimings {
    pub fn total_s(&self) -> f64 {
        self.screen_s + self.pareto_s + self.sampled_s + self.exact_s
    }
}

/// The full search result.
#[derive(Clone, Debug)]
pub struct ExploreResult {
    /// Name of the generated (scaled) workload tensor.
    pub tensor: String,
    /// Nonzeros of the generated workload.
    pub nnz: u64,
    /// The ranking objective the frontier is ordered by.
    pub objective: ObjectiveKind,
    /// Every constraint-passing candidate, in enumeration order.
    pub candidates: Vec<Candidate>,
    /// Screening-phase objectives, parallel to
    /// [`candidates`](Self::candidates).
    pub analytic: Vec<Objectives>,
    /// Phase-3 sampled event objectives for **every** screened
    /// candidate, parallel to [`candidates`](Self::candidates) — the
    /// contention-aware view of the whole grid, not just the frontier.
    pub event_sampled: Vec<Objectives>,
    /// The sampling spec the grid-wide confirmation ran under.
    pub sample: SampleSpec,
    /// Points pruned by [`crate::accel::config::AcceleratorConfig::validate`].
    pub n_invalid: usize,
    /// Points pruned by the area-budget / reticle predicates.
    pub n_filtered: usize,
    /// The confirmed frontier, sorted by `analytic_rank`.
    pub frontier: Vec<FrontierPoint>,
    /// One entry per frontier member the event confirmation disagreed
    /// about ([`FrontierPoint::flipped`]); empty = the engines agree on
    /// both order and within-frontier dominance.
    pub deltas: Vec<ExploreDelta>,
    /// Evaluation-cache traffic attributable to this search.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Records the persistent store replayed at open (0 when the cache
    /// is in-memory only; counts the whole store, not just this search).
    pub cache_loaded: u64,
    /// Records this search persisted to the store (0 when in-memory).
    pub cache_appended: u64,
    /// Full-workload functional stream walks this search performed to
    /// fill the profile memo (unit: one walk = every mode of one kernel
    /// traversed once — the same work as one direct candidate
    /// evaluation; see [`EvalCache::add_walks`]). 0 when profiling is
    /// off or every geometry was already memoized; the profiled screen's
    /// whole point is `candidates.len() / functional_walks ≫ 1`.
    pub functional_walks: u64,
    /// Per-phase wall time of this search.
    pub timing: PhaseTimings,
}

impl ExploreResult {
    /// The frontier member for a technology name at the paper-default
    /// configuration, if the search kept one — the acceptance hook
    /// ("is the paper's design point on the frontier?").
    pub fn paper_default_point(&self, tech: &str) -> Option<&FrontierPoint> {
        self.frontier
            .iter()
            .find(|p| p.candidate.tech.name == tech && p.candidate.is_paper_default())
    }
}

/// Run the four-phase search with a private, single-use evaluation cache.
pub fn run_explore(spec: &ExploreSpec) -> Result<ExploreResult, String> {
    run_explore_with_cache(spec, &EvalCache::new())
}

/// [`run_explore`] against a caller-owned [`EvalCache`], so overlapping
/// candidates across successive searches (refined axes, added
/// technologies, a different ranking objective on the same grid) are
/// computed once.
pub fn run_explore_with_cache(
    spec: &ExploreSpec,
    cache: &EvalCache,
) -> Result<ExploreResult, String> {
    spec.validate()?;
    let enumerated = spec.space.enumerate()?;
    if enumerated.candidates.is_empty() {
        return Err(format!(
            "design space enumerates zero candidates ({} invalid, {} filtered by \
             area constraints) — relax the axes or the budget",
            enumerated.n_invalid, enumerated.n_filtered
        ));
    }
    let candidates = enumerated.candidates;
    let (hits0, misses0, appended0) = (cache.hits(), cache.misses(), cache.appended());
    let walks0 = cache.functional_walks();
    let mut timing = PhaseTimings::default();

    // one workload, shared by every candidate × engine evaluation
    let tensor = spec.tensor.clone().scaled(spec.scale).generate(spec.seed);
    let mapped = if spec.remap {
        crate::coordinator::driver::apply_memory_mapping(&tensor)
    } else {
        tensor.clone()
    };
    let views: Vec<(usize, ModeView)> =
        (0..mapped.n_modes()).map(|m| (m, ModeView::build(&mapped, m))).collect();

    // thread-budget rule (see `SimBudget`): the candidate fan-out claims
    // min(threads, candidates) workers; each simulation gets the
    // left-over threads for its per-PE inner loop
    let threads = effective_threads(spec.threads);
    let budget_for = |jobs: usize, sample: SampleSpec| {
        let workers = threads.min(jobs.max(1));
        SimBudget { threads: (threads / workers).max(1), chunk_nnz: spec.chunk_nnz, sample }
    };
    let evaluator = |budget: SimBudget| Evaluator {
        tensor: &mapped,
        views: &views,
        workload_tag: Evaluator::tag(&mapped, spec.seed, spec.remap),
        budget,
    };

    // Phase 1: analytic screen of the full grid (sample-independent).
    // Profiled by default: one functional stream walk per kernel answers
    // every cold geometry, candidates are priced from the memo.
    // Each phase is one timed obs span; its elapsed seconds feed the
    // same PhaseTimings field the hand-rolled Instant used to fill.
    let sp = Span::timed("explore.screen", "explore");
    let screen_eval = evaluator(budget_for(candidates.len(), SampleSpec::exact()));
    let analytic: Vec<Objectives> = if spec.profile {
        profiled_screen(&screen_eval, &candidates, cache, threads, spec.chunk_nnz)
    } else {
        parallel_map(&candidates, threads, |cand| {
            screen_eval.evaluate(cand, EngineKind::Analytic, cache)
        })
    };
    timing.screen_s = sp.finish();

    // Phase 2: frontier extraction (dominance scoped to the kernel).
    let sp = Span::timed("explore.pareto", "explore");
    let groups: Vec<&str> = candidates.iter().map(|c| c.kernel.name()).collect();
    let front = pareto::frontier_indices(&analytic, &groups);
    timing.pareto_s = sp.finish();

    // Phase 3: sampled event confirmation of the ENTIRE screened grid.
    let sp = Span::timed("explore.sampled", "explore");
    let sampled_eval = evaluator(budget_for(candidates.len(), spec.sample));
    let event_sampled: Vec<Objectives> = parallel_map(&candidates, threads, |cand| {
        sampled_eval.evaluate(cand, EngineKind::Event, cache)
    });
    timing.sampled_s = sp.finish();

    // Phase 4: exact event pass over the frontier members only — the
    // published numbers. At rate 1.0 phase 3 already computed these
    // under the same cache key, so this is pure warm-cache reuse.
    let sp = Span::timed("explore.exact", "explore");
    let confirm_eval = evaluator(budget_for(front.len(), SampleSpec::exact()));
    let event: Vec<Objectives> = parallel_map(&front, threads, |&i| {
        confirm_eval.evaluate(&candidates[i], EngineKind::Event, cache)
    });
    timing.exact_s = sp.finish();

    // Ranks by the chosen objective under each engine's numbers;
    // ties break on the (deterministic) candidate index.
    let rank_by = |values: &[f64]| -> Vec<usize> {
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&x, &y| values[x].total_cmp(&values[y]).then(front[x].cmp(&front[y])));
        let mut rank = vec![0usize; front.len()];
        for (r, &slot) in order.iter().enumerate() {
            rank[slot] = r;
        }
        rank
    };
    let analytic_values: Vec<f64> =
        front.iter().map(|&i| analytic[i].value(spec.objective)).collect();
    let event_values: Vec<f64> = event.iter().map(|o| o.value(spec.objective)).collect();
    let sampled_values: Vec<f64> =
        front.iter().map(|&i| event_sampled[i].value(spec.objective)).collect();
    let analytic_rank = rank_by(&analytic_values);
    let event_rank = rank_by(&event_values);
    let sampled_rank = rank_by(&sampled_values);

    let mut frontier: Vec<FrontierPoint> = front
        .iter()
        .enumerate()
        .map(|(slot, &i)| {
            let event_dominated = front.iter().enumerate().any(|(other, &j)| {
                other != slot
                    && candidates[j].kernel == candidates[i].kernel
                    && pareto::dominates(&event[other], &event[slot])
            });
            FrontierPoint {
                candidate: candidates[i].clone(),
                analytic: analytic[i],
                event: event[slot],
                event_sampled: event_sampled[i],
                analytic_rank: analytic_rank[slot],
                event_rank: event_rank[slot],
                sampled_rank: sampled_rank[slot],
                event_dominated,
            }
        })
        .collect();
    frontier.sort_by_key(|p| p.analytic_rank);

    let deltas: Vec<ExploreDelta> = frontier
        .iter()
        .filter(|p| p.flipped() || p.sample_flipped())
        .map(|p| ExploreDelta {
            label: p.candidate.label(),
            tech: p.candidate.tech.name.clone(),
            kernel: p.candidate.kernel.name().to_string(),
            objective: spec.objective,
            analytic_value: p.analytic.value(spec.objective),
            event_value: p.event.value(spec.objective),
            sampled_value: p.event_sampled.value(spec.objective),
            analytic_rank: p.analytic_rank,
            event_rank: p.event_rank,
            sampled_rank: p.sampled_rank,
            event_dominated: p.event_dominated,
        })
        .collect();

    Ok(ExploreResult {
        tensor: tensor.name.clone(),
        nnz: tensor.nnz() as u64,
        objective: spec.objective,
        candidates,
        analytic,
        event_sampled,
        sample: spec.sample,
        n_invalid: enumerated.n_invalid,
        n_filtered: enumerated.n_filtered,
        frontier,
        deltas,
        cache_hits: cache.hits() - hits0,
        cache_misses: cache.misses() - misses0,
        cache_loaded: cache.loaded(),
        cache_appended: cache.appended() - appended0,
        functional_walks: cache.functional_walks() - walks0,
        timing,
    })
}

/// The profiled phase-1 screen.
///
/// 1. **Plan** — find the candidates that are cold on *both* tiers (no
///    memoized objectives, no memoized profile) and collect, per
///    kernel, one representative config per distinct functional key.
/// 2. **Walk** — one [`profile_geometries`] call per kernel with cold
///    geometries: a single full-workload stream walk (`add_walks(1)`)
///    answers all of them at once; the profiles join the cache's memo.
/// 3. **Price** — every candidate is priced from its geometry's profile
///    (pure arithmetic, fanned across threads, slot-ordered), then
///    committed through [`EvalCache::get_or_compute`] in candidate
///    order — so hit/miss counters, store appends and every returned
///    bit are identical to the direct screen's.
fn profiled_screen(
    eval: &Evaluator<'_>,
    candidates: &[Candidate],
    cache: &EvalCache,
    threads: usize,
    chunk_nnz: usize,
) -> Vec<Objectives> {
    let keys: Vec<String> = candidates
        .iter()
        .map(|c| candidate_key(c, EngineKind::Analytic, &eval.workload_tag, eval.budget.sample))
        .collect();
    let fkeys: Vec<String> = candidates.iter().map(|c| eval.functional_key_for(c)).collect();

    // plan: per kernel, the distinct cold geometries (first candidate
    // with each functional key is its representative config)
    let mut missing: Vec<(KernelKind, Vec<(usize, &str)>)> = Vec::new();
    for (i, cand) in candidates.iter().enumerate() {
        if cache.peek(&keys[i]).is_some() || cache.functional_profile(&fkeys[i]).is_some() {
            continue;
        }
        let entry = match missing.iter_mut().find(|(k, _)| *k == cand.kernel) {
            Some(e) => e,
            None => {
                missing.push((cand.kernel, Vec::new()));
                missing.last_mut().unwrap()
            }
        };
        if !entry.1.iter().any(|&(_, fk)| fk == fkeys[i]) {
            entry.1.push((i, &fkeys[i]));
        }
    }

    // walk: one traversal per kernel covers all its cold geometries
    for (kernel, geoms) in &missing {
        let cfgs: Vec<&AcceleratorConfig> =
            geoms.iter().map(|&(i, _)| &candidates[i].cfg).collect();
        let profiles =
            profile_geometries(kernel.kernel(), eval.tensor, eval.views, &cfgs, chunk_nnz);
        cache.add_walks(1);
        cache.store_profiles(
            geoms.iter().zip(profiles).map(|(&(_, fk), p)| (fk.to_string(), p)),
        );
    }

    // price: arithmetic only — every needed profile is memoized now
    let idx: Vec<usize> = (0..candidates.len()).collect();
    let priced: Vec<Objectives> = parallel_map(&idx, threads, |&i| match cache.peek(&keys[i]) {
        Some(v) => v,
        None => match cache.functional_profile(&fkeys[i]) {
            Some(p) => eval.price_candidate(&candidates[i], &p),
            // unreachable in a single search; defensively fall back to a
            // direct (uncached) evaluation rather than panic
            None => eval.compute(&candidates[i], EngineKind::Analytic),
        },
    });

    // commit in candidate order: counters and appends match the direct
    // screen exactly (warm keys hit, cold keys miss with the same value)
    idx.iter().map(|&i| cache.get_or_compute(&keys[i], || priced[i])).collect()
}

/// Render the frontier as a table (`top` = 0 keeps every member): one
/// row per member in analytic-rank order, with both engines' view of the
/// ranking objective and the flip marker.
pub fn frontier_table(result: &ExploreResult, top: usize) -> Table {
    let shown = if top == 0 {
        result.frontier.len()
    } else {
        top.min(result.frontier.len())
    };
    let mut t = Table::new(
        &format!(
            "Pareto frontier by {} ({}, {} candidates screened, {} on frontier{}{})",
            result.objective,
            result.tensor,
            result.candidates.len(),
            result.frontier.len(),
            if result.sample.is_exact() {
                String::new()
            } else {
                format!(", grid event-confirmed @ rate {}", result.sample.rate)
            },
            if shown < result.frontier.len() {
                format!(", top {shown} shown")
            } else {
                String::new()
            }
        ),
        &[
            "#",
            "configuration",
            "tech",
            "kernel",
            "runtime",
            "energy",
            "EDP",
            "area mm^2",
            "event rank",
            "sampled rank",
        ],
    )
    .align(1, Align::Left)
    .align(2, Align::Left)
    .align(3, Align::Left);
    for p in result.frontier.iter().take(shown) {
        let event_cell = if p.event_dominated {
            format!("#{} (dominated)", p.event_rank)
        } else if p.event_rank != p.analytic_rank {
            format!("#{} (flip)", p.event_rank)
        } else {
            format!("#{}", p.event_rank)
        };
        let sampled_cell = if p.sample_flipped() {
            format!("#{} (flip)", p.sampled_rank)
        } else {
            format!("#{}", p.sampled_rank)
        };
        t.row(vec![
            format!("{}", p.analytic_rank),
            p.candidate.label(),
            p.candidate.tech.name.clone(),
            p.candidate.kernel.name().to_string(),
            format!("{:.3e} s", p.analytic.runtime_s),
            format!("{:.3e} J", p.analytic.energy_j),
            format!("{:.3e}", p.analytic.edp()),
            fmt_sig(p.analytic.area_mm2, 4),
            event_cell,
            sampled_cell,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelKind;
    use crate::mem::registry::tech;
    use crate::tensor::gen::TensorSpec;

    fn tiny_spec() -> ExploreSpec {
        let mut space = DesignSpace::paper_grid(
            vec![tech("e-sram"), tech("o-sram")],
            vec![KernelKind::Spmttkrp],
        );
        space.axes = vec![crate::explore::space::Axis::parse("n_pes=2,4").unwrap()];
        let mut spec =
            ExploreSpec::new(space, TensorSpec::custom("tiny", vec![48, 48, 48], 4_000, 1.0));
        spec.threads = 2;
        spec
    }

    #[test]
    fn search_runs_end_to_end_with_consistent_shape() {
        let r = run_explore(&tiny_spec()).unwrap();
        assert_eq!(r.candidates.len(), 4);
        assert_eq!(r.analytic.len(), 4);
        // the ENTIRE screened grid is event-confirmed, not just the frontier
        assert_eq!(r.event_sampled.len(), 4);
        assert!(!r.sample.is_exact(), "explore defaults to a sampled confirmation");
        assert!(!r.frontier.is_empty());
        assert_eq!(r.objective, ObjectiveKind::Edp);
        // every grid point's sampled event view can only add time/energy
        for (a, s) in r.analytic.iter().zip(&r.event_sampled) {
            assert!(s.runtime_s >= a.runtime_s);
            assert!(s.energy_j >= a.energy_j);
            assert_eq!(s.area_mm2, a.area_mm2);
        }
        // frontier is sorted by analytic rank, ranks are a permutation
        for (i, p) in r.frontier.iter().enumerate() {
            assert_eq!(p.analytic_rank, i);
            assert!(p.event_rank < r.frontier.len());
            assert!(p.sampled_rank < r.frontier.len());
            // event can only add time/energy; area is engine-independent
            assert!(p.event.runtime_s >= p.analytic.runtime_s);
            assert!(p.event.energy_j >= p.analytic.energy_j);
            assert_eq!(p.event.area_mm2, p.analytic.area_mm2);
        }
        // deltas are exactly the flipped members (either flavour)
        assert_eq!(
            r.deltas.len(),
            r.frontier.iter().filter(|p| p.flipped() || p.sample_flipped()).count()
        );
        // cache traffic: screen misses + grid-wide sampled event misses
        // + exact frontier event misses, no hits (sampled keys differ)
        assert_eq!(r.cache_misses, 4 + 4 + r.frontier.len() as u64);
        assert_eq!(r.cache_hits, 0);
    }

    #[test]
    fn exact_sampling_reuses_the_grid_confirmation_for_the_frontier() {
        let mut spec = tiny_spec();
        spec.sample = SampleSpec::exact();
        let r = run_explore(&spec).unwrap();
        // rate 1.0 keys exactly, so the phase-4 frontier pass is pure
        // warm-cache reuse of the grid-wide phase 3
        assert_eq!(r.cache_misses, 4 + 4);
        assert_eq!(r.cache_hits, r.frontier.len() as u64);
        for p in &r.frontier {
            assert_eq!(p.event.runtime_s.to_bits(), p.event_sampled.runtime_s.to_bits());
            assert_eq!(p.event.energy_j.to_bits(), p.event_sampled.energy_j.to_bits());
            assert_eq!(p.sampled_rank, p.event_rank);
            assert!(!p.sample_flipped());
        }
    }

    #[test]
    fn sampled_frontier_matches_the_exact_frontier() {
        // membership is decided by the (sample-independent) screen and
        // the reported event numbers come from the exact phase-4 pass,
        // so the frontier must be identical at any rate — even with the
        // chunk size forced small enough that sampling really skips work
        let exact = {
            let mut s = tiny_spec();
            s.sample = SampleSpec::exact();
            s.chunk_nnz = 193;
            run_explore(&s).unwrap()
        };
        let sampled = {
            let mut s = tiny_spec();
            s.sample = SampleSpec::new(0.25, 0).unwrap();
            s.chunk_nnz = 193;
            run_explore(&s).unwrap()
        };
        assert_eq!(exact.frontier.len(), sampled.frontier.len());
        for (x, y) in exact.frontier.iter().zip(&sampled.frontier) {
            assert_eq!(x.candidate.label(), y.candidate.label());
            assert_eq!(x.candidate.tech.name, y.candidate.tech.name);
            assert_eq!(x.analytic_rank, y.analytic_rank);
            assert_eq!(x.event_rank, y.event_rank);
            assert_eq!(x.analytic.runtime_s.to_bits(), y.analytic.runtime_s.to_bits());
            assert_eq!(x.event.runtime_s.to_bits(), y.event.runtime_s.to_bits());
            assert_eq!(x.event.energy_j.to_bits(), y.event.energy_j.to_bits());
        }
    }

    #[test]
    fn profiled_screen_is_bit_identical_to_the_direct_screen() {
        let profiled = run_explore(&tiny_spec()).unwrap();
        let direct = {
            let mut s = tiny_spec();
            s.profile = false;
            run_explore(&s).unwrap()
        };
        // 4 candidates (2 n_pes × 2 techs), 2 distinct geometries, one
        // kernel → exactly one functional stream walk for the whole grid
        assert_eq!(profiled.functional_walks, 1);
        assert_eq!(direct.functional_walks, 0);
        assert!(profiled.candidates.len() as u64 >= 4 * profiled.functional_walks);
        // the screen and everything downstream of it are bit-identical
        assert_eq!(profiled.analytic.len(), direct.analytic.len());
        for (a, b) in profiled.analytic.iter().zip(&direct.analytic) {
            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
        }
        assert_eq!(profiled.frontier.len(), direct.frontier.len());
        for (x, y) in profiled.frontier.iter().zip(&direct.frontier) {
            assert_eq!(x.candidate.label(), y.candidate.label());
            assert_eq!(x.candidate.tech.name, y.candidate.tech.name);
            assert_eq!(x.analytic_rank, y.analytic_rank);
            assert_eq!(x.event_rank, y.event_rank);
            assert_eq!(x.analytic.runtime_s.to_bits(), y.analytic.runtime_s.to_bits());
            assert_eq!(x.analytic.energy_j.to_bits(), y.analytic.energy_j.to_bits());
            assert_eq!(x.event.runtime_s.to_bits(), y.event.runtime_s.to_bits());
            assert_eq!(x.event.energy_j.to_bits(), y.event.energy_j.to_bits());
        }
        // same cache traffic as the direct screen, by construction
        assert_eq!(profiled.cache_misses, direct.cache_misses);
        assert_eq!(profiled.cache_hits, direct.cache_hits);
    }

    #[test]
    fn warm_memo_needs_no_walks_and_timings_are_populated() {
        let spec = tiny_spec();
        let cache = EvalCache::new();
        let a = run_explore_with_cache(&spec, &cache).unwrap();
        assert_eq!(a.functional_walks, 1);
        for phase in [a.timing.screen_s, a.timing.pareto_s, a.timing.sampled_s, a.timing.exact_s]
        {
            assert!(phase >= 0.0 && phase.is_finite());
        }
        assert!(a.timing.total_s() >= a.timing.screen_s);
        // second search over the same grid: every objective key is warm,
        // so the screen neither walks nor prices anything
        let b = run_explore_with_cache(&spec, &cache).unwrap();
        assert_eq!(b.functional_walks, 0);
        assert_eq!(b.cache_misses, 0);
    }

    #[test]
    fn frontier_table_lists_the_members_and_honours_top() {
        let r = run_explore(&tiny_spec()).unwrap();
        let full = frontier_table(&r, 0);
        assert_eq!(full.n_rows(), r.frontier.len());
        let s = full.render_ascii();
        assert!(s.contains("Pareto frontier by edp"), "{s}");
        assert!(s.contains("o-sram") || s.contains("e-sram"), "{s}");
        let one = frontier_table(&r, 1);
        assert_eq!(one.n_rows(), 1);
        assert!(one.render_ascii().contains("top 1 shown"));
    }

    #[test]
    fn warm_cache_reuses_every_evaluation() {
        let spec = tiny_spec();
        let cache = EvalCache::new();
        let a = run_explore_with_cache(&spec, &cache).unwrap();
        let b = run_explore_with_cache(&spec, &cache).unwrap();
        assert_eq!(b.cache_misses, 0);
        assert_eq!(b.cache_hits, a.cache_misses);
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.analytic.runtime_s.to_bits(), y.analytic.runtime_s.to_bits());
            assert_eq!(x.event.energy_j.to_bits(), y.event.energy_j.to_bits());
            assert_eq!(x.candidate.label(), y.candidate.label());
        }
    }

    #[test]
    fn invalid_specs_are_rejected() {
        let mut s = tiny_spec();
        s.scale = 2.0;
        assert!(run_explore(&s).is_err());
        let mut s = tiny_spec();
        s.chunk_nnz = 0;
        assert!(run_explore(&s).is_err());
        // a space pruned to nothing errors with the counts, not an empty
        // success
        let mut s = tiny_spec();
        s.space.budget_mm2 = Some(1e-3);
        let e = run_explore(&s).unwrap_err();
        assert!(e.contains("zero candidates"), "{e}");
        // an out-of-range sample rate is rejected with the range
        let mut s = tiny_spec();
        s.sample = SampleSpec { rate: 1.5, seed: 0 };
        let e = run_explore(&s).unwrap_err();
        assert!(e.contains("(0, 1]"), "{e}");
    }

    #[test]
    fn delta_describes_itself() {
        let d = ExploreDelta {
            label: "n_pes=4".into(),
            tech: "o-sram".into(),
            kernel: "spmttkrp".into(),
            objective: ObjectiveKind::Edp,
            analytic_value: 1.0,
            event_value: 1.5,
            sampled_value: 1.5,
            analytic_rank: 0,
            event_rank: 1,
            sampled_rank: 1,
            event_dominated: false,
        };
        assert!((d.ratio() - 1.5).abs() < 1e-12);
        let s = d.describe();
        assert!(s.starts_with("rank flip"), "{s}");
        assert!(s.contains("n_pes=4") && s.contains("o-sram") && s.contains("edp"), "{s}");
        assert!(s.contains("#0") && s.contains("#1"), "{s}");
        // equal ranks + within-frontier domination is not a flip and
        // must not claim one
        let d2 = ExploreDelta {
            analytic_rank: 2,
            event_rank: 2,
            sampled_rank: 2,
            event_dominated: true,
            ..d.clone()
        };
        let s2 = d2.describe();
        assert!(s2.starts_with("event dominance"), "{s2}");
        assert!(s2.contains("event-dominated within frontier"), "{s2}");
        // a disagreement only the sampled ranking produced names itself
        let d3 = ExploreDelta { analytic_rank: 2, event_rank: 2, sampled_rank: 3, ..d };
        let s3 = d3.describe();
        assert!(s3.starts_with("sampled rank flip"), "{s3}");
        assert!(s3.contains("sampled rank #3"), "{s3}");
    }
}
