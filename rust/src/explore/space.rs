//! The design-space axis grammar: which accelerator configurations,
//! technologies and kernels a search enumerates, and which constraint
//! predicates prune the grid before anything is simulated.
//!
//! An [`Axis`] names one [`AcceleratorConfig`] knob ([`Knob`]) and the
//! values it sweeps (`--axes n_pes=2,4,8` on the CLI). A [`DesignSpace`]
//! crosses every axis combination with the requested technologies and
//! kernels, then filters:
//!
//! 1. **structural validity** — [`AcceleratorConfig::validate`] (e.g.
//!    `rank=32` with 64 B lines is a contradiction, not a candidate);
//! 2. **area budget** — instantiated-design area
//!    ([`AreaModel::design`]) within `budget_mm2`, per technology;
//! 3. **wafer-scale exclusion** — optionally drop candidates larger than
//!    one reticle ([`crate::area::model::RETICLE_MM2`]), the §II
//!    single-die feasibility line.
//!
//! Enumeration order is deterministic (axis-major in listed order, then
//! technology, then kernel) and filtered counts are reported, never
//! silently swallowed.

use crate::accel::config::AcceleratorConfig;
use crate::area::model::{AreaModel, RETICLE_MM2};
use crate::kernel::KernelKind;
use crate::mem::hierarchy::MemLevelSpec;
use crate::mem::tech::MemTechnology;

/// An explorable [`AcceleratorConfig`] knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Knob {
    /// `n_pes` — PE (and DRAM channel) count.
    NPes,
    /// `cache_lines` — lines per cache (capacity).
    CacheLines,
    /// `cache_assoc` — cache associativity (ways).
    CacheAssoc,
    /// `esram_bank_factor` — electrical data-array bank cascade.
    BankFactor,
    /// `rank` — decomposition rank R.
    Rank,
    /// `sram_kib` — capacity (KiB) of a shared `sram` memory-hierarchy
    /// level between the PE caches and DRAM
    /// ([`AcceleratorConfig::levels`]); `0` removes the level, making
    /// the degenerate single-level model itself an axis value.
    SramKib,
    /// `local_kib` — capacity (KiB) of an inner `local` hierarchy level
    /// (nearest the PE caches); `0` removes it.
    LocalKib,
}

impl Knob {
    /// Every knob, in CLI listing order.
    pub const ALL: [Knob; 7] = [
        Knob::NPes,
        Knob::CacheLines,
        Knob::CacheAssoc,
        Knob::BankFactor,
        Knob::Rank,
        Knob::SramKib,
        Knob::LocalKib,
    ];

    /// The stable grammar name (`--axes <name>=v1,v2,...`).
    pub fn name(self) -> &'static str {
        match self {
            Knob::NPes => "n_pes",
            Knob::CacheLines => "cache_lines",
            Knob::CacheAssoc => "cache_assoc",
            Knob::BankFactor => "bank_factor",
            Knob::Rank => "rank",
            Knob::SramKib => "sram_kib",
            Knob::LocalKib => "local_kib",
        }
    }

    /// Parse a grammar spelling; the error lists every knob (the
    /// `--kernel` / `--tech` error style).
    pub fn parse(s: &str) -> Result<Self, String> {
        Self::ALL.into_iter().find(|k| k.name() == s).ok_or_else(|| {
            let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
            format!("unknown design-space knob `{s}` (expected one of: {})", names.join(", "))
        })
    }

    /// Set this knob to `value` on `cfg`. Structural sanity of the result
    /// is checked by [`AcceleratorConfig::validate`] during enumeration,
    /// not here — an axis may legitimately contain values that are only
    /// valid in combination with another axis.
    pub fn apply(self, cfg: &mut AcceleratorConfig, value: usize) {
        match self {
            Knob::NPes => cfg.n_pes = value,
            Knob::CacheLines => cfg.cache_lines = value,
            Knob::CacheAssoc => cfg.cache_assoc = value,
            Knob::BankFactor => cfg.esram_bank_factor = value,
            Knob::Rank => cfg.rank = value,
            // the hierarchy axes size (or remove, at 0) one named level
            // each; `sram` stays outermost, `local` innermost, so any
            // value combination yields a well-ordered stack
            Knob::SramKib => set_level(cfg, "sram", value, true),
            Knob::LocalKib => set_level(cfg, "local", value, false),
        }
    }

    /// The paper-default value of this knob (Table I; the hierarchy
    /// axes default to 0 — the paper prices no intermediate level).
    pub fn paper_default(self) -> usize {
        let d = AcceleratorConfig::paper_default();
        match self {
            Knob::NPes => d.n_pes,
            Knob::CacheLines => d.cache_lines,
            Knob::CacheAssoc => d.cache_assoc,
            Knob::BankFactor => d.esram_bank_factor,
            Knob::Rank => d.rank,
            Knob::SramKib | Knob::LocalKib => 0,
        }
    }
}

/// Size the named memory-hierarchy level to `kib` KiB, creating it if
/// absent (`outer` prepends — DRAM side; otherwise appends — PE side);
/// `kib == 0` removes the level. Geometry validity (power-of-two line
/// count) is still [`AcceleratorConfig::validate`]'s call during
/// enumeration, like every other knob.
fn set_level(cfg: &mut AcceleratorConfig, name: &str, kib: usize, outer: bool) {
    if kib == 0 {
        cfg.levels.retain(|l| l.name != name);
    } else if let Some(l) = cfg.levels.iter_mut().find(|l| l.name == name) {
        l.capacity_bytes = kib as u64 * 1024;
    } else {
        let spec = MemLevelSpec::new(name, kib as u64 * 1024);
        if outer {
            cfg.levels.insert(0, spec);
        } else {
            cfg.levels.push(spec);
        }
    }
}

impl std::fmt::Display for Knob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One axis of the grid: a knob and the values it takes.
#[derive(Clone, Debug, PartialEq)]
pub struct Axis {
    pub knob: Knob,
    pub values: Vec<usize>,
}

impl Axis {
    pub fn new(knob: Knob, values: Vec<usize>) -> Self {
        Axis { knob, values }
    }

    /// Parse the CLI grammar `knob=v1,v2,...`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let (name, vals) = s
            .split_once('=')
            .ok_or_else(|| format!("axis `{s}` is not of the form knob=v1,v2,..."))?;
        let knob = Knob::parse(name.trim())?;
        let values = vals
            .split(',')
            .map(|v| {
                v.trim()
                    .parse::<usize>()
                    .map_err(|e| format!("axis `{}` value `{v}`: {e}", knob.name()))
            })
            .collect::<Result<Vec<usize>, String>>()?;
        if values.is_empty() {
            return Err(format!("axis `{}` has no values", knob.name()));
        }
        Ok(Axis { knob, values })
    }
}

/// One enumerated, constraint-passing point of the design space.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Stable position in the enumeration (== its slot in every
    /// evaluation vector).
    pub index: usize,
    /// The axis settings that produced [`cfg`](Self::cfg), in axis order.
    pub settings: Vec<(Knob, usize)>,
    /// The fully-applied configuration (validated).
    pub cfg: AcceleratorConfig,
    /// The registry-resolved technology.
    pub tech: MemTechnology,
    /// The kernel this candidate runs.
    pub kernel: KernelKind,
    /// Instantiated-design area in the candidate's technology
    /// ([`AreaModel::design`]) — the area objective and the budget
    /// constraint share this one number.
    pub area_mm2: f64,
}

impl Candidate {
    /// Human-readable knob settings (`n_pes=4,cache_lines=4096`), or
    /// `base` when the space has no axes.
    pub fn label(&self) -> String {
        if self.settings.is_empty() {
            "base".to_string()
        } else {
            self.settings
                .iter()
                .map(|(k, v)| format!("{}={v}", k.name()))
                .collect::<Vec<_>>()
                .join(",")
        }
    }

    /// Is this the paper-default configuration (every knob at its Table I
    /// value, whatever subset of knobs the axes swept)?
    pub fn is_paper_default(&self) -> bool {
        self.cfg == AcceleratorConfig::paper_default()
    }
}

/// The enumerated grid plus the constraint bookkeeping — how many raw
/// points each predicate pruned (reported by the CLI so a tight budget
/// is visible, never a silently smaller search).
#[derive(Clone, Debug)]
pub struct EnumeratedSpace {
    pub candidates: Vec<Candidate>,
    /// (config, tech, kernel) points dropped by
    /// [`AcceleratorConfig::validate`].
    pub n_invalid: usize,
    /// Points dropped by the area-budget / wafer-scale predicates.
    pub n_filtered: usize,
}

/// The axis grammar: base configuration × axes × technologies × kernels,
/// with the constraint predicates.
#[derive(Clone, Debug)]
pub struct DesignSpace {
    /// Configuration every axis perturbs. **Not** scale-shrunk: explore
    /// evaluates real design points (capacity axes must mean something
    /// absolute) against a scaled workload fingerprint.
    pub base_cfg: AcceleratorConfig,
    /// Knob axes; empty means the base configuration alone.
    pub axes: Vec<Axis>,
    /// Technologies crossed with every configuration.
    pub techs: Vec<MemTechnology>,
    /// Kernels crossed with every (configuration, technology); frontier
    /// dominance never crosses kernels (they do different work).
    pub kernels: Vec<KernelKind>,
    /// Keep only candidates whose instantiated-design area is within
    /// this many mm² (`--budget-mm2`).
    pub budget_mm2: Option<f64>,
    /// Drop candidates larger than one reticle ([`RETICLE_MM2`]) — the
    /// §II wafer-scale feasibility predicate (`--exclude-wafer-scale`).
    /// Note this excludes *every* O-SRAM candidate of a Table-I-sized
    /// design: that is the paper's point, not a bug.
    pub exclude_wafer_scale: bool,
}

impl DesignSpace {
    /// A space over the paper-default configuration with the default
    /// axes ([`Self::paper_axes`]).
    pub fn paper_grid(techs: Vec<MemTechnology>, kernels: Vec<KernelKind>) -> Self {
        DesignSpace {
            base_cfg: AcceleratorConfig::paper_default(),
            axes: Self::paper_axes(),
            techs,
            kernels,
            budget_mm2: None,
            exclude_wafer_scale: false,
        }
    }

    /// The default CLI axes: PE count {2, 4, 8} × cache capacity
    /// {4096, 8192} lines. Both include the Table I default, so the
    /// paper's design point is always a member of the default grid.
    pub fn paper_axes() -> Vec<Axis> {
        vec![
            Axis::new(Knob::NPes, vec![2, 4, 8]),
            Axis::new(Knob::CacheLines, vec![4096, 8192]),
        ]
    }

    /// Upper bound on the grid size (before constraint pruning).
    pub fn n_points(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product::<usize>()
            * self.techs.len()
            * self.kernels.len()
    }

    fn validate(&self) -> Result<(), String> {
        if self.techs.is_empty() || self.kernels.is_empty() {
            return Err("design space needs at least one technology and one kernel".into());
        }
        let mut seen_knobs: Vec<Knob> = Vec::new();
        for a in &self.axes {
            if a.values.is_empty() {
                return Err(format!("axis `{}` has no values", a.knob.name()));
            }
            if seen_knobs.contains(&a.knob) {
                return Err(format!("knob `{}` listed twice", a.knob.name()));
            }
            seen_knobs.push(a.knob);
            // a repeated value would enumerate bit-identical candidates
            // (ties both survive strict dominance ⇒ duplicate frontier
            // rows) and waste a full simulation each — fail loudly like
            // the duplicate-tech/kernel checks do
            for (i, v) in a.values.iter().enumerate() {
                if a.values[..i].contains(v) {
                    return Err(format!(
                        "axis `{}` lists value {v} twice",
                        a.knob.name()
                    ));
                }
            }
        }
        let mut seen: Vec<&str> = Vec::new();
        for t in &self.techs {
            if seen.contains(&t.name.as_str()) {
                return Err(format!("technology `{}` listed twice", t.name));
            }
            seen.push(&t.name);
        }
        let mut seen_k: Vec<&str> = Vec::new();
        for k in &self.kernels {
            if seen_k.contains(&k.name()) {
                return Err(format!("kernel `{}` listed twice", k.name()));
            }
            seen_k.push(k.name());
        }
        if let Some(b) = self.budget_mm2 {
            if !(b > 0.0 && b.is_finite()) {
                return Err(format!("area budget {b} mm^2 is not a positive finite number"));
            }
        }
        Ok(())
    }

    /// Expand the grid, apply every constraint predicate, and return the
    /// surviving candidates in deterministic enumeration order.
    pub fn enumerate(&self) -> Result<EnumeratedSpace, String> {
        self.validate()?;
        // cartesian product of axis values, axis-major in listed order
        let mut combos: Vec<Vec<usize>> = vec![Vec::new()];
        for axis in &self.axes {
            combos = combos
                .iter()
                .flat_map(|c| {
                    axis.values.iter().map(move |&v| {
                        let mut c2 = c.clone();
                        c2.push(v);
                        c2
                    })
                })
                .collect();
        }
        let mut candidates = Vec::new();
        let mut n_invalid = 0usize;
        let mut n_filtered = 0usize;
        for combo in &combos {
            let settings: Vec<(Knob, usize)> =
                self.axes.iter().zip(combo).map(|(a, &v)| (a.knob, v)).collect();
            let mut cfg = self.base_cfg.clone();
            for &(knob, v) in &settings {
                knob.apply(&mut cfg, v);
            }
            if cfg.validate().is_err() {
                n_invalid += self.techs.len() * self.kernels.len();
                continue;
            }
            let area_model = AreaModel::new(&cfg);
            for tech in &self.techs {
                let area_mm2 = area_model.design(tech).total_mm2();
                let over_budget = self.budget_mm2.is_some_and(|b| area_mm2 > b);
                let over_reticle = self.exclude_wafer_scale && area_mm2 > RETICLE_MM2;
                if over_budget || over_reticle {
                    n_filtered += self.kernels.len();
                    continue;
                }
                for &kernel in &self.kernels {
                    candidates.push(Candidate {
                        index: candidates.len(),
                        settings: settings.clone(),
                        cfg: cfg.clone(),
                        tech: tech.clone(),
                        kernel,
                        area_mm2,
                    });
                }
            }
        }
        Ok(EnumeratedSpace { candidates, n_invalid, n_filtered })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;

    #[test]
    fn knob_grammar_roundtrips_and_rejects() {
        for k in Knob::ALL {
            assert_eq!(Knob::parse(k.name()), Ok(k));
        }
        let err = Knob::parse("warp").unwrap_err();
        for name in
            ["n_pes", "cache_lines", "cache_assoc", "bank_factor", "rank", "sram_kib", "local_kib"]
        {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn axis_grammar_parses_the_cli_form() {
        let a = Axis::parse("n_pes=2,4, 8").unwrap();
        assert_eq!(a.knob, Knob::NPes);
        assert_eq!(a.values, vec![2, 4, 8]);
        assert!(Axis::parse("n_pes").unwrap_err().contains("knob=v1,v2"));
        assert!(Axis::parse("warp=1").unwrap_err().contains("n_pes"));
        assert!(Axis::parse("rank=16,x").unwrap_err().contains("rank"));
    }

    #[test]
    fn knobs_apply_to_the_config_and_know_their_defaults() {
        let mut cfg = AcceleratorConfig::paper_default();
        Knob::NPes.apply(&mut cfg, 8);
        Knob::CacheLines.apply(&mut cfg, 8192);
        Knob::CacheAssoc.apply(&mut cfg, 8);
        Knob::BankFactor.apply(&mut cfg, 2);
        Knob::Rank.apply(&mut cfg, 8);
        assert_eq!(
            (cfg.n_pes, cfg.cache_lines, cfg.cache_assoc, cfg.esram_bank_factor, cfg.rank),
            (8, 8192, 8, 2, 8)
        );
        assert_eq!(Knob::NPes.paper_default(), 4);
        assert_eq!(Knob::CacheLines.paper_default(), 4096);
        assert_eq!(Knob::Rank.paper_default(), 16);
        assert_eq!(Knob::SramKib.paper_default(), 0);
        assert_eq!(Knob::LocalKib.paper_default(), 0);
    }

    #[test]
    fn hierarchy_knobs_edit_the_level_stack() {
        let mut cfg = AcceleratorConfig::paper_default();
        // creation order must not matter: sram is always outermost
        Knob::LocalKib.apply(&mut cfg, 4);
        Knob::SramKib.apply(&mut cfg, 256);
        assert_eq!(cfg.levels.len(), 2);
        assert_eq!(cfg.levels[0].name, "sram");
        assert_eq!(cfg.levels[0].capacity_bytes, 256 * 1024);
        assert_eq!(cfg.levels[1].name, "local");
        cfg.validate().unwrap();
        // re-applying resizes in place, never duplicates
        Knob::SramKib.apply(&mut cfg, 512);
        assert_eq!(cfg.levels.len(), 2);
        assert_eq!(cfg.levels[0].capacity_bytes, 512 * 1024);
        // 0 removes the level; all-zero returns to the degenerate stack
        Knob::SramKib.apply(&mut cfg, 0);
        Knob::LocalKib.apply(&mut cfg, 0);
        assert!(cfg.levels.is_empty());
        assert!(cfg == AcceleratorConfig::paper_default());
    }

    #[test]
    fn hierarchy_axes_enumerate_and_price_area() {
        let mut space = DesignSpace::paper_grid(vec![tech("e-sram")], vec![KernelKind::Spmttkrp]);
        space.axes = vec![Axis::new(Knob::SramKib, vec![0, 256, 512])];
        let e = space.enumerate().unwrap();
        assert_eq!(e.candidates.len(), 3);
        assert_eq!((e.n_invalid, e.n_filtered), (0, 0));
        // capacity must cost area monotonically (the AreaModel pricing)
        assert!(e.candidates[0].area_mm2 < e.candidates[1].area_mm2);
        assert!(e.candidates[1].area_mm2 < e.candidates[2].area_mm2);
        // the 0-valued point is the degenerate paper default
        assert!(e.candidates[0].is_paper_default());
        assert_eq!(e.candidates[0].label(), "sram_kib=0");
        assert_eq!(e.candidates[1].cfg.levels.len(), 1);
    }

    #[test]
    fn enumeration_is_the_filtered_cartesian_product() {
        let space = DesignSpace::paper_grid(
            vec![tech("e-sram"), tech("o-sram")],
            vec![KernelKind::Spmttkrp],
        );
        // 3 PE counts × 2 cache sizes × 2 techs × 1 kernel
        assert_eq!(space.n_points(), 12);
        let e = space.enumerate().unwrap();
        assert_eq!(e.candidates.len(), 12);
        assert_eq!((e.n_invalid, e.n_filtered), (0, 0));
        for (i, c) in e.candidates.iter().enumerate() {
            assert_eq!(c.index, i);
            c.cfg.validate().unwrap();
            assert!(c.area_mm2 > 0.0);
        }
        // deterministic order: axis-major, then tech, then kernel
        assert_eq!(e.candidates[0].label(), "n_pes=2,cache_lines=4096");
        assert_eq!(e.candidates[0].tech.name, "e-sram");
        assert_eq!(e.candidates[1].tech.name, "o-sram");
        assert_eq!(e.candidates[2].label(), "n_pes=2,cache_lines=8192");
        // exactly one paper-default config per tech
        let defaults: Vec<&Candidate> =
            e.candidates.iter().filter(|c| c.is_paper_default()).collect();
        assert_eq!(defaults.len(), 2);
        assert_eq!(defaults[0].label(), "n_pes=4,cache_lines=4096");
    }

    #[test]
    fn invalid_configs_are_counted_not_enumerated() {
        let mut space = DesignSpace::paper_grid(vec![tech("o-sram")], vec![KernelKind::Spmttkrp]);
        // rank 32 → 128 B rows > 64 B lines: every rank-32 combo invalid
        space.axes = vec![Axis::new(Knob::Rank, vec![16, 32])];
        let e = space.enumerate().unwrap();
        assert_eq!(e.candidates.len(), 1);
        assert_eq!(e.n_invalid, 1);
        assert!(e.candidates.iter().all(|c| c.cfg.rank == 16));
    }

    #[test]
    fn area_budget_and_reticle_prune_per_technology() {
        let mut space = DesignSpace::paper_grid(
            vec![tech("e-sram"), tech("o-sram")],
            vec![KernelKind::Spmttkrp],
        );
        space.axes = Vec::new();
        // a Table-I e-sram design is a few hundred mm²; o-sram is wafer-scale
        space.budget_mm2 = Some(500.0);
        let e = space.enumerate().unwrap();
        assert_eq!(e.candidates.len(), 1);
        assert_eq!(e.candidates[0].tech.name, "e-sram");
        assert_eq!(e.n_filtered, 1);
        assert_eq!(e.candidates[0].label(), "base");
        // the reticle predicate prunes the same wafer-scale point
        space.budget_mm2 = None;
        space.exclude_wafer_scale = true;
        let e = space.enumerate().unwrap();
        assert_eq!(e.candidates.len(), 1);
        assert_eq!(e.candidates[0].tech.name, "e-sram");
    }

    #[test]
    fn invalid_spaces_are_rejected() {
        let mut s = DesignSpace::paper_grid(vec![tech("o-sram")], vec![KernelKind::Spmttkrp]);
        s.techs.clear();
        assert!(s.enumerate().is_err());
        let mut s = DesignSpace::paper_grid(vec![tech("o-sram")], vec![KernelKind::Spmttkrp]);
        s.kernels.clear();
        assert!(s.enumerate().is_err());
        let mut s = DesignSpace::paper_grid(vec![tech("o-sram")], vec![KernelKind::Spmttkrp]);
        s.axes.push(Axis::new(Knob::NPes, vec![16]));
        assert!(s.enumerate().unwrap_err().contains("n_pes"));
        // a duplicated value would enumerate the same candidate twice
        let mut s = DesignSpace::paper_grid(vec![tech("o-sram")], vec![KernelKind::Spmttkrp]);
        s.axes = vec![Axis::new(Knob::NPes, vec![4, 4])];
        let e = s.enumerate().unwrap_err();
        assert!(e.contains("n_pes") && e.contains("twice"), "{e}");
        let mut s = DesignSpace::paper_grid(
            vec![tech("o-sram"), tech("o-sram")],
            vec![KernelKind::Spmttkrp],
        );
        assert!(s.enumerate().is_err());
        s.techs = vec![tech("o-sram")];
        s.kernels = vec![KernelKind::Spmm, KernelKind::Spmm];
        assert!(s.enumerate().is_err());
        s.kernels = vec![KernelKind::Spmm];
        s.budget_mm2 = Some(0.0);
        assert!(s.enumerate().is_err());
    }
}
