//! Canonical, versioned cache-key serialization.
//!
//! [`EvalCache`](crate::explore::eval::EvalCache) keys used to be the
//! `Debug` rendering of the configuration and technology structs. That
//! was injective *today*, but tied cache identity to `#[derive(Debug)]`
//! output: a field rename, a field reorder, or a future rustc change to
//! float formatting would silently invalidate every stored entry — or,
//! worse, alias two distinct configurations. Now that entries survive
//! the process on disk ([`crate::explore::store`]), key text is a
//! *format* with a compatibility contract, so it is spelled out here by
//! hand:
//!
//! * every field of [`AcceleratorConfig`] (including every
//!   [`DramConfig`] sub-field and the [`MemLevelSpec`] stack via
//!   [`format_levels`]) and every field of [`MemTechnology`] is written
//!   **by name**, in declaration order — adding a field to either
//!   struct is a compile error here until the key learns about it, at
//!   which point [`CACHE_SCHEMA_VERSION`] must be bumped;
//! * every `f64` is rendered as the `{:016x}` hex of its IEEE-754 bits
//!   — injective per value (no shortest-roundtrip subtleties) and
//!   byte-stable across compilers and platforms;
//! * `Option` fields render as `-` when absent, so `None` can never
//!   collide with any present value;
//! * the key starts with `v{CACHE_SCHEMA_VERSION}|`, and the on-disk
//!   store embeds the same version in its filename — a version bump
//!   orphans old files instead of misreading them.
//!
//! **Policy:** bump [`CACHE_SCHEMA_VERSION`] on *any* change that can
//! alter a reported number for an unchanged key — a new config field
//! consulted by the engines, a semantic change to an existing field, a
//! change to the energy/area models, or a change to this serialization
//! itself. Bumping is cheap (one cold re-fill); a stale hit is a wrong
//! answer served as a bit-identical truth.
//!
//! **Two-tier structure.** The config rendering is split along the
//! functional/timing seam (see [`crate::sim::profile`]): the fields
//! that determine the *functional* counters — hit/miss/traffic, a pure
//! function of `{workload, kernel, cache geometry, level stack}` —
//! render as the `geom{…}` component ([`canonical_geometry`]), and the
//! fields that only *price* those counters (technology-tuned knobs,
//! exec shape, rank, DRAM timing…) render as the `price{…}` component
//! ([`canonical_pricing`]). An [`eval_key`] leads with the geometry
//! component, so the persistent store textually records both tiers of
//! every entry's identity; [`functional_key`] is the geometry tier
//! alone and keys the in-memory profile memo that lets one stream walk
//! serve every pricing of the same geometry.

use crate::accel::config::AcceleratorConfig;
use crate::mem::dram::DramConfig;
use crate::mem::hierarchy::format_levels;
use crate::mem::tech::MemTechnology;
use crate::sim::{EngineKind, SampleSpec};

/// Version of the canonical key/record format. Bump on any change that
/// can alter a reported number for an unchanged key (see module docs);
/// the on-disk store names its file after this, so old entries are
/// orphaned rather than misread.
/// v1 → v2: busy figures became `count × constant` derivations (ULP
/// shifts vs. the old per-access accumulation) and the config rendering
/// split into geometry/pricing components.
pub const CACHE_SCHEMA_VERSION: u32 = 2;

/// IEEE-754 bits as fixed-width hex: injective per value, byte-stable.
fn f(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

fn opt_usize(x: Option<usize>) -> String {
    x.map_or_else(|| "-".to_string(), |v| v.to_string())
}

fn opt_u32(x: Option<u32>) -> String {
    x.map_or_else(|| "-".to_string(), |v| v.to_string())
}

/// Canonical rendering of a [`DramConfig`]: every field, by name, in
/// declaration order, floats as bit-hex.
pub fn canonical_dram(d: &DramConfig) -> String {
    format!(
        "dram{{peak={};eff={};burst={};rowhit={};rowmiss={};randhit={};overlap={};epb={};act={}}}",
        f(d.peak_bytes_per_s),
        f(d.stream_efficiency),
        d.burst_bytes,
        f(d.row_hit_ns),
        f(d.row_miss_ns),
        f(d.random_row_hit_rate),
        f(d.random_overlap),
        f(d.energy_pj_per_bit),
        f(d.activate_pj),
    )
}

/// Split one [`AcceleratorConfig`] into its `(geometry, pricing)`
/// canonical components: every field, by name, in declaration order,
/// each on exactly one side of the functional/timing seam. The single
/// destructuring binding is the completeness guard — a new field fails
/// to compile here until it is added to one of the two renderings (and
/// the schema version bumped).
fn split_config(cfg: &AcceleratorConfig) -> (String, String) {
    let AcceleratorConfig {
        n_pes,
        n_pipelines,
        psum_elements,
        n_caches,
        cache_assoc,
        cache_lines,
        line_bytes,
        n_dma_buffers,
        dma_buffer_bytes,
        rank,
        fabric_hz,
        dram,
        esram_bank_factor,
        compute_power_w,
        cache_bypass_factor,
        osram_lambda_override,
        levels,
        onchip_bytes,
        luts,
        flipflops,
        dsps,
    } = cfg;
    let geom = format!(
        "geom{{pes={n_pes};caches={n_caches};assoc={cache_assoc};lines={cache_lines};\
         lineb={line_bytes};bypass={};levels=[{}]}}",
        opt_usize(*cache_bypass_factor),
        format_levels(levels),
    );
    let price = format!(
        "price{{pipes={n_pipelines};psum={psum_elements};dmabuf={n_dma_buffers};\
         dmabytes={dma_buffer_bytes};rank={rank};fabric={};{};bankf={esram_bank_factor};\
         power={};lambda={};onchip={onchip_bytes};luts={luts};ffs={flipflops};dsps={dsps}}}",
        f(*fabric_hz),
        canonical_dram(dram),
        f(*compute_power_w),
        opt_u32(*osram_lambda_override),
    );
    (geom, price)
}

/// The functional-geometry component of a config: exactly the fields
/// the functional pass consumes — `n_pes` (PE partitioning), cache
/// count/associativity/lines/line bytes, the bypass factor and the
/// level stack. Two configs with equal geometry components produce
/// bit-identical [`crate::sim::profile::GeometryProfile`]s for any
/// workload.
pub fn canonical_geometry(cfg: &AcceleratorConfig) -> String {
    split_config(cfg).0
}

/// The pricing component of a config: every remaining field — the ones
/// that only scale the functional counters into cycles/joules/mm².
pub fn canonical_pricing(cfg: &AcceleratorConfig) -> String {
    split_config(cfg).1
}

/// Canonical rendering of an [`AcceleratorConfig`]: the geometry
/// component followed by the pricing component, `|`-separated, so the
/// functional tier is a textual prefix of the full config identity.
pub fn canonical_config(cfg: &AcceleratorConfig) -> String {
    let (geom, price) = split_config(cfg);
    format!("{geom}|{price}")
}

/// The functional-tier key: identifies one
/// [`crate::sim::profile::GeometryProfile`] — geometry × kernel ×
/// workload, nothing else (no technology, no pricing knob, no engine,
/// no sample). Every evaluation whose [`eval_key`] shares this prefix
/// reuses the same profiled stream walk.
pub fn functional_key(cfg: &AcceleratorConfig, kernel: &str, workload_tag: &str) -> String {
    format!(
        "v{CACHE_SCHEMA_VERSION}|{}|kernel={kernel}|wl={workload_tag}",
        canonical_geometry(cfg)
    )
}

/// Canonical rendering of a [`MemTechnology`]: every field, by name, in
/// declaration order. Registry names are identifier-like (TOML section
/// keys), so the raw name is delimiter-safe.
pub fn canonical_tech(t: &MemTechnology) -> String {
    let MemTechnology {
        name,
        freq_hz,
        wavelengths,
        lanes_per_core_cycle,
        port_width_bits,
        ports_per_block,
        block_bits,
        data_lines,
        access_latency_cycles,
        static_pj_per_bit_cycle,
        switching_pj_per_bit,
        conversion_pj_per_bit,
        storage_pj_per_bit,
        area_um2_per_bit,
    } = t;
    format!(
        "tech{{name={name};freq={};wl={wavelengths};lanes={lanes_per_core_cycle};\
         portw={port_width_bits};ports={ports_per_block};block={block_bits};\
         dlines={data_lines};lat={access_latency_cycles};static={};switch={};conv={};\
         store={};area={}}}",
        f(*freq_hz),
        f(*static_pj_per_bit_cycle),
        f(*switching_pj_per_bit),
        f(*conversion_pj_per_bit),
        f(*storage_pj_per_bit),
        f(*area_um2_per_bit),
    )
}

/// The full canonical content key of one evaluation:
/// `(config, tech, kernel, engine, sample, workload)`.
///
/// The sample tag is `exact` unless it can change the result — event
/// engine at a rate below 1.0 (see [`crate::explore::eval`] module
/// docs) — so a rate-1.0 event run keys identically to an unsampled
/// one, regardless of seed, and the analytic engine ignores the sample
/// entirely.
pub fn eval_key(
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    kernel: &str,
    engine: EngineKind,
    sample: SampleSpec,
    workload_tag: &str,
) -> String {
    let sample_tag = if engine == EngineKind::Event && !sample.is_exact() {
        format!("sample{{rate={};seed={}}}", f(sample.rate), sample.seed)
    } else {
        "sample{exact}".to_string()
    };
    format!(
        "v{CACHE_SCHEMA_VERSION}|{}|{}|kernel={kernel}|engine={}|{sample_tag}|wl={workload_tag}",
        canonical_config(cfg),
        canonical_tech(tech),
        engine.name(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::hierarchy::parse_levels;
    use crate::mem::registry::tech;

    fn base_key(cfg: &AcceleratorConfig) -> String {
        eval_key(
            cfg,
            &tech("o-sram"),
            "spmttkrp",
            EngineKind::Analytic,
            SampleSpec::exact(),
            "wl#test",
        )
    }

    #[test]
    fn key_text_is_byte_stable_across_runs() {
        // Pure function of field values: two independent renderings of
        // equal inputs must be byte-identical, and the versioned prefix
        // is pinned so a schema bump cannot happen silently.
        let cfg = AcceleratorConfig::paper_default();
        let a = base_key(&cfg);
        let b = base_key(&cfg.clone());
        assert_eq!(a, b);
        assert!(
            a.starts_with(&format!("v{CACHE_SCHEMA_VERSION}|geom{{")),
            "canonical keys must lead with the schema version and the \
             functional-geometry tier: {a}"
        );
        // no Debug rendering leaks in (struct names would appear)
        assert!(!a.contains("AcceleratorConfig"), "{a}");
        assert!(!a.contains("MemTechnology"), "{a}");
    }

    #[test]
    fn every_config_field_separates_keys() {
        // Two configs differing in exactly one field — any field — must
        // never collide. One mutation per field, including the Option
        // fields, the DRAM sub-fields and the level stack.
        let base = AcceleratorConfig::paper_default();
        let k0 = base_key(&base);
        let mutations: Vec<Box<dyn Fn(&mut AcceleratorConfig)>> = vec![
            Box::new(|c| c.n_pes += 1),
            Box::new(|c| c.n_pipelines += 1),
            Box::new(|c| c.psum_elements += 1),
            Box::new(|c| c.n_caches += 1),
            Box::new(|c| c.cache_assoc += 1),
            Box::new(|c| c.cache_lines += 1),
            Box::new(|c| c.line_bytes *= 2),
            Box::new(|c| c.n_dma_buffers += 1),
            Box::new(|c| c.dma_buffer_bytes *= 2),
            Box::new(|c| c.rank += 1),
            Box::new(|c| c.fabric_hz += 1.0),
            Box::new(|c| c.dram.peak_bytes_per_s += 1.0),
            Box::new(|c| c.dram.stream_efficiency += 0.01),
            Box::new(|c| c.dram.burst_bytes *= 2),
            Box::new(|c| c.dram.row_hit_ns += 1.0),
            Box::new(|c| c.dram.row_miss_ns += 1.0),
            Box::new(|c| c.dram.random_row_hit_rate += 0.01),
            Box::new(|c| c.dram.random_overlap += 0.5),
            Box::new(|c| c.dram.energy_pj_per_bit += 0.5),
            Box::new(|c| c.dram.activate_pj += 1.0),
            Box::new(|c| c.esram_bank_factor += 1),
            Box::new(|c| c.compute_power_w += 0.1),
            Box::new(|c| c.cache_bypass_factor = Some(2)),
            Box::new(|c| c.osram_lambda_override = Some(8)),
            Box::new(|c| c.levels = parse_levels("sram:256KiB:8banks").unwrap()),
            Box::new(|c| c.onchip_bytes += 1),
            Box::new(|c| c.luts += 1),
            Box::new(|c| c.flipflops += 1),
            Box::new(|c| c.dsps += 1),
        ];
        let mut seen = vec![k0.clone()];
        for (i, m) in mutations.iter().enumerate() {
            let mut c = base.clone();
            m(&mut c);
            let k = base_key(&c);
            assert_ne!(k, k0, "mutation #{i} did not change the key");
            assert!(!seen.contains(&k), "mutation #{i} aliased another key");
            seen.push(k);
        }
    }

    #[test]
    fn every_tech_field_separates_keys() {
        let base = tech("o-sram");
        let cfg = AcceleratorConfig::paper_default();
        let key = |t: &MemTechnology| {
            eval_key(&cfg, t, "spmttkrp", EngineKind::Analytic, SampleSpec::exact(), "wl")
        };
        let k0 = key(&base);
        let mutations: Vec<Box<dyn Fn(&mut MemTechnology)>> = vec![
            Box::new(|t| t.name.push('x')),
            Box::new(|t| t.freq_hz += 1.0),
            Box::new(|t| t.wavelengths += 1),
            Box::new(|t| t.lanes_per_core_cycle += 1),
            Box::new(|t| t.port_width_bits += 1),
            Box::new(|t| t.ports_per_block += 1),
            Box::new(|t| t.block_bits += 1),
            Box::new(|t| t.data_lines += 1),
            Box::new(|t| t.access_latency_cycles += 1),
            Box::new(|t| t.static_pj_per_bit_cycle += 0.1),
            Box::new(|t| t.switching_pj_per_bit += 0.1),
            Box::new(|t| t.conversion_pj_per_bit += 0.1),
            Box::new(|t| t.storage_pj_per_bit += 0.1),
            Box::new(|t| t.area_um2_per_bit += 0.1),
        ];
        let mut seen = vec![k0.clone()];
        for (i, m) in mutations.iter().enumerate() {
            let mut t = base.clone();
            m(&mut t);
            let k = key(&t);
            assert_ne!(k, k0, "tech mutation #{i} did not change the key");
            assert!(!seen.contains(&k), "tech mutation #{i} aliased another key");
            seen.push(k);
        }
    }

    #[test]
    fn functional_key_tracks_geometry_and_ignores_pricing() {
        // The functional tier must separate every geometry field (a
        // collision would serve one geometry's counts as another's) and
        // must NOT move under pricing-only mutations (that reuse is the
        // whole point of the tier).
        let base = AcceleratorConfig::paper_default();
        let fk = |c: &AcceleratorConfig| functional_key(c, "spmttkrp", "wl#test");
        let k0 = fk(&base);
        assert!(k0.starts_with(&format!("v{CACHE_SCHEMA_VERSION}|geom{{")), "{k0}");

        let geometry: Vec<Box<dyn Fn(&mut AcceleratorConfig)>> = vec![
            Box::new(|c| c.n_pes += 1),
            Box::new(|c| c.n_caches += 1),
            Box::new(|c| c.cache_assoc += 1),
            Box::new(|c| c.cache_lines += 1),
            Box::new(|c| c.line_bytes *= 2),
            Box::new(|c| c.cache_bypass_factor = Some(2)),
            Box::new(|c| c.levels = parse_levels("sram:256KiB:8banks").unwrap()),
        ];
        let mut seen = vec![k0.clone()];
        for (i, m) in geometry.iter().enumerate() {
            let mut c = base.clone();
            m(&mut c);
            let k = fk(&c);
            assert_ne!(k, k0, "geometry mutation #{i} did not change the functional key");
            assert!(!seen.contains(&k), "geometry mutation #{i} aliased another key");
            seen.push(k);
        }

        let pricing: Vec<Box<dyn Fn(&mut AcceleratorConfig)>> = vec![
            Box::new(|c| c.n_pipelines += 1),
            Box::new(|c| c.psum_elements += 1),
            Box::new(|c| c.n_dma_buffers += 1),
            Box::new(|c| c.dma_buffer_bytes *= 2),
            Box::new(|c| c.rank += 1),
            Box::new(|c| c.fabric_hz += 1.0),
            Box::new(|c| c.dram.row_miss_ns += 1.0),
            Box::new(|c| c.esram_bank_factor += 1),
            Box::new(|c| c.compute_power_w += 0.1),
            Box::new(|c| c.osram_lambda_override = Some(8)),
            Box::new(|c| c.onchip_bytes += 1),
            Box::new(|c| c.luts += 1),
            Box::new(|c| c.flipflops += 1),
            Box::new(|c| c.dsps += 1),
        ];
        for (i, m) in pricing.iter().enumerate() {
            let mut c = base.clone();
            m(&mut c);
            assert_eq!(fk(&c), k0, "pricing mutation #{i} moved the functional key");
        }
    }

    #[test]
    fn eval_key_leads_with_the_functional_geometry_component() {
        // Two-tier store records: the full key's geometry component is
        // textually identical to the one the functional memo keys on.
        let cfg = AcceleratorConfig::paper_default();
        let full = base_key(&cfg);
        let geom = canonical_geometry(&cfg);
        assert!(
            full.starts_with(&format!("v{CACHE_SCHEMA_VERSION}|{geom}|price{{")),
            "eval key must lead with the geometry tier then the pricing tier: {full}"
        );
    }

    #[test]
    fn none_options_cannot_alias_present_values() {
        let mut with = AcceleratorConfig::paper_default();
        with.cache_bypass_factor = Some(1);
        let mut without = AcceleratorConfig::paper_default();
        without.cache_bypass_factor = None;
        assert_ne!(base_key(&with), base_key(&without));
    }

    #[test]
    fn keys_never_contain_newlines() {
        // The on-disk store is line-oriented: one record per line, the
        // key as the final field. Canonical keys must therefore stay on
        // one line for every representable input.
        let mut cfg = AcceleratorConfig::paper_default();
        cfg.levels = parse_levels("sram:256KiB:8banks,local:4KiB:db").unwrap();
        let k = eval_key(
            &cfg,
            &tech("e-sram"),
            "spttm",
            EngineKind::Event,
            SampleSpec::new(0.25, 7).unwrap(),
            "grid#dims[64, 64, 64]#nnz3000#seed7#remaptrue#fpdeadbeefdeadbeef",
        );
        assert!(!k.contains('\n'));
        assert!(!k.contains('\r'));
    }
}
