//! Canonical, versioned cache-key serialization.
//!
//! [`EvalCache`](crate::explore::eval::EvalCache) keys used to be the
//! `Debug` rendering of the configuration and technology structs. That
//! was injective *today*, but tied cache identity to `#[derive(Debug)]`
//! output: a field rename, a field reorder, or a future rustc change to
//! float formatting would silently invalidate every stored entry — or,
//! worse, alias two distinct configurations. Now that entries survive
//! the process on disk ([`crate::explore::store`]), key text is a
//! *format* with a compatibility contract, so it is spelled out here by
//! hand:
//!
//! * every field of [`AcceleratorConfig`] (including every
//!   [`DramConfig`] sub-field and the [`MemLevelSpec`] stack via
//!   [`format_levels`]) and every field of [`MemTechnology`] is written
//!   **by name**, in declaration order — adding a field to either
//!   struct is a compile error here until the key learns about it, at
//!   which point [`CACHE_SCHEMA_VERSION`] must be bumped;
//! * every `f64` is rendered as the `{:016x}` hex of its IEEE-754 bits
//!   — injective per value (no shortest-roundtrip subtleties) and
//!   byte-stable across compilers and platforms;
//! * `Option` fields render as `-` when absent, so `None` can never
//!   collide with any present value;
//! * the key starts with `v{CACHE_SCHEMA_VERSION}|`, and the on-disk
//!   store embeds the same version in its filename — a version bump
//!   orphans old files instead of misreading them.
//!
//! **Policy:** bump [`CACHE_SCHEMA_VERSION`] on *any* change that can
//! alter a reported number for an unchanged key — a new config field
//! consulted by the engines, a semantic change to an existing field, a
//! change to the energy/area models, or a change to this serialization
//! itself. Bumping is cheap (one cold re-fill); a stale hit is a wrong
//! answer served as a bit-identical truth.

use crate::accel::config::AcceleratorConfig;
use crate::mem::dram::DramConfig;
use crate::mem::hierarchy::format_levels;
use crate::mem::tech::MemTechnology;
use crate::sim::{EngineKind, SampleSpec};

/// Version of the canonical key/record format. Bump on any change that
/// can alter a reported number for an unchanged key (see module docs);
/// the on-disk store names its file after this, so old entries are
/// orphaned rather than misread.
pub const CACHE_SCHEMA_VERSION: u32 = 1;

/// IEEE-754 bits as fixed-width hex: injective per value, byte-stable.
fn f(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

fn opt_usize(x: Option<usize>) -> String {
    x.map_or_else(|| "-".to_string(), |v| v.to_string())
}

fn opt_u32(x: Option<u32>) -> String {
    x.map_or_else(|| "-".to_string(), |v| v.to_string())
}

/// Canonical rendering of a [`DramConfig`]: every field, by name, in
/// declaration order, floats as bit-hex.
pub fn canonical_dram(d: &DramConfig) -> String {
    format!(
        "dram{{peak={};eff={};burst={};rowhit={};rowmiss={};randhit={};overlap={};epb={};act={}}}",
        f(d.peak_bytes_per_s),
        f(d.stream_efficiency),
        d.burst_bytes,
        f(d.row_hit_ns),
        f(d.row_miss_ns),
        f(d.random_row_hit_rate),
        f(d.random_overlap),
        f(d.energy_pj_per_bit),
        f(d.activate_pj),
    )
}

/// Canonical rendering of an [`AcceleratorConfig`]: every field, by
/// name, in declaration order. The destructuring binding is the
/// completeness guard — a new field fails to compile here until it is
/// added to the rendering (and the schema version bumped).
pub fn canonical_config(cfg: &AcceleratorConfig) -> String {
    let AcceleratorConfig {
        n_pes,
        n_pipelines,
        psum_elements,
        n_caches,
        cache_assoc,
        cache_lines,
        line_bytes,
        n_dma_buffers,
        dma_buffer_bytes,
        rank,
        fabric_hz,
        dram,
        esram_bank_factor,
        compute_power_w,
        cache_bypass_factor,
        osram_lambda_override,
        levels,
        onchip_bytes,
        luts,
        flipflops,
        dsps,
    } = cfg;
    format!(
        "cfg{{pes={n_pes};pipes={n_pipelines};psum={psum_elements};caches={n_caches};\
         assoc={cache_assoc};lines={cache_lines};lineb={line_bytes};dmabuf={n_dma_buffers};\
         dmabytes={dma_buffer_bytes};rank={rank};fabric={};{};bankf={esram_bank_factor};\
         power={};bypass={};lambda={};levels=[{}];onchip={onchip_bytes};luts={luts};\
         ffs={flipflops};dsps={dsps}}}",
        f(*fabric_hz),
        canonical_dram(dram),
        f(*compute_power_w),
        opt_usize(*cache_bypass_factor),
        opt_u32(*osram_lambda_override),
        format_levels(levels),
    )
}

/// Canonical rendering of a [`MemTechnology`]: every field, by name, in
/// declaration order. Registry names are identifier-like (TOML section
/// keys), so the raw name is delimiter-safe.
pub fn canonical_tech(t: &MemTechnology) -> String {
    let MemTechnology {
        name,
        freq_hz,
        wavelengths,
        lanes_per_core_cycle,
        port_width_bits,
        ports_per_block,
        block_bits,
        data_lines,
        access_latency_cycles,
        static_pj_per_bit_cycle,
        switching_pj_per_bit,
        conversion_pj_per_bit,
        storage_pj_per_bit,
        area_um2_per_bit,
    } = t;
    format!(
        "tech{{name={name};freq={};wl={wavelengths};lanes={lanes_per_core_cycle};\
         portw={port_width_bits};ports={ports_per_block};block={block_bits};\
         dlines={data_lines};lat={access_latency_cycles};static={};switch={};conv={};\
         store={};area={}}}",
        f(*freq_hz),
        f(*static_pj_per_bit_cycle),
        f(*switching_pj_per_bit),
        f(*conversion_pj_per_bit),
        f(*storage_pj_per_bit),
        f(*area_um2_per_bit),
    )
}

/// The full canonical content key of one evaluation:
/// `(config, tech, kernel, engine, sample, workload)`.
///
/// The sample tag is `exact` unless it can change the result — event
/// engine at a rate below 1.0 (see [`crate::explore::eval`] module
/// docs) — so a rate-1.0 event run keys identically to an unsampled
/// one, regardless of seed, and the analytic engine ignores the sample
/// entirely.
pub fn eval_key(
    cfg: &AcceleratorConfig,
    tech: &MemTechnology,
    kernel: &str,
    engine: EngineKind,
    sample: SampleSpec,
    workload_tag: &str,
) -> String {
    let sample_tag = if engine == EngineKind::Event && !sample.is_exact() {
        format!("sample{{rate={};seed={}}}", f(sample.rate), sample.seed)
    } else {
        "sample{exact}".to_string()
    };
    format!(
        "v{CACHE_SCHEMA_VERSION}|{}|{}|kernel={kernel}|engine={}|{sample_tag}|wl={workload_tag}",
        canonical_config(cfg),
        canonical_tech(tech),
        engine.name(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::hierarchy::parse_levels;
    use crate::mem::registry::tech;

    fn base_key(cfg: &AcceleratorConfig) -> String {
        eval_key(
            cfg,
            &tech("o-sram"),
            "spmttkrp",
            EngineKind::Analytic,
            SampleSpec::exact(),
            "wl#test",
        )
    }

    #[test]
    fn key_text_is_byte_stable_across_runs() {
        // Pure function of field values: two independent renderings of
        // equal inputs must be byte-identical, and the versioned prefix
        // is pinned so a schema bump cannot happen silently.
        let cfg = AcceleratorConfig::paper_default();
        let a = base_key(&cfg);
        let b = base_key(&cfg.clone());
        assert_eq!(a, b);
        assert!(
            a.starts_with(&format!("v{CACHE_SCHEMA_VERSION}|cfg{{")),
            "canonical keys must lead with the schema version: {a}"
        );
        // no Debug rendering leaks in (struct names would appear)
        assert!(!a.contains("AcceleratorConfig"), "{a}");
        assert!(!a.contains("MemTechnology"), "{a}");
    }

    #[test]
    fn every_config_field_separates_keys() {
        // Two configs differing in exactly one field — any field — must
        // never collide. One mutation per field, including the Option
        // fields, the DRAM sub-fields and the level stack.
        let base = AcceleratorConfig::paper_default();
        let k0 = base_key(&base);
        let mutations: Vec<Box<dyn Fn(&mut AcceleratorConfig)>> = vec![
            Box::new(|c| c.n_pes += 1),
            Box::new(|c| c.n_pipelines += 1),
            Box::new(|c| c.psum_elements += 1),
            Box::new(|c| c.n_caches += 1),
            Box::new(|c| c.cache_assoc += 1),
            Box::new(|c| c.cache_lines += 1),
            Box::new(|c| c.line_bytes *= 2),
            Box::new(|c| c.n_dma_buffers += 1),
            Box::new(|c| c.dma_buffer_bytes *= 2),
            Box::new(|c| c.rank += 1),
            Box::new(|c| c.fabric_hz += 1.0),
            Box::new(|c| c.dram.peak_bytes_per_s += 1.0),
            Box::new(|c| c.dram.stream_efficiency += 0.01),
            Box::new(|c| c.dram.burst_bytes *= 2),
            Box::new(|c| c.dram.row_hit_ns += 1.0),
            Box::new(|c| c.dram.row_miss_ns += 1.0),
            Box::new(|c| c.dram.random_row_hit_rate += 0.01),
            Box::new(|c| c.dram.random_overlap += 0.5),
            Box::new(|c| c.dram.energy_pj_per_bit += 0.5),
            Box::new(|c| c.dram.activate_pj += 1.0),
            Box::new(|c| c.esram_bank_factor += 1),
            Box::new(|c| c.compute_power_w += 0.1),
            Box::new(|c| c.cache_bypass_factor = Some(2)),
            Box::new(|c| c.osram_lambda_override = Some(8)),
            Box::new(|c| c.levels = parse_levels("sram:256KiB:8banks").unwrap()),
            Box::new(|c| c.onchip_bytes += 1),
            Box::new(|c| c.luts += 1),
            Box::new(|c| c.flipflops += 1),
            Box::new(|c| c.dsps += 1),
        ];
        let mut seen = vec![k0.clone()];
        for (i, m) in mutations.iter().enumerate() {
            let mut c = base.clone();
            m(&mut c);
            let k = base_key(&c);
            assert_ne!(k, k0, "mutation #{i} did not change the key");
            assert!(!seen.contains(&k), "mutation #{i} aliased another key");
            seen.push(k);
        }
    }

    #[test]
    fn every_tech_field_separates_keys() {
        let base = tech("o-sram");
        let cfg = AcceleratorConfig::paper_default();
        let key = |t: &MemTechnology| {
            eval_key(&cfg, t, "spmttkrp", EngineKind::Analytic, SampleSpec::exact(), "wl")
        };
        let k0 = key(&base);
        let mutations: Vec<Box<dyn Fn(&mut MemTechnology)>> = vec![
            Box::new(|t| t.name.push('x')),
            Box::new(|t| t.freq_hz += 1.0),
            Box::new(|t| t.wavelengths += 1),
            Box::new(|t| t.lanes_per_core_cycle += 1),
            Box::new(|t| t.port_width_bits += 1),
            Box::new(|t| t.ports_per_block += 1),
            Box::new(|t| t.block_bits += 1),
            Box::new(|t| t.data_lines += 1),
            Box::new(|t| t.access_latency_cycles += 1),
            Box::new(|t| t.static_pj_per_bit_cycle += 0.1),
            Box::new(|t| t.switching_pj_per_bit += 0.1),
            Box::new(|t| t.conversion_pj_per_bit += 0.1),
            Box::new(|t| t.storage_pj_per_bit += 0.1),
            Box::new(|t| t.area_um2_per_bit += 0.1),
        ];
        let mut seen = vec![k0.clone()];
        for (i, m) in mutations.iter().enumerate() {
            let mut t = base.clone();
            m(&mut t);
            let k = key(&t);
            assert_ne!(k, k0, "tech mutation #{i} did not change the key");
            assert!(!seen.contains(&k), "tech mutation #{i} aliased another key");
            seen.push(k);
        }
    }

    #[test]
    fn none_options_cannot_alias_present_values() {
        let mut with = AcceleratorConfig::paper_default();
        with.cache_bypass_factor = Some(1);
        let mut without = AcceleratorConfig::paper_default();
        without.cache_bypass_factor = None;
        assert_ne!(base_key(&with), base_key(&without));
    }

    #[test]
    fn keys_never_contain_newlines() {
        // The on-disk store is line-oriented: one record per line, the
        // key as the final field. Canonical keys must therefore stay on
        // one line for every representable input.
        let mut cfg = AcceleratorConfig::paper_default();
        cfg.levels = parse_levels("sram:256KiB:8banks,local:4KiB:db").unwrap();
        let k = eval_key(
            &cfg,
            &tech("e-sram"),
            "spttm",
            EngineKind::Event,
            SampleSpec::new(0.25, 7).unwrap(),
            "grid#dims[64, 64, 64]#nnz3000#seed7#remaptrue#fpdeadbeefdeadbeef",
        );
        assert!(!k.contains('\n'));
        assert!(!k.contains('\r'));
    }
}
