//! Frontier JSON export — the machine-readable counterpart of
//! [`crate::explore::search::frontier_table`], written by
//! `photon-mttkrp explore --json FILE` and uploaded as a CI artifact by
//! the `explore-smoke` workflow step.
//!
//! Shape (stable — downstream tooling accumulates against it):
//!
//! ```json
//! {
//!   "objective": "edp",
//!   "tensor": "nell-2@1e-4",
//!   "nnz": 7690,
//!   "candidates_screened": 12,
//!   "invalid": 0,
//!   "filtered": 0,
//!   "sample": {"rate": 2.5e-1, "seed": 0},
//!   "cache": {"hits": 0, "misses": 12, "loaded": 0, "appended": 0},
//!   "timing": {"screen_s": 1.9e-2, "pareto_s": 3e-6, "sampled_s": 1.1e-1,
//!              "exact_s": 2e-2, "total_s": 1.5e-1, "functional_walks": 1},
//!   "frontier": [
//!     { "rank": 0, "configuration": "n_pes=4,cache_lines=4096",
//!       "tech": "o-sram", "kernel": "spmttkrp",
//!       "analytic": {"runtime_s": 1e-3, "energy_j": 2e-3,
//!                    "edp": 2e-6, "area_mm2": 9.6e4},
//!       "event": {"runtime_s": 1.1e-3, "energy_j": 2.1e-3,
//!                 "edp": 2.3e-6, "area_mm2": 9.6e4},
//!       "event_sampled": {"runtime_s": 1.1e-3, "energy_j": 2.1e-3,
//!                         "edp": 2.3e-6, "area_mm2": 9.6e4},
//!       "event_rank": 0, "sampled_rank": 0, "event_dominated": false }
//!   ],
//!   "deltas": [
//!     { "configuration": "...", "tech": "...", "kernel": "...",
//!       "analytic_rank": 0, "event_rank": 1, "sampled_rank": 1,
//!       "event_dominated": false,
//!       "analytic_value": 1e-6, "event_value": 1.4e-6,
//!       "sampled_value": 1.4e-6 }
//!   ]
//! }
//! ```
//!
//! The `event` objects are always from the exact (rate 1.0) phase-4
//! pass, so two runs of the same grid at different `--sample-rate`
//! settings agree on every `frontier[*].{rank, configuration, tech,
//! kernel, analytic, event, event_rank}` field — the invariant the
//! `explore-smoke` CI step asserts.
//!
//! The `"timing"` object is deliberately emitted on **one** line: it
//! carries the only run-to-run-volatile values in the artifact (host
//! wall time per search phase, plus the mode-dependent
//! `functional_walks` counter), so `grep -v '"timing"'` yields a
//! byte-stable document — which is how the `explore-smoke` CI step
//! asserts the profiled and direct screens publish identical frontiers.
//!
//! Hand-rolled writer (the build is offline, no serde): numbers via
//! `{:e}` so round-tripping loses nothing, strings escaped through
//! [`json_escape`].

use std::io;
use std::path::Path;

use crate::explore::search::ExploreResult;
use crate::report::export::objectives_json;
use crate::util::bench::json_escape;

/// Serialize the search result (see the module docs for the shape).
pub fn frontier_json(result: &ExploreResult) -> String {
    let mut out = format!(
        "{{\n  \"objective\": \"{}\",\n  \"tensor\": \"{}\",\n  \"nnz\": {},\n  \
         \"candidates_screened\": {},\n  \"invalid\": {},\n  \"filtered\": {},\n  \
         \"sample\": {{\"rate\": {:e}, \"seed\": {}}},\n  \
         \"cache\": {{\"hits\": {}, \"misses\": {}, \"loaded\": {}, \"appended\": {}}},\n  \
         \"timing\": {{\"screen_s\": {:e}, \"pareto_s\": {:e}, \"sampled_s\": {:e}, \
         \"exact_s\": {:e}, \"total_s\": {:e}, \"functional_walks\": {}}},\n  \
         \"frontier\": [",
        json_escape(result.objective.name()),
        json_escape(&result.tensor),
        result.nnz,
        result.candidates.len(),
        result.n_invalid,
        result.n_filtered,
        result.sample.rate,
        result.sample.seed,
        result.cache_hits,
        result.cache_misses,
        result.cache_loaded,
        result.cache_appended,
        result.timing.screen_s,
        result.timing.pareto_s,
        result.timing.sampled_s,
        result.timing.exact_s,
        result.timing.total_s(),
        result.functional_walks,
    );
    for (i, p) in result.frontier.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rank\": {}, \"configuration\": \"{}\", \"tech\": \"{}\", \
             \"kernel\": \"{}\", \"analytic\": {}, \"event\": {}, \
             \"event_sampled\": {}, \"event_rank\": {}, \"sampled_rank\": {}, \
             \"event_dominated\": {}}}",
            p.analytic_rank,
            json_escape(&p.candidate.label()),
            json_escape(&p.candidate.tech.name),
            p.candidate.kernel.name(),
            objectives_json(&p.analytic),
            objectives_json(&p.event),
            objectives_json(&p.event_sampled),
            p.event_rank,
            p.sampled_rank,
            p.event_dominated,
        ));
    }
    out.push_str("\n  ],\n  \"deltas\": [");
    for (i, d) in result.deltas.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"configuration\": \"{}\", \"tech\": \"{}\", \"kernel\": \"{}\", \
             \"analytic_rank\": {}, \"event_rank\": {}, \"sampled_rank\": {}, \
             \"event_dominated\": {}, \
             \"analytic_value\": {:e}, \"event_value\": {:e}, \"sampled_value\": {:e}}}",
            json_escape(&d.label),
            json_escape(&d.tech),
            json_escape(&d.kernel),
            d.analytic_rank,
            d.event_rank,
            d.sampled_rank,
            d.event_dominated,
            d.analytic_value,
            d.event_value,
            d.sampled_value,
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Write [`frontier_json`] to `path`, creating parent directories as
/// needed.
pub fn write_frontier_json(result: &ExploreResult, path: &Path) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, frontier_json(result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::search::{run_explore, ExploreSpec};
    use crate::explore::space::{Axis, DesignSpace};
    use crate::kernel::KernelKind;
    use crate::mem::registry::tech;
    use crate::tensor::gen::TensorSpec;

    fn result() -> ExploreResult {
        let mut space = DesignSpace::paper_grid(
            vec![tech("e-sram"), tech("o-sram")],
            vec![KernelKind::Spmttkrp],
        );
        space.axes = vec![Axis::parse("n_pes=2,4").unwrap()];
        let spec = ExploreSpec::new(space, TensorSpec::custom("j", vec![40, 40, 40], 2_000, 0.9));
        run_explore(&spec).unwrap()
    }

    #[test]
    fn json_has_the_documented_shape() {
        let r = result();
        let json = frontier_json(&r);
        assert!(json.starts_with("{\n  \"objective\": \"edp\""), "{json}");
        assert!(json.contains("\"candidates_screened\": 4"), "{json}");
        assert!(json.contains("\"frontier\": ["), "{json}");
        assert!(json.contains("\"deltas\": ["), "{json}");
        assert!(json.contains("\"analytic\": {\"runtime_s\": "), "{json}");
        assert!(json.contains("\"event_dominated\": "), "{json}");
        // the sampling spec and the per-member sampled view are exported
        assert!(json.contains("\"sample\": {\"rate\": "), "{json}");
        // cache effectiveness counters (cold in-memory run: no hits,
        // one miss per evaluation, nothing loaded or persisted)
        assert!(json.contains(&format!(
            "\"cache\": {{\"hits\": {}, \"misses\": {}, \"loaded\": 0, \"appended\": 0}}",
            r.cache_hits, r.cache_misses
        )), "{json}");
        assert!(json.contains("\"event_sampled\": {\"runtime_s\": "), "{json}");
        assert!(json.contains("\"sampled_rank\": "), "{json}");
        // one frontier object per member, ranks in output order
        assert_eq!(json.matches("{\"rank\"").count(), r.frontier.len());
        assert!(json.contains("\"rank\": 0"), "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
    }

    #[test]
    fn timing_is_one_strippable_line_and_the_rest_is_stable() {
        // every volatile value (wall times, walk counter) lives on the
        // single "timing" line, so stripping it must leave a document
        // that is byte-identical across profiled and direct runs
        let r = result();
        let json = frontier_json(&r);
        let timing_lines: Vec<&str> =
            json.lines().filter(|l| l.contains("\"timing\"")).collect();
        assert_eq!(timing_lines.len(), 1, "{json}");
        let line = timing_lines[0];
        for field in
            ["screen_s", "pareto_s", "sampled_s", "exact_s", "total_s", "functional_walks"]
        {
            assert!(line.contains(&format!("\"{field}\": ")), "{line}");
        }
        assert!(line.contains(&format!("\"functional_walks\": {}", r.functional_walks)));
        // stripped documents from a profiled and a direct run agree
        let direct = {
            let mut space = DesignSpace::paper_grid(
                vec![tech("e-sram"), tech("o-sram")],
                vec![KernelKind::Spmttkrp],
            );
            space.axes = vec![Axis::parse("n_pes=2,4").unwrap()];
            let mut spec =
                ExploreSpec::new(space, TensorSpec::custom("j", vec![40, 40, 40], 2_000, 0.9));
            spec.profile = false;
            run_explore(&spec).unwrap()
        };
        let strip = |s: &str| {
            s.lines().filter(|l| !l.contains("\"timing\"")).collect::<Vec<_>>().join("\n")
        };
        assert_eq!(strip(&json), strip(&frontier_json(&direct)));
    }

    #[test]
    fn writer_creates_parent_directories() {
        let r = result();
        let root = std::env::temp_dir()
            .join(format!("photon_explore_json_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let path = root.join("deep/frontier.json");
        write_frontier_json(&r, &path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, frontier_json(&r));
        let _ = std::fs::remove_dir_all(&root);
    }
}
