//! The multi-objective vector every candidate evaluation produces, and
//! the objective selector the frontier is ranked by.

/// The objective vector of one evaluated candidate.
///
/// `runtime_s` and `energy_j` come from a full all-modes simulation on
/// one engine (Eq. 2–3 pricing); `area_mm2` is the instantiated-design
/// area ([`crate::area::model::AreaModel::design`]) and is
/// engine-independent. EDP is derived, not stored, so the vector can
/// never carry an inconsistent product.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Full-run (all output modes, serial) runtime in seconds.
    pub runtime_s: f64,
    /// Full-run Eq. 2–3 energy in joules.
    pub energy_j: f64,
    /// Instantiated-design area (on-chip bits in the candidate's
    /// technology + the PE array scaled to its PE count).
    pub area_mm2: f64,
}

impl Objectives {
    /// Energy-delay product (J·s) — the paper community's single-number
    /// quality metric; [`crate::sim::sweep::SweepPoint::edp`] is the same
    /// accessor on sweep points, so sweep and explore rank identically.
    pub fn edp(&self) -> f64 {
        self.energy_j * self.runtime_s
    }

    /// The scalar this vector scores under `objective` (the ranking
    /// accessor; lower is always better).
    pub fn value(&self, objective: ObjectiveKind) -> f64 {
        match objective {
            ObjectiveKind::Runtime => self.runtime_s,
            ObjectiveKind::Energy => self.energy_j,
            ObjectiveKind::Edp => self.edp(),
            ObjectiveKind::Area => self.area_mm2,
        }
    }
}

/// Ranking objective selector (`--objective` on the CLI). The Pareto
/// frontier itself is always extracted over the full
/// (runtime, energy, area) vector — the objective only chooses how the
/// frontier is *ordered* (and which scalar the two-phase rank-flip check
/// compares across engines).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ObjectiveKind {
    /// Full-run runtime, seconds.
    Runtime,
    /// Full-run Eq. 2–3 energy, joules.
    Energy,
    /// Energy-delay product — the default.
    #[default]
    Edp,
    /// Instantiated-design area, mm².
    Area,
}

impl ObjectiveKind {
    /// Every objective, in CLI listing order.
    pub const ALL: [ObjectiveKind; 4] = [
        ObjectiveKind::Runtime,
        ObjectiveKind::Energy,
        ObjectiveKind::Edp,
        ObjectiveKind::Area,
    ];

    /// The stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            ObjectiveKind::Runtime => "runtime",
            ObjectiveKind::Energy => "energy",
            ObjectiveKind::Edp => "edp",
            ObjectiveKind::Area => "area",
        }
    }

    /// Unit string for report columns.
    pub fn unit(self) -> &'static str {
        match self {
            ObjectiveKind::Runtime => "s",
            ObjectiveKind::Energy => "J",
            ObjectiveKind::Edp => "J*s",
            ObjectiveKind::Area => "mm^2",
        }
    }

    /// Parse a CLI spelling; the error lists the valid options (the
    /// `--kernel` / `--tech` error style).
    pub fn parse(s: &str) -> Result<Self, String> {
        Self::ALL.into_iter().find(|o| o.name() == s).ok_or_else(|| {
            let names: Vec<&str> = Self::ALL.iter().map(|o| o.name()).collect();
            format!("unknown objective `{s}` (expected one of: {})", names.join(", "))
        })
    }
}

impl std::str::FromStr for ObjectiveKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s)
    }
}

impl std::fmt::Display for ObjectiveKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_kinds_parse_and_display() {
        for o in ObjectiveKind::ALL {
            assert_eq!(ObjectiveKind::parse(o.name()), Ok(o));
            assert_eq!(o.to_string(), o.name());
            assert!(!o.unit().is_empty());
        }
        let err = ObjectiveKind::parse("speed").unwrap_err();
        for name in ["runtime", "energy", "edp", "area"] {
            assert!(err.contains(name), "{err}");
        }
        assert_eq!(ObjectiveKind::default(), ObjectiveKind::Edp);
    }

    #[test]
    fn edp_is_the_product_and_value_dispatches() {
        let o = Objectives { runtime_s: 2.0, energy_j: 3.0, area_mm2: 5.0 };
        assert_eq!(o.edp(), 6.0);
        assert_eq!(o.value(ObjectiveKind::Runtime), 2.0);
        assert_eq!(o.value(ObjectiveKind::Energy), 3.0);
        assert_eq!(o.value(ObjectiveKind::Edp), 6.0);
        assert_eq!(o.value(ObjectiveKind::Area), 5.0);
    }
}
