//! The multi-objective candidate evaluator and its memoizing cache.
//!
//! One evaluation = one full all-modes simulation of the candidate's
//! kernel on the candidate's configuration × technology, priced through
//! Eq. 2–3 — exactly the driver path
//! ([`crate::coordinator::driver::compare_technologies_with_budget`]),
//! bit for bit, because it runs through the same
//! [`crate::sim::SimEngine::simulate_kernel_all_modes_with_views_budget`]
//! entry point over the same memoized [`ModeView`]s. The views are built
//! once per workload and shared by **every** candidate × engine
//! evaluation (a candidate changes the accelerator, never the tensor).
//!
//! The [`EvalCache`] memoizes objective vectors under a **content key**:
//! the canonical, versioned serialization of the configuration and the
//! resolved technology from [`crate::explore::key`] (every field by
//! name, floats as IEEE-754 bit-hex, prefixed with
//! [`CACHE_SCHEMA_VERSION`](crate::explore::key::CACHE_SCHEMA_VERSION))
//! plus the kernel, engine and
//! workload tags. Overlapping candidates across searches — the same
//! (config, tech, kernel, engine, workload) reached from different axis
//! grammars, or a re-run with a warm cache — are therefore computed
//! once. Host-execution knobs ([`SimBudget`]) are deliberately *not* part
//! of the key: threads and chunk size are bit-transparent (pinned by
//! `rust/tests/parallel_determinism.rs`), so a hit and a miss return
//! bit-identical vectors by construction (pinned by
//! `rust/tests/explore.rs`). The one exception is
//! [`SampleSpec`](crate::sim::SampleSpec): a sampled **event** replay
//! legitimately changes the stall estimate, so a non-exact sample joins
//! the key — but only for the event engine, and only when the rate is
//! below 1.0. The analytic engine never replays, and a rate-1.0 event
//! run is bit-identical to an unsampled one, so both key exactly —
//! which is what lets the explore search's final exact frontier pass
//! reuse rate-1.0 entries from a warm cache for free.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::energy::model::EnergyModel;
use crate::explore::objective::Objectives;
use crate::explore::space::Candidate;
use crate::explore::store::EvalStore;
use crate::obs::metrics::{self, Counter};
use crate::sim::profile::GeometryProfile;
use crate::sim::{EngineKind, SampleSpec, SimBudget};
use crate::tensor::coo::SparseTensor;
use crate::tensor::csf::ModeView;

/// Memoized objective vectors, shareable across searches (and across the
/// worker threads of one search). Interior-mutable so a `&EvalCache` can
/// be handed to every evaluation job. Optionally backed by an on-disk
/// [`EvalStore`]: entries load at open and every miss is appended, so
/// the cache survives the process (see [`crate::explore::store`]).
///
/// Alongside the objective map the cache holds the **functional memo**:
/// [`GeometryProfile`]s keyed by [`crate::explore::key::functional_key`]
/// — the geometry tier of the two-tier key scheme. The memo is
/// in-memory only (profiles re-derive in one stream walk, so persisting
/// them buys little), but because the serve daemon owns one `EvalCache`
/// across batch windows, profiles are shared across windows
/// automatically, exactly like warm objective entries.
#[derive(Default)]
pub struct EvalCache {
    map: Mutex<HashMap<String, Objectives>>,
    hits: AtomicU64,
    misses: AtomicU64,
    store: Option<EvalStore>,
    /// The functional memo: geometry-tier key → profiled stream walk.
    profiles: Mutex<HashMap<String, Arc<GeometryProfile>>>,
    /// Full-workload functional stream walks performed to fill the memo
    /// (see [`Self::functional_walks`]).
    walks: AtomicU64,
    /// Process-registry mirrors of the counters above.
    obs: ObsCounters,
}

/// [`crate::obs::metrics`] handles the cache mirrors its traffic onto:
/// every hit/miss/append/walk lands on both the cache's own atomics
/// (the exact per-instance stats each search reports) and these shared
/// named counters (what the serve `metrics` verb and the Prometheus
/// exposition aggregate process-wide).
struct ObsCounters {
    hits: Counter,
    misses: Counter,
    loaded: Counter,
    appended: Counter,
    walks: Counter,
    geometries: Counter,
}

impl Default for ObsCounters {
    fn default() -> Self {
        let m = metrics::global();
        ObsCounters {
            hits: m.counter("eval_cache_hits_total"),
            misses: m.counter("eval_cache_misses_total"),
            loaded: m.counter("eval_cache_loaded_total"),
            appended: m.counter("eval_cache_appended_total"),
            walks: m.counter("functional_walks_total"),
            geometries: m.counter("profiled_geometries_total"),
        }
    }
}

impl EvalCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open (or create) the persistent store under `dir`, replay every
    /// valid record into memory, and return a cache that appends each
    /// future miss back to disk. Later duplicates win during replay —
    /// harmless, because duplicate keys hold bit-identical vectors by
    /// the cache contract.
    pub fn with_store(dir: &Path) -> std::io::Result<EvalCache> {
        let (store, entries) = EvalStore::open(dir)?;
        let cache = EvalCache {
            map: Mutex::new(entries.into_iter().collect()),
            store: Some(store),
            ..Default::default()
        };
        cache.obs.loaded.add(cache.loaded());
        Ok(cache)
    }

    /// Distinct evaluations currently memoized.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to compute.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Records loaded from disk at open (0 for an in-memory cache).
    pub fn loaded(&self) -> u64 {
        self.store.as_ref().map_or(0, |s| s.loaded())
    }

    /// Records persisted to disk so far (0 for an in-memory cache).
    pub fn appended(&self) -> u64 {
        self.store.as_ref().map_or(0, |s| s.appended())
    }

    /// The backing log file, when this cache is persistent.
    pub fn store_path(&self) -> Option<&Path> {
        self.store.as_ref().map(|s| s.path())
    }

    /// Membership probe that never touches the hit/miss counters — used
    /// by the serving layer to plan a batch without distorting stats.
    pub fn peek(&self, key: &str) -> Option<Objectives> {
        self.map.lock().unwrap().get(key).copied()
    }

    /// The memoized functional profile for a geometry-tier key
    /// ([`crate::explore::key::functional_key`]), if one was profiled.
    pub fn functional_profile(&self, key: &str) -> Option<Arc<GeometryProfile>> {
        self.profiles.lock().unwrap().get(key).cloned()
    }

    /// Memoize freshly profiled geometries. First insert wins on a key
    /// race — harmless, profiles of the same key are bit-identical by
    /// the profiler's contract.
    pub fn store_profiles(&self, entries: impl IntoIterator<Item = (String, GeometryProfile)>) {
        let mut map = self.profiles.lock().unwrap();
        let mut fresh = 0u64;
        for (key, profile) in entries {
            if let std::collections::hash_map::Entry::Vacant(slot) = map.entry(key) {
                slot.insert(Arc::new(profile));
                fresh += 1;
            }
        }
        self.obs.geometries.add(fresh);
    }

    /// Record `n` full-workload functional stream walks.
    ///
    /// **Unit:** one walk = one complete traversal of a workload's
    /// access streams (every mode of one kernel) — the same work one
    /// direct candidate evaluation performs. One
    /// [`crate::sim::profile::profile_geometries`] call is one walk no
    /// matter how many geometries it answers; that is what the explore
    /// screen's walks-vs-grid-points ratio measures.
    pub fn add_walks(&self, n: u64) {
        self.walks.fetch_add(n, Ordering::Relaxed);
        self.obs.walks.add(n);
    }

    /// Full-workload functional stream walks performed so far (see
    /// [`Self::add_walks`] for the unit).
    pub fn functional_walks(&self) -> u64 {
        self.walks.load(Ordering::Relaxed)
    }

    /// Distinct geometry profiles currently memoized.
    pub fn profiled_geometries(&self) -> usize {
        self.profiles.lock().unwrap().len()
    }

    /// Return the memoized vector for `key`, or compute, memoize and
    /// return it. The lock is **not** held across `compute` (a simulation
    /// may take milliseconds), so two workers racing on the same fresh
    /// key may both compute it — the results are bit-identical (that is
    /// the cache's correctness contract), the counters are merely
    /// approximate under such races, and last-insert wins harmlessly.
    pub fn get_or_compute(&self, key: &str, compute: impl FnOnce() -> Objectives) -> Objectives {
        self.get_or_compute_traced(key, compute).0
    }

    /// [`get_or_compute`](Self::get_or_compute), also reporting whether
    /// the lookup was a hit. A miss on a persistent cache is appended
    /// (fsync'd) to the store; a disk error degrades to in-memory-only
    /// with a warning — it must never fail the evaluation itself.
    pub fn get_or_compute_traced(
        &self,
        key: &str,
        compute: impl FnOnce() -> Objectives,
    ) -> (Objectives, bool) {
        if let Some(v) = self.map.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.obs.hits.inc();
            return (*v, true);
        }
        let v = compute();
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.obs.misses.inc();
        self.map.lock().unwrap().insert(key.to_string(), v);
        if let Some(store) = &self.store {
            match store.append(key, &v) {
                Ok(()) => self.obs.appended.inc(),
                Err(e) => crate::obs::log::warn(
                    "explore",
                    "failed to persist cache entry",
                    &[
                        ("path", store.path().display().to_string()),
                        ("err", e.to_string()),
                    ],
                ),
            }
        }
        (v, false)
    }
}

/// The content key of one (candidate, engine, workload, sample)
/// evaluation: the canonical serialization from
/// [`crate::explore::key::eval_key`]. The sample joins the key only
/// when it can change the result: event engine at a rate below 1.0
/// (see the module docs).
pub fn candidate_key(
    cand: &Candidate,
    engine: EngineKind,
    workload_tag: &str,
    sample: SampleSpec,
) -> String {
    crate::explore::key::eval_key(
        &cand.cfg,
        &cand.tech,
        cand.kernel.name(),
        engine,
        sample,
        workload_tag,
    )
}

/// One prepared workload the whole search evaluates against: the
/// (already remapped) tensor, its memoized per-mode views, and the
/// identity tag that scopes cache keys to this workload.
pub struct Evaluator<'a> {
    /// The remapped tensor (see
    /// [`crate::coordinator::driver::apply_memory_mapping`]).
    pub tensor: &'a SparseTensor,
    /// `(mode, view)` for every output mode, built once and shared by
    /// every candidate × engine evaluation.
    pub views: &'a [(usize, ModeView)],
    /// Workload identity for cache keys: tensor name (which embeds the
    /// scale), nnz, generator seed and remap switch.
    pub workload_tag: String,
    /// Host-execution budget. Threads and chunk size are bit-transparent
    /// and excluded from keys; a non-exact `budget.sample` joins event
    /// keys (see [`candidate_key`]).
    pub budget: SimBudget,
}

impl Evaluator<'_> {
    /// Build the workload tag for cache keys: name, dims, nnz, seed and
    /// remap switch plus an FNV-1a fingerprint of the coordinate and
    /// value streams — so two workloads that merely *look* alike (same
    /// name/nnz/seed from a different shape or locality profile) can
    /// never alias in a shared cache. O(nnz) once per search, amortized
    /// over every candidate × engine evaluation.
    pub fn tag(tensor: &SparseTensor, seed: u64, remap: bool) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x1_0000_0001_b3);
        };
        for col in &tensor.indices {
            for &i in col {
                mix(i as u64);
            }
        }
        for &v in &tensor.values {
            mix(v.to_bits() as u64);
        }
        format!(
            "{}#dims{:?}#nnz{}#seed{seed}#remap{remap}#fp{h:016x}",
            tensor.name,
            tensor.dims,
            tensor.nnz()
        )
    }

    /// The geometry-tier key of `cand` against this workload: what the
    /// functional memo is keyed by (no technology, no pricing knob).
    pub fn functional_key_for(&self, cand: &Candidate) -> String {
        crate::explore::key::functional_key(&cand.cfg, cand.kernel.name(), &self.workload_tag)
    }

    /// Price `cand` from an already-profiled functional walk: the
    /// timing/energy pass alone, bit-identical to what
    /// [`evaluate`](Self::evaluate) computes on the analytic engine
    /// (pinned by the tests below). `profile` must come from a
    /// [`crate::sim::profile::profile_geometries`] walk over this
    /// evaluator's views with a config sharing `cand`'s geometry tier.
    pub fn price_candidate(&self, cand: &Candidate, profile: &GeometryProfile) -> Objectives {
        let report = crate::sim::profile::price_report(
            cand.kernel.kernel(),
            self.tensor,
            self.views,
            &cand.cfg,
            &cand.tech,
            profile,
        );
        let energy = EnergyModel::new(&cand.cfg).run_energy(&report);
        Objectives {
            runtime_s: report.total_runtime_s(),
            energy_j: energy.total_j(),
            area_mm2: cand.area_mm2,
        }
    }

    /// Evaluate `cand` on `engine`, through `cache`.
    pub fn evaluate(&self, cand: &Candidate, engine: EngineKind, cache: &EvalCache) -> Objectives {
        self.evaluate_traced(cand, engine, cache).0
    }

    /// [`evaluate`](Self::evaluate), also reporting whether the cache
    /// answered (`true` = hit, neither engine ran).
    pub fn evaluate_traced(
        &self,
        cand: &Candidate,
        engine: EngineKind,
        cache: &EvalCache,
    ) -> (Objectives, bool) {
        let key = candidate_key(cand, engine, &self.workload_tag, self.budget.sample);
        cache.get_or_compute_traced(&key, || self.compute(cand, engine))
    }

    /// One uncached evaluation of `cand` on `engine` — the cache-miss
    /// path of [`evaluate`](Self::evaluate): a full stream walk through
    /// the driver entry point, priced through Eq. 2–3.
    pub fn compute(&self, cand: &Candidate, engine: EngineKind) -> Objectives {
        let report = engine.simulate_kernel_all_modes_with_views_budget(
            cand.kernel.kernel(),
            self.tensor,
            self.views,
            &cand.cfg,
            &cand.tech,
            self.budget,
        );
        let energy = EnergyModel::new(&cand.cfg).run_energy(&report);
        Objectives {
            runtime_s: report.total_runtime_s(),
            energy_j: energy.total_j(),
            area_mm2: cand.area_mm2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::AcceleratorConfig;
    use crate::coordinator::driver::apply_memory_mapping;
    use crate::kernel::KernelKind;
    use crate::mem::registry::tech;
    use crate::tensor::gen::TensorSpec;

    fn candidate(tech_name: &str) -> Candidate {
        let cfg = AcceleratorConfig::paper_default();
        Candidate {
            index: 0,
            settings: Vec::new(),
            cfg: cfg.clone(),
            tech: tech(tech_name),
            kernel: KernelKind::Spmttkrp,
            area_mm2: crate::area::model::AreaModel::new(&cfg)
                .design(&tech(tech_name))
                .total_mm2(),
        }
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let cache = EvalCache::new();
        assert!(cache.is_empty());
        let o = Objectives { runtime_s: 1.0, energy_j: 2.0, area_mm2: 3.0 };
        let a = cache.get_or_compute("k", || o);
        let b = cache.get_or_compute("k", || panic!("must be a hit"));
        assert_eq!(a, b);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn persistent_cache_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("photon_evalcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let o = Objectives { runtime_s: 0.125, energy_j: 7.0, area_mm2: 1.5 };
        {
            let cache = EvalCache::with_store(&dir).unwrap();
            assert_eq!((cache.loaded(), cache.appended()), (0, 0));
            let _ = cache.get_or_compute("pk", || o);
            assert_eq!(cache.appended(), 1);
        }
        // a fresh process sees the entry: hit, bit-identical, no compute
        let cache = EvalCache::with_store(&dir).unwrap();
        assert_eq!(cache.loaded(), 1);
        let (got, hit) = cache.get_or_compute_traced("pk", || panic!("must come from disk"));
        assert!(hit);
        assert_eq!(got.runtime_s.to_bits(), o.runtime_s.to_bits());
        assert_eq!(got.energy_j.to_bits(), o.energy_j.to_bits());
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_separate_every_axis_of_identity() {
        let base = candidate("o-sram");
        let tag = "t#nnz10#seed1#remaptrue";
        let exact = SampleSpec::exact();
        let k0 = candidate_key(&base, EngineKind::Analytic, tag, exact);
        // engine
        assert_ne!(k0, candidate_key(&base, EngineKind::Event, tag, exact));
        // workload
        assert_ne!(
            k0,
            candidate_key(&base, EngineKind::Analytic, "t#nnz11#seed1#remaptrue", exact)
        );
        // technology
        assert_ne!(k0, candidate_key(&candidate("e-sram"), EngineKind::Analytic, tag, exact));
        // kernel
        let mut k = base.clone();
        k.kernel = KernelKind::Spttm;
        assert_ne!(k0, candidate_key(&k, EngineKind::Analytic, tag, exact));
        // any config field — including ones no Knob names (the
        // canonical serialization keys every field by name)
        let mut c = base.clone();
        c.cfg.compute_power_w += 0.1;
        assert_ne!(k0, candidate_key(&c, EngineKind::Analytic, tag, exact));
        let mut c = base.clone();
        c.cfg.n_pipelines = 40;
        assert_ne!(k0, candidate_key(&c, EngineKind::Analytic, tag, exact));
    }

    #[test]
    fn sample_keys_only_the_inexact_event_replay() {
        let base = candidate("o-sram");
        let tag = "t#nnz10#seed1#remaptrue";
        let exact = SampleSpec::exact();
        let quarter = SampleSpec::new(0.25, 7).unwrap();
        // a sampled event replay is a distinct evaluation...
        let ev_exact = candidate_key(&base, EngineKind::Event, tag, exact);
        let ev_quarter = candidate_key(&base, EngineKind::Event, tag, quarter);
        assert_ne!(ev_exact, ev_quarter);
        // ...and both the rate and the seed are part of its identity
        assert_ne!(
            ev_quarter,
            candidate_key(&base, EngineKind::Event, tag, SampleSpec::new(0.25, 8).unwrap())
        );
        assert_ne!(
            ev_quarter,
            candidate_key(&base, EngineKind::Event, tag, SampleSpec::new(0.5, 7).unwrap())
        );
        // rate 1.0 is bit-identical to unsampled, so it keys exactly —
        // regardless of seed — and the analytic engine never replays, so
        // its key ignores the sample entirely
        assert_eq!(
            ev_exact,
            candidate_key(&base, EngineKind::Event, tag, SampleSpec { rate: 1.0, seed: 99 })
        );
        assert_eq!(
            candidate_key(&base, EngineKind::Analytic, tag, exact),
            candidate_key(&base, EngineKind::Analytic, tag, quarter)
        );
    }

    #[test]
    fn workload_tags_never_alias_lookalike_tensors() {
        // same name, nnz and seed — different shape or locality profile
        // must still produce distinct tags (the shared-cache contract)
        let a = TensorSpec::custom("grid", vec![64, 64, 64], 3_000, 0.9).generate(7);
        let b = TensorSpec::custom("grid", vec![256, 256, 256], 3_000, 0.9).generate(7);
        let c = TensorSpec::custom("grid", vec![64, 64, 64], 3_000, 0.2).generate(7);
        let ta = Evaluator::tag(&a, 7, true);
        assert_ne!(ta, Evaluator::tag(&b, 7, true), "dims must be part of the tag");
        assert_ne!(ta, Evaluator::tag(&c, 7, true), "content must be part of the tag");
        assert_ne!(ta, Evaluator::tag(&a, 8, true));
        assert_ne!(ta, Evaluator::tag(&a, 7, false));
        // deterministic: the same workload always tags identically
        assert_eq!(ta, Evaluator::tag(&a, 7, true));
    }

    #[test]
    fn functional_memo_stores_and_counts_walks() {
        let cache = EvalCache::new();
        assert_eq!((cache.functional_walks(), cache.profiled_geometries()), (0, 0));
        assert!(cache.functional_profile("fk").is_none());
        cache.store_profiles([("fk".to_string(), GeometryProfile::default())]);
        cache.add_walks(1);
        assert_eq!((cache.functional_walks(), cache.profiled_geometries()), (1, 1));
        let first = cache.functional_profile("fk").unwrap();
        // first insert wins on a duplicate key: same Arc comes back
        cache.store_profiles([(
            "fk".to_string(),
            GeometryProfile { modes: vec![Vec::new()] },
        )]);
        assert!(Arc::ptr_eq(&first, &cache.functional_profile("fk").unwrap()));
    }

    #[test]
    fn profiled_pricing_matches_direct_evaluation_bit_for_bit() {
        let tensor = TensorSpec::custom("pp", vec![48, 48, 48], 3_000, 0.7).generate(11);
        let mapped = apply_memory_mapping(&tensor);
        let views: Vec<(usize, ModeView)> =
            (0..mapped.n_modes()).map(|m| (m, ModeView::build(&mapped, m))).collect();
        let ev = Evaluator {
            tensor: &mapped,
            views: &views,
            workload_tag: Evaluator::tag(&mapped, 11, true),
            budget: SimBudget::single_threaded(),
        };
        for tech_name in ["o-sram", "e-sram"] {
            let cand = candidate(tech_name);
            let profile = crate::sim::profile::profile_geometries(
                cand.kernel.kernel(),
                &mapped,
                &views,
                &[&cand.cfg],
                4096,
            )
            .pop()
            .unwrap();
            let priced = ev.price_candidate(&cand, &profile);
            let direct = ev.evaluate(&cand, EngineKind::Analytic, &EvalCache::new());
            assert_eq!(priced.runtime_s.to_bits(), direct.runtime_s.to_bits(), "{tech_name}");
            assert_eq!(priced.energy_j.to_bits(), direct.energy_j.to_bits(), "{tech_name}");
            assert_eq!(priced.area_mm2.to_bits(), direct.area_mm2.to_bits(), "{tech_name}");
        }
    }

    #[test]
    fn evaluation_runs_the_driver_path_over_shared_views() {
        let tensor = TensorSpec::custom("ev", vec![60, 60, 60], 4_000, 0.8).generate(3);
        let mapped = apply_memory_mapping(&tensor);
        let views: Vec<(usize, ModeView)> =
            (0..mapped.n_modes()).map(|m| (m, ModeView::build(&mapped, m))).collect();
        let ev = Evaluator {
            tensor: &mapped,
            views: &views,
            workload_tag: Evaluator::tag(&mapped, 3, true),
            budget: SimBudget::single_threaded(),
        };
        let cand = candidate("o-sram");
        let cache = EvalCache::new();
        let got = ev.evaluate(&cand, EngineKind::Analytic, &cache);
        // the classic driver path must agree bit for bit
        let c = crate::coordinator::driver::compare_technologies_with_budget(
            &tensor,
            &cand.cfg,
            &[tech("o-sram")],
            EngineKind::Analytic,
            KernelKind::Spmttkrp,
            SimBudget::single_threaded(),
        );
        let run = c.baseline();
        assert_eq!(got.runtime_s.to_bits(), run.report.total_runtime_s().to_bits());
        assert_eq!(got.energy_j.to_bits(), run.energy.total_j().to_bits());
        assert_eq!(got.area_mm2, cand.area_mm2);
        // second evaluation is a hit and bit-identical
        let again = ev.evaluate(&cand, EngineKind::Analytic, &cache);
        assert_eq!(got.runtime_s.to_bits(), again.runtime_s.to_bits());
        assert_eq!(cache.hits(), 1);
        // the event evaluation keys separately and can only be slower
        let event = ev.evaluate(&cand, EngineKind::Event, &cache);
        assert_eq!(cache.len(), 2);
        assert!(event.runtime_s >= got.runtime_s);
        assert!(event.energy_j >= got.energy_j);
        assert_eq!(event.area_mm2, got.area_mm2);
    }
}
