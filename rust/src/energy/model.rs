//! Equation 2–3: total accelerator energy for a simulated run.
//!
//! ```text
//! E_FPGA = P_compute × t_runtime
//!        + E_DRAM-FPGA
//!        + (P_O-SRAM × n_O-SRAM) × t_runtime               (Eq. 2)
//!
//! P_SRAM          = P_static + P_switching                 (Eq. 3)
//! P_static        = S_total  × (p̂_static_opt + p̂_static_elec)
//! P_switching     = S_active × (p̂_conversion + p̂_storage)
//! ```
//!
//! The simulator reports *activity* (active words per component, DRAM
//! traffic, runtime); this module turns activity into joules using the
//! Table III per-bit constants carried by the [`MemTechnology`]. The
//! `(P × n_blocks) × t` product of Eq. 2 is evaluated as
//! `S_total × p̂_static × cycles` for the static part (identical algebra,
//! but exact for partially-filled blocks) plus `S_active × p̂_switching`
//! for the switching part (which is time-independent, as Eq. 3's
//! "active bits in a given clock cycle" integrates to total bits moved).

use crate::accel::config::AcceleratorConfig;
use crate::accel::design::OnChipBudget;
use crate::sim::result::{ModeReport, SimReport};

/// Energy breakdown of one run, in joules.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// `P_compute × t_runtime`.
    pub compute_j: f64,
    /// `E_DRAM-FPGA`: external-memory interface + array energy.
    pub dram_j: f64,
    /// On-chip static (leakage / bias) energy over the runtime.
    pub static_j: f64,
    /// On-chip switching energy for all active bits moved.
    pub switching_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.dram_j + self.static_j + self.switching_j
    }
}

/// The Eq. 2 evaluator bound to one accelerator configuration.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub cfg: AcceleratorConfig,
    /// On-chip bits the design instantiates (S_total of Eq. 3).
    pub s_total_bits: u64,
}

impl EnergyModel {
    pub fn new(cfg: &AcceleratorConfig) -> Self {
        let budget = OnChipBudget::from_config(cfg);
        EnergyModel { cfg: cfg.clone(), s_total_bits: budget.total_bits() }
    }

    /// Energy of one simulated mode. The Table III constants come from
    /// the resolved technology carried by the report itself, so any
    /// registry entry — builtin, config-file or programmatic — prices
    /// identically through Eq. 2–3.
    pub fn mode_energy(&self, report: &ModeReport) -> EnergyBreakdown {
        let tech = &report.tech;
        let t_s = report.runtime_s();
        let cycles = report.runtime_cycles();

        // P_compute × t
        let compute_j = self.cfg.compute_power_w * t_s;

        // E_DRAM-FPGA: per-PE traffic through the per-PE channel
        let mut dram_pj = 0.0;
        for pe in &report.pes {
            dram_pj += self.cfg.dram.transfer_pj(pe.dram_stream_bytes, 0);
            dram_pj += self.cfg.dram.transfer_pj(pe.dram_random_bytes, pe.dram_random_accesses);
        }

        // Eq. 3 static: S_total × p̂_static × cycles
        let static_pj = tech.static_pj_per_cycle(self.s_total_bits) * cycles;

        // Eq. 3 switching: S_active × (p̂_conversion + p̂_storage)
        let active_bits = report.total_onchip_words() * 32;
        let switching_pj = tech.switching_pj(active_bits);

        EnergyBreakdown {
            compute_j: compute_j,
            dram_j: dram_pj * 1e-12,
            static_j: static_pj * 1e-12,
            switching_j: switching_pj * 1e-12,
        }
    }

    /// Energy of a full all-modes spMTTKRP run (modes execute serially).
    pub fn run_energy(&self, report: &SimReport) -> EnergyBreakdown {
        let mut acc = EnergyBreakdown::default();
        for m in &report.modes {
            let e = self.mode_energy(m);
            acc.compute_j += e.compute_j;
            acc.dram_j += e.dram_j;
            acc.static_j += e.static_j;
            acc.switching_j += e.switching_j;
        }
        acc
    }
}

/// Fig. 8's metric generalized to any technology pair:
/// `E(baseline run) / E(candidate run)` — above 1.0 the candidate saves
/// energy. With `base` on E-SRAM and `other` on O-SRAM this is exactly
/// the paper's number.
pub fn energy_ratio(model: &EnergyModel, base: &SimReport, other: &SimReport) -> f64 {
    model.run_energy(base).total_j() / model.run_energy(other).total_j()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::registry::tech;
    use crate::sim::engine::{simulate_all_modes, simulate_mode};
    use crate::tensor::gen::{self, TensorSpec};

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default().scaled(1.0 / 64.0)
    }

    #[test]
    fn breakdown_components_all_positive() {
        let t = gen::random(&[100, 100, 100], 20_000, 1);
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let r = simulate_mode(&t, 0, &cfg, &tech("e-sram"));
        let e = m.mode_energy(&r);
        assert!(e.compute_j > 0.0);
        assert!(e.dram_j > 0.0);
        assert!(e.static_j > 0.0);
        assert!(e.switching_j > 0.0);
        let parts = e.compute_j + e.dram_j + e.static_j + e.switching_j;
        assert!((e.total_j() - parts).abs() < 1e-18);
    }

    #[test]
    fn osram_saves_energy_on_hot_workload() {
        let t = TensorSpec::custom("hot", vec![48, 48, 48], 50_000, 1.0).generate(2);
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let re = simulate_all_modes(&t, &cfg, &tech("e-sram"));
        let ro = simulate_all_modes(&t, &cfg, &tech("o-sram"));
        let savings = energy_ratio(&m, &re, &ro);
        assert!(savings > 2.0, "hot-workload savings {savings}");
        assert!(savings < 20.0, "savings {savings} implausibly high");
    }

    #[test]
    fn osram_still_saves_on_cold_workload() {
        let t =
            TensorSpec::custom("cold", vec![900_000, 800_000, 900_000], 50_000, 0.05).generate(2);
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let re = simulate_all_modes(&t, &cfg, &tech("e-sram"));
        let ro = simulate_all_modes(&t, &cfg, &tech("o-sram"));
        let savings = energy_ratio(&m, &re, &ro);
        assert!(savings > 1.0, "cold savings {savings}");
    }

    #[test]
    fn switching_dominates_for_esram_hot_runs() {
        // Table III: 4.68 pJ/bit switching is the headline cost of the
        // electrical technology.
        let t = TensorSpec::custom("hot", vec![48, 48, 48], 50_000, 1.0).generate(3);
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let r = simulate_mode(&t, 0, &cfg, &tech("e-sram"));
        let e = m.mode_energy(&r);
        assert!(e.switching_j > e.dram_j);
        assert!(e.switching_j > e.static_j);
    }

    #[test]
    fn static_energy_scales_with_runtime_not_traffic() {
        let t = gen::random(&[64, 64, 64], 10_000, 5);
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let r = simulate_mode(&t, 0, &cfg, &tech("o-sram"));
        let e = m.mode_energy(&r);
        let t = tech("o-sram");
        let expect = t.static_pj_per_cycle(m.s_total_bits) * r.runtime_cycles() * 1e-12;
        assert!((e.static_j - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn energy_monotone_in_nnz() {
        let cfg = cfg();
        let m = EnergyModel::new(&cfg);
        let t1 = gen::random(&[128, 128, 128], 10_000, 9);
        let t2 = gen::random(&[128, 128, 128], 40_000, 9);
        let e1 = m.mode_energy(&simulate_mode(&t1, 0, &cfg, &tech("e-sram")));
        let e2 = m.mode_energy(&simulate_mode(&t2, 0, &cfg, &tech("e-sram")));
        assert!(e2.total_j() > e1.total_j());
    }
}
