//! Energy model (paper §III-B, Eq. 2–3, Table III).

pub mod model;
