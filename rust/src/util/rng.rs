//! Deterministic pseudo-random generation.
//!
//! The synthetic FROSTT tensor generators and the property-test harness both
//! need reproducible randomness; with no `rand` crate available we implement
//! a small, fast generator (xoshiro256**, seeded via SplitMix64) plus the
//! distributions the project needs. All generation is seed-stable across
//! platforms: given the same seed the same tensor is produced everywhere,
//! which the tests rely on.

/// SplitMix64 step — used to expand a single `u64` seed into the generator
/// state (recommended seeding procedure for xoshiro).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — public-domain algorithm by Blackman & Vigna.
///
/// Fast (4×u64 state, a handful of ops per draw), passes BigCrush, and is
/// trivially seedable; more than adequate for workload synthesis.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce it
        // from any seed, but guard anyway.
        if s.iter().all(|&x| x == 0) {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (single value; wastes the pair,
    /// simplicity over speed — the generators are not normal-heavy).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Split off an independent generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf(α) sampler over `{0, 1, .., n-1}` (rank 0 is the most popular).
///
/// Uses the rejection-inversion method of Hörmann & Derflinger, which is
/// O(1) per sample and exact for any α > 0, α ≠ 1 handled too. The tensor
/// generators use this to give each mode a controllable reuse/locality
/// profile: large α ⇒ a few hot factor-matrix rows absorb most accesses
/// (high cache hit rate), α → 0 ⇒ uniform (DRAM-bound).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    alpha: f64,
    t: f64,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n >= 1, "Zipf over empty support");
        assert!(alpha >= 0.0 && alpha.is_finite());
        let nf = n as f64;
        let t = if (alpha - 1.0).abs() < 1e-12 {
            1.0 + nf.ln()
        } else {
            (nf.powf(1.0 - alpha) - alpha) / (1.0 - alpha)
        };
        Zipf { n: nf, alpha, t }
    }

    /// `H(x) = ∫ u^-α du` helper (generalized harmonic integral).
    #[inline]
    fn h(&self, x: f64) -> f64 {
        if (self.alpha - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - self.alpha) - 1.0) / (1.0 - self.alpha)
        }
    }

    #[inline]
    fn h_inv(&self, y: f64) -> f64 {
        if (self.alpha - 1.0).abs() < 1e-12 {
            y.exp()
        } else {
            (1.0 + y * (1.0 - self.alpha)).powf(1.0 / (1.0 - self.alpha))
        }
    }

    /// Draw a sample in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        if self.alpha < 1e-9 {
            return rng.index(self.n as usize); // uniform fast path
        }
        loop {
            // Rejection-inversion over the continuous envelope.
            let u = rng.f64() * self.t;
            let x = if u <= 1.0 { 1.0 } else { self.h_inv(self.h(1.0) + u - 1.0) };
            let k = x.floor().clamp(1.0, self.n);
            // accept k with probability proportional to k^-α vs envelope
            let ratio = (k.powf(-self.alpha)) / x.powf(-self.alpha);
            if rng.f64() <= ratio {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(1234);
        let mut b = Rng::new(1234);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(99);
        let n = 10u64;
        let mut counts = [0usize; 10];
        let draws = 100_000;
        for _ in 0..draws {
            counts[r.below(n) as usize] += 1;
        }
        let expect = draws as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < expect * 0.1, "counts={counts:?}");
        }
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut r = Rng::new(3);
        for bound in [1u64, 2, 3, 7, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x]);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_rank0_most_popular_and_support_respected() {
        let mut r = Rng::new(21);
        let z = Zipf::new(1000, 1.2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[500].saturating_sub(1)); // heavy head
        // head mass: for α=1.2 over n=1000, top-10 should hold a large share
        let head: usize = counts[..10].iter().sum();
        assert!(head as f64 > 0.3 * 200_000.0, "head={head}");
    }

    #[test]
    fn zipf_alpha_zero_is_uniform() {
        let mut r = Rng::new(22);
        let z = Zipf::new(100, 0.0);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        let expect = 1000.0;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < expect * 0.25);
        }
    }

    #[test]
    fn zipf_alpha_one_exact_path() {
        let mut r = Rng::new(23);
        let z = Zipf::new(50, 1.0);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 50);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(77);
        let mut b = a.fork();
        let overlap = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(overlap, 0);
    }
}
