//! Summary statistics and histograms for simulation reports and benches.

/// Online accumulator for mean / variance (Welford) plus min/max/sum.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Geometric mean of values pushed (assumes all positive) — the paper's
    /// "average 1.68× speedup" style aggregate.
    pub fn geomean_of(xs: &[f64]) -> f64 {
        assert!(!xs.is_empty());
        let logsum: f64 = xs.iter().map(|x| x.ln()).sum();
        (logsum / xs.len() as f64).exp()
    }
}

/// Percentile of a sample using linear interpolation between order
/// statistics (numpy's default "linear" method). `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fixed-width histogram over `[lo, hi)`; out-of-range values clamp into the
/// edge bins (useful for latency tails).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * n as f64).floor() as i64).clamp(0, n as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Render as a compact ASCII sparkline-style bar chart.
    pub fn render(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        let mut out = String::new();
        let n = self.bins.len();
        for (i, &c) in self.bins.iter().enumerate() {
            let l = self.lo + (self.hi - self.lo) * i as f64 / n as f64;
            let r = self.lo + (self.hi - self.lo) * (i + 1) as f64 / n as f64;
            let bar = "#".repeat((c as f64 / max as f64 * width as f64).round() as usize);
            out.push_str(&format!("[{l:>10.3}, {r:>10.3}) {c:>8} {bar}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn summary_single_value() {
        let s = Summary::from_slice(&[42.0]);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.mean(), 42.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let g = Summary::geomean_of(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = Summary::geomean_of(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 9.99, -5.0, 15.0, 5.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.bins()[0], 3); // 0.0, 0.5, clamped -5.0
        assert_eq!(h.bins()[9], 2); // 9.99, clamped 15.0
        assert_eq!(h.bins()[5], 1);
    }
}
