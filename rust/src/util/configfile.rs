//! A TOML-subset configuration parser (offline stand-in for `toml`+`serde`).
//!
//! Supports exactly what the accelerator config files need:
//! `[section]` / `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! Values are addressed by dotted path: `cfg.get_u64("cache.num_lines")`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse / lookup error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// A flat dotted-key → value map parsed from TOML-subset text.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(hdr) = line.strip_prefix('[') {
                let hdr = hdr
                    .strip_suffix(']')
                    .ok_or_else(|| {
                        ConfigError(format!("line {}: unterminated [section]", lineno + 1))
                    })?
                    .trim();
                if hdr.is_empty() {
                    return Err(ConfigError(format!("line {}: empty section name", lineno + 1)));
                }
                section = hdr.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| ConfigError(format!("line {}: expected key = value", lineno + 1)))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError(format!("line {}: empty key", lineno + 1)));
            }
            let full =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = parse_value(val.trim())
                .map_err(|e| ConfigError(format!("line {}: {}", lineno + 1, e.0)))?;
            if values.insert(full.clone(), value).is_some() {
                return Err(ConfigError(format!("line {}: duplicate key `{full}`", lineno + 1)));
            }
        }
        Ok(Config { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str) -> Result<&str, ConfigError> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| ConfigError(format!("missing or non-string key `{key}`")))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, ConfigError> {
        let v = self
            .get(key)
            .and_then(Value::as_i64)
            .ok_or_else(|| ConfigError(format!("missing or non-integer key `{key}`")))?;
        u64::try_from(v).map_err(|_| ConfigError(format!("key `{key}` is negative")))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, ConfigError> {
        Ok(self.get_u64(key)? as usize)
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, ConfigError> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| ConfigError(format!("missing or non-numeric key `{key}`")))
    }

    pub fn get_bool(&self, key: &str) -> Result<bool, ConfigError> {
        self.get(key)
            .and_then(Value::as_bool)
            .ok_or_else(|| ConfigError(format!("missing or non-boolean key `{key}`")))
    }

    /// Typed lookups with a default when the key is absent.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Value::as_i64).map(|v| v as u64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.u64_or(key, default as u64) as usize
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, ConfigError> {
    if s.is_empty() {
        return Err(ConfigError("empty value".to_string()));
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q
            .strip_suffix('"')
            .ok_or_else(|| ConfigError("unterminated string".to_string()))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| ConfigError("unterminated array".to_string()))?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, _> =
            body.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError(format!("cannot parse value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# accelerator config
scale = 0.5
name = "osram"   # inline comment

[pe]
count = 4
pipelines = 80

[cache]
num_lines = 4_096
line_bytes = 64
enabled = true
ratios = [1.0, 2.5, 3]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_f64("scale").unwrap(), 0.5);
        assert_eq!(c.get_str("name").unwrap(), "osram");
        assert_eq!(c.get_u64("pe.count").unwrap(), 4);
        assert_eq!(c.get_usize("cache.num_lines").unwrap(), 4096);
        assert!(c.get_bool("cache.enabled").unwrap());
        match c.get("cache.ratios").unwrap() {
            Value::Array(v) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[0].as_f64(), Some(1.0));
                assert_eq!(v[2].as_i64(), Some(3));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn int_coerces_to_f64_not_reverse() {
        let c = Config::parse("x = 3\ny = 3.5").unwrap();
        assert_eq!(c.get_f64("x").unwrap(), 3.0);
        assert!(c.get_u64("y").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.get_str("s").unwrap(), "a#b");
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        let e = Config::parse("ok = 1\nbad line").unwrap_err();
        assert!(e.0.contains("line 2"), "{e}");
        let e = Config::parse("[unterminated").unwrap_err();
        assert!(e.0.contains("line 1"));
    }

    #[test]
    fn duplicate_key_rejected() {
        let e = Config::parse("a = 1\na = 2").unwrap_err();
        assert!(e.0.contains("duplicate"));
    }

    #[test]
    fn defaults_helpers() {
        let c = Config::parse("a = 1").unwrap();
        assert_eq!(c.u64_or("a", 9), 1);
        assert_eq!(c.u64_or("missing", 9), 9);
        assert_eq!(c.f64_or("missing", 1.5), 1.5);
        assert!(c.bool_or("missing", true));
    }

    #[test]
    fn negative_int_to_u64_is_error() {
        let c = Config::parse("a = -5").unwrap();
        assert!(c.get_u64("a").is_err());
        assert_eq!(c.get("a").unwrap().as_i64(), Some(-5));
    }
}
