//! Miniature property-based testing harness (offline stand-in for
//! `proptest`).
//!
//! A property is a closure over a [`Gen`]-erated input; the harness runs it
//! for `cases` random inputs and, on failure, attempts bounded shrinking via
//! the generator's [`Gen::shrink`] candidates before reporting the minimal
//! failing input (with the seed so the case is replayable).
//!
//! ```
//! use photon_mttkrp::util::prop::{check, VecGen, U64Gen};
//! // reversing twice is identity
//! check("rev_rev", 200, &VecGen::new(U64Gen::below(100), 0..=16), |v| {
//!     let mut r = v.clone();
//!     r.reverse();
//!     r.reverse();
//!     r == *v
//! });
//! ```

use crate::util::rng::Rng;

/// A random-value generator that also knows how to shrink failures.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; the harness recurses greedily on the first
    /// candidate that still fails. Returning an empty vec ends shrinking.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` on `cases` generated inputs; panics with the (shrunk) minimal
/// counterexample on failure. The base seed is derived from the name so each
/// property gets a distinct but stable stream.
pub fn check<G: Gen>(name: &str, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(gen, input, &prop);
            panic!(
                "property `{name}` failed (case {case}/{cases}, seed {seed:#x})\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Bounded: at most 1000 successful shrink steps to guarantee termination
    // even for misbehaving shrinkers.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Uniform `u64` in `[lo, hi]`; shrinks toward `lo`.
#[derive(Clone, Debug)]
pub struct U64Gen {
    pub lo: u64,
    pub hi: u64,
}

impl U64Gen {
    pub fn below(n: u64) -> Self {
        assert!(n > 0);
        U64Gen { lo: 0, hi: n - 1 }
    }
    pub fn range(lo: u64, hi: u64) -> Self {
        assert!(lo <= hi);
        U64Gen { lo, hi }
    }
}

impl Gen for U64Gen {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range_u64(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform `f64` in `[lo, hi)`; shrinks toward `lo` and toward 0/1-ish
/// round values.
#[derive(Clone, Debug)]
pub struct F64Gen {
    pub lo: f64,
    pub hi: f64,
}

impl Gen for F64Gen {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if (*v - self.lo).abs() > 1e-9 {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2.0);
        }
        out
    }
}

/// Vector of values from an element generator, with length in `len_range`;
/// shrinks by halving length, then element-wise.
pub struct VecGen<G> {
    pub elem: G,
    pub len_lo: usize,
    pub len_hi: usize,
}

impl<G> VecGen<G> {
    pub fn new(elem: G, len: std::ops::RangeInclusive<usize>) -> Self {
        VecGen { elem, len_lo: *len.start(), len_hi: *len.end() }
    }
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = if self.len_lo == self.len_hi {
            self.len_lo
        } else {
            self.len_lo + rng.index(self.len_hi - self.len_lo + 1)
        };
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.len_lo {
            // drop the back half, drop one element
            let half = self.len_lo.max(v.len() / 2);
            out.push(v[..half].to_vec());
            let mut minus1 = v.clone();
            minus1.pop();
            out.push(minus1);
        }
        // shrink the first shrinkable element
        for (i, e) in v.iter().enumerate() {
            if let Some(smaller) = self.elem.shrink(e).into_iter().next() {
                let mut w = v.clone();
                w[i] = smaller;
                out.push(w);
                break;
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Generator from a closure (no shrinking).
pub struct FnGen<F>(pub F);

impl<T: Clone + std::fmt::Debug, F: Fn(&mut Rng) -> T> Gen for FnGen<F> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        (self.0)(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_comm", 200, &PairGen(U64Gen::below(1000), U64Gen::below(1000)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let res = std::panic::catch_unwind(|| {
            check("find_42", 5000, &U64Gen::below(1000), |&x| x < 42);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // the minimal counterexample of `x < 42` over shrink-toward-0 is 42
        assert!(msg.contains("minimal counterexample: 42"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_length_bounds() {
        let g = VecGen::new(U64Gen::below(10), 2..=5);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let v = g.generate(&mut rng);
            assert!((2..=5).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 10));
        }
    }

    #[test]
    fn vec_shrink_reduces_length_to_bound() {
        let res = std::panic::catch_unwind(|| {
            check("nonempty_fails", 100, &VecGen::new(U64Gen::below(5), 1..=8), |v| v.len() > 50);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // minimal failing vec should have been shrunk down to length 1
        let tail = msg.split("counterexample:").nth(1).unwrap();
        assert!(tail.contains('[') && tail.matches(',').count() == 0, "{msg}");
    }

    #[test]
    fn deterministic_given_name() {
        // same property name ⇒ same stream ⇒ same first sample
        let mut first = Vec::new();
        for _ in 0..2 {
            let captured = std::cell::Cell::new(0u64);
            check("capture", 1, &U64Gen::below(1 << 40), |&x| {
                captured.set(x);
                true
            });
            first.push(captured.get());
        }
        assert_eq!(first[0], first[1]);
    }
}
