//! Offline substrates.
//!
//! This build environment has no crates.io access beyond the vendored set,
//! so the usual ecosystem pieces (rand, clap, toml, proptest, criterion)
//! are implemented here as small, well-tested modules:
//!
//! * [`rng`] — SplitMix64 / PCG PRNG + the distributions the tensor
//!   generators need (uniform, Zipf, log-normal, permutations).
//! * [`cli`] — a declarative command-line parser (flags, options,
//!   subcommands, `--help` generation).
//! * [`configfile`] — a TOML-subset parser for accelerator config files.
//! * [`stats`] — summary statistics, percentiles, histograms.
//! * [`table`] — ASCII / Markdown / CSV table rendering for reports.
//! * [`prop`] — a miniature property-testing harness (random generation +
//!   bounded shrinking) used by the invariant tests.
//! * [`bench`] — a miniature criterion: warmup, timed iterations,
//!   mean/σ/min, throughput, and the same "name ... time" output layout.
//! * [`json`] — a minimal JSON parser (the serve daemon's request
//!   reader; the crate writes JSON by hand).

pub mod bench;
pub mod cli;
pub mod configfile;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
