//! Miniature benchmark harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets use `harness = false` and drive this module from a
//! plain `main`. Each benchmark gets a warmup phase (at least one full
//! iteration, so first-touch costs never contaminate samples), a
//! calibrated iteration count targeting a wall-time budget, and reports
//! **median**-of-N (the headline statistic — robust to scheduler noise, so
//! `BENCH_*.json` files are comparable across runs), mean ± σ, min,
//! p50/p95/p99 tail percentiles (via [`crate::util::stats::percentile`],
//! the latency-shaped view `BENCH_serve.json` surfaces), and optional
//! throughput (computed over the median). Results can be dumped as CSV
//! (plotting) or JSON (the `BENCH_*.json` perf-trajectory files at the
//! repository root).
//!
//! This intentionally mirrors criterion's output shape
//! (`name   time: [median ± σ]`) so downstream tooling/log-readers behave.

use std::hint::black_box;
use std::io;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::table::Table;

pub use std::hint::black_box as bb;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    /// Median of the N samples — the headline statistic (robust to
    /// scheduler/IO outliers, unlike a mean or a single shot).
    pub median: Duration,
    pub mean: Duration,
    pub sigma: Duration,
    pub min: Duration,
    /// 50th percentile — the same statistic as `median`, kept under its
    /// quantile name so the p50/p95/p99 family reads uniformly.
    pub p50: Duration,
    /// 95th percentile of the samples (linear interpolation).
    pub p95: Duration,
    /// 99th percentile of the samples — the latency-tail statistic.
    pub p99: Duration,
    /// Items (e.g. nnz) processed per iteration, for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// Throughput over the **median** sample, so the number is stable
    /// across runs on noisy machines.
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.items_per_iter.map(|it| it / self.median.as_secs_f64())
    }
}

/// Median of a sample set (mean of the two middle samples when even).
fn median_of(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Harness configuration: wall-clock budgets per phase.
#[derive(Clone, Debug)]
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    results: Vec<Measurement>,
    values: Vec<(String, f64, String)>,
    group: String,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor PHOTON_BENCH_FAST=1 for CI-speed runs.
        let fast = std::env::var("PHOTON_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            min_iters: 5,
            results: Vec::new(),
            values: Vec::new(),
            group: String::new(),
        }
    }

    pub fn group(&mut self, name: &str) -> &mut Self {
        self.group = name.to_string();
        println!("\n### bench group: {name}");
        self
    }

    fn full_name(&self, name: &str) -> String {
        if self.group.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.group, name)
        }
    }

    /// Benchmark `f`, which should return something consumable by
    /// `black_box` so the optimizer cannot delete the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        self.bench_with_items(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (items per iteration).
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> &Measurement {
        self.bench_with_items(name, Some(items_per_iter), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Measurement {
        // Warmup (always ≥ 1 full iteration — caches, allocator pools and
        // lazy statics are primed before any timed sample) + a
        // single-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 1 {
            black_box(f());
            warm_iters += 1;
        }
        let est = warm_start.elapsed() / warm_iters.max(1) as u32;

        // Choose sample count: aim for `measure` total, ≥ min_iters samples.
        let target = self
            .measure
            .as_nanos()
            .checked_div(est.as_nanos().max(1))
            .unwrap_or(u128::from(self.min_iters)) as u64;
        let iters = target.clamp(self.min_iters, 1_000_000);

        // one sample buffer, all statistics derived from it at the end —
        // no parallel accumulators to drift apart
        let mut raw = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            raw.push(t0.elapsed().as_secs_f64());
        }
        let samples = Summary::from_slice(&raw);
        let pct = |p: f64| Duration::from_secs_f64(crate::util::stats::percentile(&raw, p));
        let m = Measurement {
            name: self.full_name(name),
            iters,
            median: Duration::from_secs_f64(median_of(&raw)),
            mean: Duration::from_secs_f64(samples.mean()),
            sigma: Duration::from_secs_f64(samples.std()),
            min: Duration::from_secs_f64(samples.min()),
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            items_per_iter: items,
        };
        print_measurement(&m);
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally computed scalar (used by the table/figure
    /// "benches", where the interesting output is the model value itself,
    /// and by counters like the explore screen's stream-walk count).
    /// Persisted into [`Bench::write_json`] under a `"values"` array.
    pub fn record_value(&mut self, name: &str, value: f64, unit: &str) {
        let formatted = crate::util::table::fmt_sig(value, 4);
        println!("{:<44} value: {formatted} {unit}", self.full_name(name));
        self.values.push((self.full_name(name), value, unit.to_string()));
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render all measurements as a table.
    pub fn summary_table(&self) -> Table {
        let cols = ["name", "iters", "median", "mean", "sigma", "min", "throughput"];
        let mut t = Table::new("bench summary", &cols).align(0, crate::util::table::Align::Left);
        for m in &self.results {
            t.row(vec![
                m.name.clone(),
                m.iters.to_string(),
                fmt_dur(m.median),
                fmt_dur(m.mean),
                fmt_dur(m.sigma),
                fmt_dur(m.min),
                m.throughput_per_s().map(|t| format!("{:.3e}/s", t)).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Write CSV of all measurements to `path`, creating parent
    /// directories as needed.
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        let cols = [
            "name",
            "iters",
            "mean_s",
            "median_s",
            "sigma_s",
            "min_s",
            "p50_s",
            "p95_s",
            "p99_s",
            "throughput_per_s",
        ];
        let mut t = Table::new("", &cols);
        for m in &self.results {
            t.row(vec![
                m.name.clone(),
                m.iters.to_string(),
                format!("{:.9}", m.mean.as_secs_f64()),
                format!("{:.9}", m.median.as_secs_f64()),
                format!("{:.9}", m.sigma.as_secs_f64()),
                format!("{:.9}", m.min.as_secs_f64()),
                format!("{:.9}", m.p50.as_secs_f64()),
                format!("{:.9}", m.p95.as_secs_f64()),
                format!("{:.9}", m.p99.as_secs_f64()),
                m.throughput_per_s().map(|t| format!("{t:.3}")).unwrap_or_default(),
            ]);
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, t.render_csv())
    }

    /// Write all measurements as JSON to `path`, creating parent
    /// directories as needed. Shape (stable — the perf-trajectory files
    /// at the repository root accumulate against it):
    ///
    /// ```json
    /// { "benchmarks": [ { "name": "...", "iters": 7, "mean_s": 0.1,
    ///   "median_s": 0.1, "sigma_s": 0.01, "min_s": 0.09,
    ///   "p50_s": 0.1, "p95_s": 0.12, "p99_s": 0.13,
    ///   "throughput_per_s": 123.0 } ] }
    /// ```
    ///
    /// `throughput_per_s` is `null` for benches without an item count and
    /// is computed over `median_s`, the run-to-run-comparable statistic.
    /// Hand-rolled writer (the build is offline, no serde): numbers via
    /// `{:e}` so round-tripping loses nothing, names JSON-escaped.
    /// Scalars recorded with [`Bench::record_value`] land in an additional
    /// `"values"` array (omitted when none were recorded, so existing
    /// trajectory files keep their exact shape).
    pub fn write_json(&self, path: &Path) -> io::Result<()> {
        let mut out = String::from("{\n  \"benchmarks\": [");
        for (i, m) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {:e}, \
                 \"median_s\": {:e}, \"sigma_s\": {:e}, \"min_s\": {:e}, \
                 \"p50_s\": {:e}, \"p95_s\": {:e}, \"p99_s\": {:e}, \
                 \"throughput_per_s\": {}}}",
                json_escape(&m.name),
                m.iters,
                m.mean.as_secs_f64(),
                m.median.as_secs_f64(),
                m.sigma.as_secs_f64(),
                m.min.as_secs_f64(),
                m.p50.as_secs_f64(),
                m.p95.as_secs_f64(),
                m.p99.as_secs_f64(),
                m.throughput_per_s().map(|t| format!("{t:e}")).unwrap_or_else(|| "null".into()),
            ));
        }
        out.push_str("\n  ]");
        if !self.values.is_empty() {
            out.push_str(",\n  \"values\": [");
            for (i, (name, value, unit)) in self.values.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n    {{\"name\": \"{}\", \"value\": {:e}, \"unit\": \"{}\"}}",
                    json_escape(name),
                    value,
                    json_escape(unit),
                ));
            }
            out.push_str("\n  ]");
        }
        out.push_str("\n}\n");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, out)
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// names are usually ASCII identifiers but a writer must never emit
/// invalid JSON whatever it is fed. Shared by the bench JSON writer and
/// the explore frontier export ([`crate::explore::export`]).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

fn print_measurement(m: &Measurement) {
    let thr = m
        .throughput_per_s()
        .map(|t| format!("   thrpt: {:.3e} items/s", t))
        .unwrap_or_default();
    println!(
        "{:<44} time: [{} ± {}] min {} ({} iters){}",
        m.name,
        fmt_dur(m.median),
        fmt_dur(m.sigma),
        fmt_dur(m.min),
        m.iters,
        thr
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean.as_nanos() > 0);
        assert!(m.median.as_nanos() > 0);
        // the median of N samples can never undercut the fastest sample
        assert!(m.median >= m.min);
        assert!(m.iters >= 5);
    }

    #[test]
    fn median_is_the_middle_sample() {
        assert_eq!(median_of(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_of(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median_of(&[7.0]), 7.0);
        assert_eq!(median_of(&[]), 0.0);
        // robust to one wild outlier — the property the bench JSONs need
        assert_eq!(median_of(&[1.0, 1.0, 1.0, 1.0, 500.0]), 1.0);
    }

    #[test]
    fn percentiles_are_ordered_and_land_in_the_json() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        let m = b.bench("p", || std::hint::black_box(2 + 2)).clone();
        assert!(m.min <= m.p50 && m.p50 <= m.p95 && m.p95 <= m.p99);
        // p50 is the median under its quantile name (interpolation at
        // rank (n-1)/2 is exactly the middle-sample mean)
        assert_eq!(m.p50, m.median);
        let path = std::env::temp_dir()
            .join(format!("photon_bench_pct_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let json = std::fs::read_to_string(&path).unwrap();
        for key in ["\"p50_s\": ", "\"p95_s\": ", "\"p99_s\": "] {
            assert!(json.contains(key), "{json}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn throughput_computed() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        let m = b.bench_items("items", 100.0, || std::hint::black_box(3 * 7)).clone();
        let t = m.throughput_per_s().unwrap();
        assert!(t > 0.0);
    }

    #[test]
    fn summary_and_csv_shapes() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.group("g");
        b.bench("a", || 1 + 1);
        let tbl = b.summary_table();
        assert_eq!(tbl.n_rows(), 1);
        let csv = {
            let dir = std::env::temp_dir().join("photon_bench_test.csv");
            b.write_csv(&dir).unwrap();
            std::fs::read_to_string(&dir).unwrap()
        };
        assert!(csv.starts_with("name,iters,mean_s,median_s"));
        assert!(csv.contains("g/a"));
    }

    #[test]
    fn json_shape_and_escaping() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.group("g");
        b.bench_items("with\"quote", 10.0, || 1 + 1);
        b.bench("plain", || 2 + 2);
        let path = std::env::temp_dir()
            .join(format!("photon_bench_test_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let json = std::fs::read_to_string(&path).unwrap();
        assert!(json.starts_with("{\n  \"benchmarks\": ["), "{json}");
        assert!(json.contains("\"name\": \"g/with\\\"quote\""), "{json}");
        assert!(json.contains("\"throughput_per_s\": null"), "{json}");
        assert!(json.contains("\"mean_s\": "), "{json}");
        assert!(json.contains("\"median_s\": "), "{json}");
        // balanced structure: one object per measurement
        assert_eq!(json.matches("{\"name\"").count(), 2);
        assert!(json.trim_end().ends_with('}'), "{json}");
        // no values recorded → no "values" key at all (shape unchanged)
        assert!(!json.contains("\"values\""), "{json}");
    }

    #[test]
    fn recorded_values_land_in_the_json() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.group("g");
        b.bench("plain", || 2 + 2);
        b.record_value("walks", 3.0, "stream walks");
        let path = std::env::temp_dir()
            .join(format!("photon_bench_values_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let json = std::fs::read_to_string(&path).unwrap();
        assert!(json.contains("\"values\": ["), "{json}");
        assert!(json.contains("\"name\": \"g/walks\""), "{json}");
        assert!(json.contains("\"value\": 3e0"), "{json}");
        assert!(json.contains("\"unit\": \"stream walks\""), "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn writers_create_parent_directories() {
        std::env::set_var("PHOTON_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.bench("x", || 1);
        let root = std::env::temp_dir()
            .join(format!("photon_bench_dirs_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        b.write_csv(&root.join("deep/nested/out.csv")).unwrap();
        b.write_json(&root.join("deep/other/out.json")).unwrap();
        assert!(root.join("deep/nested/out.csv").exists());
        assert!(root.join("deep/other/out.json").exists());
        let _ = std::fs::remove_dir_all(&root);
    }
}
