//! Table rendering for the report / bench output.
//!
//! Every paper table and figure is regenerated as rows of a [`Table`]; the
//! same structure renders to aligned ASCII (terminal), Markdown
//! (EXPERIMENTS.md) and CSV (plotting).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple rows-of-strings table with a header.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: header.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override alignment for a column (default: Right).
    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    fn pad(cell: &str, width: usize, a: Align) -> String {
        match a {
            Align::Left => format!("{cell:<width$}"),
            Align::Right => format!("{cell:>width$}"),
        }
    }

    /// Aligned plain-text rendering (terminal output).
    pub fn render_ascii(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let hdr: Vec<String> = self
            .header
            .iter()
            .zip(&w)
            .zip(&self.aligns)
            .map(|((h, &wi), &a)| Self::pad(h, wi, a))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .zip(&w)
                .zip(&self.aligns)
                .map(|((c, &wi), &a)| Self::pad(c, wi, a))
                .collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured Markdown rendering.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        let sep: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| if *a == Align::Right { "---:" } else { ":---" })
            .collect();
        out.push_str(&format!("| {} |\n", sep.join(" | ")));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// CSV rendering (RFC-4180 quoting where needed).
    pub fn render_csv(&self) -> String {
        fn esc(s: &str) -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style sensible precision for tables.
pub fn fmt_sig(x: f64, sig: usize) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let mag = x.abs().log10().floor() as i32;
    if (-3..6).contains(&mag) {
        let dec = (sig as i32 - 1 - mag).max(0) as usize;
        format!("{x:.dec$}")
    } else {
        format!("{x:.prec$e}", prec = sig - 1)
    }
}

/// Human-readable byte count (KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable count (K/M/B), e.g. `143.6M` nnz like Table II.
pub fn fmt_count(n: u64) -> String {
    let v = n as f64;
    if v >= 1e9 {
        format!("{:.1}B", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", &["name", "value"]).align(0, Align::Left);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "22".into()]);
        t
    }

    #[test]
    fn ascii_aligns_columns() {
        let s = sample().render_ascii();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].contains("== T =="));
        assert!(lines[1].starts_with("name "));
        // value column right-aligned: " 1" and "22" end at same offset
        let l3 = lines[3];
        let l4 = lines[4];
        assert_eq!(l3.len(), l4.len());
        assert!(l3.ends_with(" 1"));
        assert!(l4.ends_with("22"));
    }

    #[test]
    fn markdown_shape() {
        let s = sample().render_markdown();
        assert!(s.contains("| name | value |"));
        assert!(s.contains("| :--- | ---: |"));
        assert!(s.contains("| alpha | 1 |"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let s = t.render_csv();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.5, 3), "1234"); // round-half-even, no decimals
        assert_eq!(fmt_sig(0.001234, 2), "0.0012");
        assert!(fmt_sig(1.0e9, 3).contains('e'));
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_count(143_600_000), "143.6M");
        assert_eq!(fmt_count(4_700_000_000), "4.7B");
        assert_eq!(fmt_count(950), "950");
    }
}
