//! A minimal JSON parser for the serving layer (no external crates).
//!
//! The crate *writes* JSON by hand everywhere (`{:e}` floats +
//! [`crate::util::bench::json_escape`]); the `serve` daemon is the
//! first thing that must *read* it. This is a small recursive-descent
//! parser over the full JSON grammar — objects, arrays, strings with
//! escapes (incl. `\uXXXX` and surrogate pairs), numbers, literals —
//! with a nesting-depth cap so a hostile request cannot overflow the
//! stack. Numbers are parsed as `f64` (every request field the daemon
//! accepts — ids, seeds, rates, scales — fits losslessly) and object
//! keys keep their file order in a `Vec`, which is all the request
//! decoder needs.

/// Maximum array/object nesting accepted. Requests are flat; this is a
/// stack-overflow guard, not a capacity target.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse one complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(s: &str) -> Result<Value, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object member lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number: exact non-negative integers only.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(_) => self.number(),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: a low surrogate must follow
                                if self.peek() != Some(b'\\') {
                                    return Err("lone high surrogate".into());
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err("lone high surrogate".into());
                                }
                                self.pos += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("invalid codepoint {cp:#x}"))?,
                            );
                        }
                        other => return Err(format!("invalid escape `\\{}`", other as char)),
                    }
                }
                Some(b) if b < 0x20 => {
                    return Err(format!("raw control byte {b:#04x} in string"));
                }
                Some(_) => {
                    // copy one UTF-8 scalar (input is &str, so boundaries
                    // are valid by construction)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end]).map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape `{s}`"))?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        // reject the shapes `parse::<f64>` would accept but JSON forbids
        if text.is_empty()
            || text == "-"
            || text.starts_with('.')
            || text.ends_with('.')
            || text.contains("-.")
        {
            return Err(format!("invalid number at byte {start}"));
        }
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("invalid number `{text}` at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_request_shapes_the_daemon_sees() {
        let v = Value::parse(
            r#"{"id": 3, "cmd": "simulate", "tensor": "nell-2", "scale": 1e-4,
                "techs": ["e-sram", "o-sram"], "remap": true, "note": null}"#,
        )
        .unwrap();
        assert_eq!(v.get("id").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("cmd").unwrap().as_str(), Some("simulate"));
        assert_eq!(v.get("scale").unwrap().as_f64(), Some(1e-4));
        let techs: Vec<&str> =
            v.get("techs").unwrap().as_arr().unwrap().iter().filter_map(|t| t.as_str()).collect();
        assert_eq!(techs, ["e-sram", "o-sram"]);
        assert_eq!(v.get("remap").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("note"), Some(&Value::Null));
        assert_eq!(v.get("absent"), None);
    }

    #[test]
    fn round_trips_escapes_and_unicode() {
        let v = Value::parse(r#"["a\"b\\c\/d\n\t", "\u00e9\u0041", "\ud83d\ude00", "π"]"#).unwrap();
        let items = v.as_arr().unwrap();
        assert_eq!(items[0].as_str(), Some("a\"b\\c/d\n\t"));
        assert_eq!(items[1].as_str(), Some("éA"));
        assert_eq!(items[2].as_str(), Some("😀"));
        assert_eq!(items[3].as_str(), Some("π"));
    }

    #[test]
    fn parses_numbers_exactly() {
        for (text, want) in [
            ("0", 0.0),
            ("-0", -0.0),
            ("42", 42.0),
            ("-1.5", -1.5),
            ("2.5e3", 2500.0),
            ("1E-2", 0.01),
            ("1e+2", 100.0),
        ] {
            assert_eq!(Value::parse(text).unwrap().as_f64(), Some(want), "{text}");
        }
        assert_eq!(Value::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Value::parse("7.5").unwrap().as_u64(), None);
        assert_eq!(Value::parse("-7").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "nul", "01x", "- 1", ".5", "5.",
            "\"unterminated", "{\"a\":1} extra", "[1 2]", "\"\\q\"", "\"\\ud83d\"", "{1: 2}",
        ] {
            assert!(Value::parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn depth_guard_rejects_hostile_nesting() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(Value::parse(&deep).is_err());
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Value::parse(&ok).is_ok());
    }
}
