//! Declarative command-line parsing (offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, repeated
//! options, positional arguments, defaults, and generated `--help` text.
//!
//! ```
//! use photon_mttkrp::util::cli::{Command, Parsed};
//! let cmd = Command::new("demo", "demo tool")
//!     .flag("verbose", 'v', "chatty output")
//!     .opt("seed", "N", "rng seed", Some("42"));
//! let p = cmd.parse_from(&["--verbose", "--seed=7"]).unwrap();
//! assert!(p.flag("verbose"));
//! assert_eq!(p.get_u64("seed").unwrap(), 7);
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// Parse error with a user-facing message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    short: Option<char>,
    help: String,
}

#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    value_name: String,
    help: String,
    default: Option<String>,
    repeated: bool,
}

#[derive(Clone, Debug)]
struct PosSpec {
    name: String,
    help: String,
    required: bool,
}

/// A command (or subcommand) definition.
#[derive(Clone, Debug)]
pub struct Command {
    name: String,
    about: String,
    flags: Vec<FlagSpec>,
    opts: Vec<OptSpec>,
    positionals: Vec<PosSpec>,
    subcommands: Vec<Command>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command {
            name: name.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
            opts: Vec::new(),
            positionals: Vec::new(),
            subcommands: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &str, short: char, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            short: if short == '\0' { None } else { Some(short) },
            help: help.to_string(),
        });
        self
    }

    /// An option taking a value, with an optional default.
    pub fn opt(mut self, name: &str, value_name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            value_name: value_name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            repeated: false,
        });
        self
    }

    /// An option that may be given multiple times (collected in order).
    pub fn opt_repeated(mut self, name: &str, value_name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            value_name: value_name.to_string(),
            help: help.to_string(),
            default: None,
            repeated: true,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str, required: bool) -> Self {
        self.positionals.push(PosSpec { name: name.to_string(), help: help.to_string(), required });
        self
    }

    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subcommands.push(sub);
        self
    }

    /// Registered subcommand names, in definition order — the help
    /// listing's order, and what the unknown-subcommand error enumerates.
    pub fn subcommand_names(&self) -> Vec<&str> {
        self.subcommands.iter().map(|s| s.name.as_str()).collect()
    }

    /// Generated help text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            out.push_str(" <SUBCOMMAND>");
        }
        if !self.flags.is_empty() || !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        for p in &self.positionals {
            if p.required {
                out.push_str(&format!(" <{}>", p.name));
            } else {
                out.push_str(&format!(" [{}]", p.name));
            }
        }
        out.push('\n');
        if !self.subcommands.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for s in &self.subcommands {
                out.push_str(&format!("  {:<16} {}\n", s.name, s.about));
            }
        }
        if !self.flags.is_empty() {
            out.push_str("\nFLAGS:\n");
            for f in &self.flags {
                let short = f.short.map(|c| format!("-{c}, ")).unwrap_or_default();
                out.push_str(&format!("  {short}--{:<16} {}\n", f.name, f.help));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let dflt = o
                    .default
                    .as_ref()
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                out.push_str(&format!(
                    "  --{} <{}>{}\n      {}{}\n",
                    o.name,
                    o.value_name,
                    if o.repeated { " (repeatable)" } else { "" },
                    o.help,
                    dflt
                ));
            }
        }
        if !self.positionals.is_empty() {
            out.push_str("\nARGS:\n");
            for p in &self.positionals {
                out.push_str(&format!("  {:<16} {}\n", p.name, p.help));
            }
        }
        out
    }

    /// Parse from explicit argument strings (no program name).
    pub fn parse_from<S: AsRef<str>>(&self, args: &[S]) -> Result<Parsed, CliError> {
        let mut parsed = Parsed {
            command_path: vec![self.name.clone()],
            flags: Default::default(),
            opts: Default::default(),
            positionals: Vec::new(),
            help_requested: false,
        };
        for o in &self.opts {
            if let Some(d) = &o.default {
                parsed.opts.insert(o.name.clone(), vec![d.clone()]);
            }
        }
        let mut i = 0usize;
        let mut first_positional_seen = false;
        while i < args.len() {
            let a = args[i].as_ref();
            if a == "--help" || a == "-h" {
                parsed.help_requested = true;
                return Ok(parsed);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                if self.flags.iter().any(|f| f.name == key) {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{key} takes no value")));
                    }
                    parsed.flags.insert(key.to_string());
                } else if let Some(spec) = self.opts.iter().find(|o| o.name == key) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .map(|s| s.as_ref().to_string())
                                .ok_or_else(|| CliError(format!("--{key} requires a value")))?
                        }
                    };
                    let entry = parsed.opts.entry(key.to_string()).or_default();
                    if spec.repeated {
                        // defaults never exist for repeated opts
                        entry.push(val);
                    } else {
                        *entry = vec![val];
                    }
                } else {
                    return Err(CliError(format!("unknown option --{key}")));
                }
            } else if let Some(short) = a.strip_prefix('-').filter(|s| !s.is_empty()) {
                for c in short.chars() {
                    let f = self
                        .flags
                        .iter()
                        .find(|f| f.short == Some(c))
                        .ok_or_else(|| CliError(format!("unknown flag -{c}")))?;
                    parsed.flags.insert(f.name.clone());
                }
            } else {
                // subcommand (only in first positional position) or positional
                if !first_positional_seen {
                    if let Some(sub) = self.subcommands.iter().find(|s| s.name == a) {
                        let rest: Vec<String> =
                            args[i + 1..].iter().map(|s| s.as_ref().to_string()).collect();
                        let mut sub_parsed = sub.parse_from(&rest)?;
                        sub_parsed.command_path.insert(0, self.name.clone());
                        return Ok(sub_parsed);
                    }
                    if !self.subcommands.is_empty() && self.positionals.is_empty() {
                        // list every registered subcommand, matching the
                        // helpful unknown --kernel / --tech error style
                        return Err(CliError(format!(
                            "unknown subcommand `{a}` (expected one of: {})",
                            self.subcommand_names().join(", ")
                        )));
                    }
                }
                first_positional_seen = true;
                parsed.positionals.push(a.to_string());
            }
            i += 1;
        }
        let required = self.positionals.iter().filter(|p| p.required).count();
        if parsed.positionals.len() < required {
            return Err(CliError(format!(
                "missing required argument <{}>",
                self.positionals[parsed.positionals.len()].name
            )));
        }
        Ok(parsed)
    }

    /// Parse `std::env::args()` (skipping the program name).
    pub fn parse_env(&self) -> Result<Parsed, CliError> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&args)
    }
}

/// Parse result: resolved flags, options and positionals.
#[derive(Clone, Debug)]
pub struct Parsed {
    /// e.g. `["photon-mttkrp", "simulate"]` — last element is the leaf.
    pub command_path: Vec<String>,
    flags: std::collections::BTreeSet<String>,
    opts: BTreeMap<String, Vec<String>>,
    pub positionals: Vec<String>,
    pub help_requested: bool,
}

impl Parsed {
    pub fn subcommand(&self) -> Option<&str> {
        if self.command_path.len() > 1 {
            Some(self.command_path.last().unwrap())
        } else {
            None
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.opts.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("--{name} not given")))?
            .parse()
            .map_err(|e| CliError(format!("--{name}: {e}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.get_u64(name)? as usize)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("--{name} not given")))?
            .parse()
            .map_err(|e| CliError(format!("--{name}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("tool", "a tool")
            .flag("verbose", 'v', "verbose")
            .flag("quiet", 'q', "quiet")
            .opt("seed", "N", "seed", Some("42"))
            .opt_repeated("tensor", "NAME", "tensor selection")
            .subcommand(
                Command::new("run", "run it")
                    .opt("mode", "M", "mode index", None)
                    .positional("input", "input file", true),
            )
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse_from::<&str>(&[]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 42);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn flags_and_eq_opts() {
        let p = cmd().parse_from(&["--verbose", "--seed=7"]).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.get_u64("seed").unwrap(), 7);
    }

    #[test]
    fn space_separated_value() {
        let p = cmd().parse_from(&["--seed", "9"]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 9);
    }

    #[test]
    fn short_flags_combined() {
        let p = cmd().parse_from(&["-vq"]).unwrap();
        assert!(p.flag("verbose") && p.flag("quiet"));
    }

    #[test]
    fn repeated_options_collect() {
        let p = cmd().parse_from(&["--tensor", "nell-1", "--tensor=nell-2"]).unwrap();
        assert_eq!(p.get_all("tensor"), vec!["nell-1", "nell-2"]);
    }

    #[test]
    fn subcommand_dispatch() {
        let p = cmd().parse_from(&["run", "--mode", "2", "file.tns"]).unwrap();
        assert_eq!(p.subcommand(), Some("run"));
        assert_eq!(p.get("mode"), Some("2"));
        assert_eq!(p.positionals, vec!["file.tns"]);
    }

    #[test]
    fn missing_required_positional() {
        let e = cmd().parse_from(&["run"]).unwrap_err();
        assert!(e.0.contains("input"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse_from(&["--nope"]).is_err());
        assert!(cmd().parse_from(&["bogus-subcommand"]).is_err());
    }

    #[test]
    fn unknown_subcommand_lists_the_registered_ones() {
        let e = cmd().parse_from(&["bogus-subcommand"]).unwrap_err();
        assert!(e.0.contains("unknown subcommand `bogus-subcommand`"), "{e}");
        assert!(e.0.contains("expected one of: run"), "{e}");
        assert_eq!(cmd().subcommand_names(), vec!["run"]);
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse_from(&["--seed"]).is_err());
    }

    #[test]
    fn help_flag_short_circuits() {
        let p = cmd().parse_from(&["--help"]).unwrap();
        assert!(p.help_requested);
        let h = cmd().help();
        assert!(h.contains("SUBCOMMANDS"));
        assert!(h.contains("--seed"));
        assert!(h.contains("[default: 42]"));
    }

    #[test]
    fn last_wins_for_non_repeated() {
        let p = cmd().parse_from(&["--seed=1", "--seed=2"]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 2);
    }
}
