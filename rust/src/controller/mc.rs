//! Per-PE memory controller: functional caches + timing for the three
//! access types of §IV-A.
//!
//! 1. **Cache transfers** — random accesses with reuse potential (input
//!    factor rows). Routed to one of the `n_caches` set-associative
//!    caches (matrix → cache, round-robin as "each cache is shared with
//!    multiple input factor matrices").
//! 2. **DMA stream transfers** — sequential loads/stores (the mode-sorted
//!    tensor nonzeros in; output factor rows out).
//! 3. **DMA element-wise transfers** — no locality at all: factor matrices
//!    whose row space is hopeless for the cache (≫ capacity × bypass
//!    factor) bypass the cache so they neither pollute it nor pay tag
//!    overhead; they go straight to DRAM as independent bursts.
//!
//! ## Functional/timing split
//!
//! The controller's state is split in two strata:
//!
//! * **Functional counters** — integer hit/miss/traffic/active-word
//!   counts, a pure function of the access stream and the cache
//!   *geometry* (sets × assoc × line, plus the level stack). These are
//!   what [`Self::counts`] extracts and [`Self::load_counts`] restores,
//!   and what the reuse-distance profiler
//!   ([`crate::sim::profile`]) derives without replaying the stream.
//! * **Pricing constants** — technology-dependent occupancies hoisted
//!   once in [`Self::new`] (`hit_occ`, `fill_occ`, per-level
//!   `serve_occ`/`fill_occ`, `miss_dram_cycles`, the element-DMA
//!   charge). Every busy figure is **derived** from the functional
//!   counters at read time (`count × constant`, see [`Self::cache_busy`]
//!   and friends), never accumulated per access — which is what makes a
//!   priced-from-counts report bit-identical to a directly simulated
//!   one. The only incremental `f64` left is `stream_busy`, charged by
//!   the handful of [`Self::stream`] calls the engine replays verbatim
//!   on the pricing path.
//!
//! ## Memory hierarchy (`AcceleratorConfig::levels`)
//!
//! When the config carries a non-empty level stack, the type-1 *miss*
//! path probes the stack innermost-first instead of going straight to
//! DRAM: a hit at some level serves the PE-cache line fill from that
//! level's array; an all-miss fetches the outermost level's line from
//! DRAM and fills every missed level on the way back in. Each level
//! keeps a functional [`SetAssocCache`] over coarsened row keys (its
//! line is a power-of-two multiple of the PE cache line, so the level
//! key is `row >> shift`), per-level hit/traffic/word counters
//! (surfaced as [`LevelReport`]s), and hoisted `ArrayTiming` occupancy
//! constants the event engine re-uses for its per-level arbitration.
//! Bypass accesses and dirty writebacks keep the direct-DRAM path, so
//! the conservation invariant is exact: level `i` accesses ==
//! level `i+1` misses, and the innermost level sees every PE-cache
//! line fill. An **empty stack executes the pre-hierarchy code
//! byte-for-byte** — the degenerate config is bit-identical (pinned by
//! `tests/golden.rs`).

use crate::accel::config::AcceleratorConfig;
use crate::cache::cache::{row_key, Access, CacheStats, SetAssocCache};
use crate::cache::pipeline::{ArrayTiming, CacheTiming};
use crate::dma::elementwise::{ElementCharge, ElementDma};
use crate::dma::stream::StreamDma;
use crate::mem::dram::{DramChannelState, DramConfig};
use crate::mem::hierarchy::LevelReport;
use crate::mem::tech::MemTechnology;

/// How a factor-row access was served (for the engine's accounting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Served {
    CacheHit { cache: usize },
    CacheMiss { cache: usize, writeback: bool },
    Bypass,
}

/// One instantiated level of the configured memory hierarchy:
/// functional set-associative state over coarsened row keys, hoisted
/// occupancy constants, and the per-level accounting that becomes a
/// [`LevelReport`]. Stored in `AcceleratorConfig::levels` stack order
/// (index 0 outermost / DRAM-side).
struct LevelState {
    cache: SetAssocCache,
    /// `log2(level_line / cfg.line_bytes)`: the level key is
    /// `row_key(matrix, row >> row_shift)`.
    row_shift: u32,
    /// Array occupancy to serve one inner request (fabric cycles).
    serve_occ: f64,
    /// Array occupancy to write one level line on a fill.
    fill_occ: f64,
    /// Pipelined array latency (fabric cycles) — the event engine's
    /// hit-to-forward delay for this level.
    latency: f64,
    /// 32-bit words of one inner request (the next-inner level's line,
    /// or the PE cache line for the innermost level).
    request_words: u64,
    /// 32-bit words of one level line.
    line_words: u64,
    // --- accounting (functional counters; busy is derived) ---
    accesses: u64,
    hits: u64,
    misses: u64,
    words: u64,
    // --- spec echo for reports ---
    name: String,
    capacity_bytes: u64,
    line_bytes: u64,
    double_buffer: bool,
}

impl LevelState {
    /// Busy cycles, derived: every access serves the inner request,
    /// every miss additionally writes the level's own line.
    fn busy(&self) -> f64 {
        self.accesses as f64 * self.serve_occ + self.misses as f64 * self.fill_occ
    }

    fn report(&self) -> LevelReport {
        LevelReport {
            name: self.name.clone(),
            capacity_bytes: self.capacity_bytes,
            line_bytes: self.line_bytes,
            double_buffer: self.double_buffer,
            accesses: self.accesses,
            hits: self.hits,
            misses: self.misses,
            traffic_bytes: self.accesses * self.request_words * 4,
            words: self.words,
            busy_cycles: self.busy(),
        }
    }
}

/// Per-level functional counters, the hierarchy slice of
/// [`FunctionalCounts`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LevelCounts {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
}

/// The complete functional state of one controller after a stream walk:
/// everything the pricing pass needs, and nothing technology-dependent.
/// Extracted by [`MemoryController::counts`], restored into a fresh
/// controller (possibly built for a *different* technology) by
/// [`MemoryController::load_counts`] — the contract the profiler-parity
/// tests pin is that `walk → counts → load_counts` prices bit-identically
/// to `walk` on the priced controller itself.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FunctionalCounts {
    /// Per-cache hit/miss/eviction/writeback counters (index = cache).
    pub cache_stats: Vec<CacheStats>,
    /// §IV-A type-3 bypass loads served by the element-wise DMA.
    pub element_accesses: u64,
    /// DRAM random accesses of one PE-cache line each: bypass loads,
    /// degenerate-path miss fills and dirty writebacks.
    pub dram_line_accesses: u64,
    /// DRAM random accesses of one outermost-level line each
    /// (all-levels hierarchy misses; 0 for the degenerate stack).
    pub dram_hier_accesses: u64,
    /// Per-level counters, stack order (outermost first).
    pub levels: Vec<LevelCounts>,
}

impl FunctionalCounts {
    /// Combined per-PE cache statistics.
    pub fn total_cache_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.cache_stats {
            s.hits += c.hits;
            s.misses += c.misses;
            s.evictions += c.evictions;
            s.writebacks += c.writebacks;
        }
        s
    }
}

/// Per-PE memory controller: functional + timing state.
pub struct MemoryController {
    pub tech: MemTechnology,
    pub caches: Vec<SetAssocCache>,
    pub cache_timing: CacheTiming,
    pub stream_dma: StreamDma,
    pub element_dma: ElementDma,
    pub dram_cfg: DramConfig,
    pub dram: DramChannelState,
    /// Busy cycles of the stream DMA buffer (incremental: the engine's
    /// few `stream` calls are replayed verbatim on the pricing path).
    pub stream_busy: f64,
    /// Active-word counters for the Eq. 3 `S_active` energy terms.
    pub cache_words: u64,
    pub dma_words: u64,
    /// Matrices bypassing the cache (index = matrix slot).
    bypass: Vec<bool>,
    line_bytes: u64,
    /// Data-array ways read per lookup: `assoc` for synchronous arrays
    /// (speculative parallel way read, Fig. 6), 1 for fast arrays that
    /// serialize tag→data within a fabric cycle (energy model only; see
    /// `MemTechnology::serial_tag_data`).
    ways_read_per_lookup: u64,
    /// Tag words pulled per probe (all `assoc` candidate tags).
    tag_words_per_access: u64,
    // --- hoisted per-access constants (§Perf: computed once, the
    // factor_row_load fast path runs hundreds of millions of times) ---
    hit_occ: f64,
    fill_occ: f64,
    probe_words: u64,
    words_per_line: u64,
    miss_dram_cycles: f64,
    /// One element-wise bypass transfer of a PE-cache line, hoisted
    /// (the element DMA's charge is a pure function of the derated
    /// DRAM config and the line size).
    element_charge: ElementCharge,
    // --- functional counters (busy figures derive from these) ---
    element_accesses: u64,
    dram_line_accesses: u64,
    dram_hier_accesses: u64,
    /// Configured memory hierarchy (empty = degenerate single-level
    /// model; the miss path then runs the pre-hierarchy code exactly).
    levels: Vec<LevelState>,
    /// DRAM occupancy of fetching one *outermost-level* line on an
    /// all-levels miss (`miss_dram_cycles` covers the degenerate path's
    /// PE-cache line instead).
    hier_miss_dram_cycles: f64,
    /// Bytes of one outermost-level line (all-miss DRAM traffic unit).
    hier_line_bytes: u64,
    /// Missed-level count of the most recent `CacheMiss` serve
    /// (0 = innermost level hit … `n_levels()` = went to DRAM).
    /// Meaningful only right after [`Self::factor_row_load`] returns
    /// `Served::CacheMiss`, and only with a non-empty stack.
    last_fill_depth: u8,
}

/// A fabric-synchronous (electrical) cache's MEM pipeline sustains fewer
/// in-flight misses than a fast (optical-class) one, reducing the
/// effective bank-level overlap its DRAM channel achieves on miss bursts
/// (MSHR depth scales with the pipeline clock). Applied as a multiplier
/// on `DramConfig::random_overlap` whenever the technology fails the
/// [`MemTechnology::is_fast_array`] predicate.
pub const SLOW_ARRAY_MISS_OVERLAP_DERATE: f64 = 0.875;

impl MemoryController {
    /// Build a controller for one PE for an already-resolved (and, by the
    /// engine, already [`tuned`](AcceleratorConfig::tuned_tech))
    /// technology. `matrix_rows[j]` = row count of input factor matrix
    /// slot `j` (used for the §IV-A type-3 bypass routing decision when
    /// `cfg.cache_bypass_factor` is set).
    pub fn new(cfg: &AcceleratorConfig, tech: &MemTechnology, matrix_rows: &[u64]) -> Self {
        let t = tech;
        let banks = cfg.bank_factor(t);
        let cache_timing = CacheTiming::new(t, cfg.fabric_hz, banks, cfg.line_bytes);
        let buffer_timing = ArrayTiming::new(t, cfg.fabric_hz, banks);
        let caches = (0..cfg.n_caches)
            .map(|_| SetAssocCache::new(cfg.cache_sets(), cfg.cache_assoc))
            .collect();
        let capacity_lines = cfg.cache_lines as u64;
        let bypass = matrix_rows
            .iter()
            .map(|&rows| match cfg.cache_bypass_factor {
                Some(f) => rows > capacity_lines * f as u64,
                None => false,
            })
            .collect();
        let mut dram_cfg = cfg.dram.clone();
        if !t.is_fast_array(cfg.fabric_hz) {
            dram_cfg.random_overlap *= SLOW_ARRAY_MISS_OVERLAP_DERATE;
        }
        let ways_read = if t.serial_tag_data(cfg.fabric_hz) { 1 } else { cfg.cache_assoc as u64 };
        let words_per_line = (cfg.line_bytes / 4) as u64;
        let tag_words = cfg.cache_assoc as u64 * 2;
        // Memory-hierarchy stack: one functional cache + hoisted
        // occupancy constants per configured level (see module docs;
        // `AcceleratorConfig::validate` guarantees the power-of-two
        // geometry the set-associative model needs).
        let levels: Vec<LevelState> = cfg
            .levels
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let line = spec.resolved_line_bytes(cfg.line_bytes) as u64;
                let request_bytes = match cfg.levels.get(i + 1) {
                    Some(inner) => inner.resolved_line_bytes(cfg.line_bytes) as u64,
                    None => cfg.line_bytes as u64,
                };
                let lines = (spec.capacity_bytes / line) as usize;
                let assoc = lines.min(4);
                let timing = ArrayTiming::new(t, cfg.fabric_hz, spec.banks);
                let request_words = request_bytes / 4;
                let line_words = line / 4;
                LevelState {
                    cache: SetAssocCache::new(lines / assoc, assoc),
                    row_shift: (line / cfg.line_bytes as u64).trailing_zeros(),
                    serve_occ: timing.occupancy_cycles(request_words as f64),
                    fill_occ: timing.occupancy_cycles(line_words as f64),
                    latency: timing.latency_fabric_cycles,
                    request_words,
                    line_words,
                    accesses: 0,
                    hits: 0,
                    misses: 0,
                    words: 0,
                    name: spec.name.clone(),
                    capacity_bytes: spec.capacity_bytes,
                    line_bytes: line,
                    double_buffer: spec.double_buffer,
                }
            })
            .collect();
        let hier_line_bytes = levels.first().map(|l| l.line_bytes).unwrap_or(0);
        let hier_miss_dram_cycles = if hier_line_bytes == 0 {
            0.0
        } else {
            dram_cfg.random_access_cycles(hier_line_bytes)
        };
        let element_dma = ElementDma::new(buffer_timing);
        let element_charge = element_dma.access(&dram_cfg, cfg.line_bytes as u64);
        MemoryController {
            tech: tech.clone(),
            caches,
            hit_occ: cache_timing.hit_occupancy(),
            fill_occ: cache_timing.fill_occupancy(),
            probe_words: tag_words + ways_read * words_per_line,
            words_per_line,
            miss_dram_cycles: dram_cfg.random_access_cycles(cfg.line_bytes as u64),
            cache_timing,
            stream_dma: StreamDma::new(
                ArrayTiming::new(t, cfg.fabric_hz, banks),
                cfg.dma_buffer_bytes,
            ),
            element_dma,
            dram_cfg,
            dram: DramChannelState::default(),
            stream_busy: 0.0,
            cache_words: 0,
            dma_words: 0,
            bypass,
            line_bytes: cfg.line_bytes as u64,
            ways_read_per_lookup: ways_read,
            tag_words_per_access: tag_words,
            element_charge,
            element_accesses: 0,
            dram_line_accesses: 0,
            dram_hier_accesses: 0,
            levels,
            hier_miss_dram_cycles,
            hier_line_bytes,
            last_fill_depth: 0,
        }
    }

    /// Which cache serves factor-matrix slot `j`.
    #[inline]
    pub fn cache_of(&self, matrix: usize) -> usize {
        matrix % self.caches.len()
    }

    /// Is matrix slot `j` routed around the cache?
    pub fn is_bypassed(&self, matrix: usize) -> bool {
        self.bypass.get(matrix).copied().unwrap_or(false)
    }

    /// One factor-row load: the §IV-A type-1 (or type-3, if bypassed) path.
    /// Bumps the functional counters; returns how it was served. All
    /// timing derives from the counters at read time (see module docs).
    #[inline]
    pub fn factor_row_load(&mut self, matrix: usize, row: u32) -> Served {
        if self.is_bypassed(matrix) {
            self.element_accesses += 1;
            self.dma_words += self.element_charge.buffer_words;
            self.dram_line_accesses += 1;
            self.dram.bytes_random += self.line_bytes;
            self.dram.random_accesses += 1;
            return Served::Bypass;
        }
        let ci = self.cache_of(matrix);
        let key = crate::cache::cache::row_key(matrix, row);
        // Fig. 6: every probe reads all `assoc` tags and, on a read hit,
        // `ways_read_per_lookup` data ways — active words for the energy
        // model include that fan-out even though the *timing* sees
        // parallel way banks (one line-time of occupancy). All occupancy
        // constants are hoisted into the controller (§Perf).
        match self.caches[ci].access(key, false) {
            Access::Hit => {
                self.cache_words += self.probe_words;
                Served::CacheHit { cache: ci }
            }
            Access::Miss { evicted_dirty } => {
                // probe + MEM-pipeline line fill (Fig. 5)
                self.cache_words += self.probe_words + self.words_per_line;
                if self.levels.is_empty() {
                    // degenerate single-level model: straight to DRAM
                    // (this arm is the pre-hierarchy code, unchanged)
                    self.dram_line_accesses += 1;
                    self.dram.bytes_random += self.line_bytes;
                    self.dram.random_accesses += 1;
                } else {
                    self.last_fill_depth = self.hierarchy_fill(matrix, row);
                }
                if evicted_dirty {
                    // dirty writebacks post straight to DRAM in both
                    // shapes (keeps the per-level traffic invariant
                    // exact: level accesses count only line fills)
                    self.dram_line_accesses += 1;
                    self.dram.bytes_random += self.line_bytes;
                    self.dram.random_accesses += 1;
                    self.cache_words += self.words_per_line;
                }
                Served::CacheMiss { cache: ci, writeback: evicted_dirty }
            }
        }
    }

    /// Serve a PE-cache line fill from the hierarchy: probe levels
    /// innermost-first; a hit at some level stops there, an all-miss
    /// fetches the outermost line from DRAM and every missed level
    /// fills on the way back in. Returns the missed-level count
    /// (0 = innermost hit … `n_levels()` = DRAM).
    ///
    /// Accounting per probed level: every probe reads the inner
    /// request's words; a miss additionally writes the level's own
    /// line. Levels are read-only caches over factor rows — no dirty
    /// state, so no level-level writebacks.
    fn hierarchy_fill(&mut self, matrix: usize, row: u32) -> u8 {
        let mut depth = 0u8;
        for idx in (0..self.levels.len()).rev() {
            let lv = &mut self.levels[idx];
            let key = row_key(matrix, row >> lv.row_shift);
            lv.accesses += 1;
            lv.words += lv.request_words;
            match lv.cache.access(key, false) {
                Access::Hit => {
                    lv.hits += 1;
                    return depth;
                }
                Access::Miss { .. } => {
                    lv.misses += 1;
                    lv.words += lv.line_words;
                    depth += 1;
                }
            }
        }
        // missed every level: one outermost-line fetch from DRAM
        self.dram_hier_accesses += 1;
        self.dram.bytes_random += self.hier_line_bytes;
        self.dram.random_accesses += 1;
        depth
    }

    /// Number of configured hierarchy levels (0 = degenerate).
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Missed-level count of the most recent `CacheMiss` serve; see the
    /// field docs. The event engine reads this right after
    /// [`Self::factor_row_load`] to know which level granted the fill.
    #[inline]
    pub fn last_fill_depth(&self) -> u8 {
        self.last_fill_depth
    }

    /// Busy cycles of cache `ci`, derived: every probe occupies the hit
    /// path, every miss additionally occupies the MEM-pipeline fill.
    pub fn cache_busy(&self, ci: usize) -> f64 {
        let s = &self.caches[ci].stats;
        s.accesses() as f64 * self.hit_occ + s.misses as f64 * self.fill_occ
    }

    /// [`Self::cache_busy`] for every cache, in cache order.
    pub fn cache_busy_vec(&self) -> Vec<f64> {
        (0..self.caches.len()).map(|ci| self.cache_busy(ci)).collect()
    }

    /// Busy cycles of the element-wise DMA buffer, derived.
    pub fn element_busy(&self) -> f64 {
        self.element_accesses as f64 * self.element_charge.buffer_cycles
    }

    /// DRAM channel busy cycles: derived random-access occupancy
    /// (line-sized + outermost-line-sized) plus the incrementally
    /// charged stream occupancy.
    pub fn dram_busy(&self) -> f64 {
        self.dram_line_accesses as f64 * self.miss_dram_cycles
            + self.dram_hier_accesses as f64 * self.hier_miss_dram_cycles
            + self.dram.busy_cycles
    }

    /// Accumulated busy cycles of level `i` (stack order), derived.
    pub fn level_busy(&self, i: usize) -> f64 {
        self.levels[i].busy()
    }

    /// Per-level event-engine timing constants, **innermost-first**
    /// (the order the replay walks a miss): `(serve_occ, fill_occ,
    /// latency, double_buffer)` per level. Empty for the degenerate
    /// configuration.
    pub fn level_event_constants(&self) -> Vec<(f64, f64, f64, bool)> {
        self.levels
            .iter()
            .rev()
            .map(|l| (l.serve_occ, l.fill_occ, l.latency, l.double_buffer))
            .collect()
    }

    /// DRAM occupancy of an all-levels miss (one outermost-line fetch);
    /// `0.0` for the degenerate configuration.
    pub fn hier_miss_dram_cycles(&self) -> f64 {
        self.hier_miss_dram_cycles
    }

    /// Per-level accounting snapshot, in stack order (outermost first).
    pub fn level_reports(&self) -> Vec<LevelReport> {
        self.levels.iter().map(LevelState::report).collect()
    }

    /// Sequential stream of `bytes` (tensor in / output rows out):
    /// §IV-A type 2.
    pub fn stream(&mut self, bytes: u64) {
        let c = self.stream_dma.stream(&self.dram_cfg, bytes);
        self.dram.stream(&self.dram_cfg, bytes);
        self.stream_busy += c.buffer_cycles;
        self.dma_words += c.buffer_words;
    }

    /// Combined cache statistics across the subsystem.
    pub fn cache_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.caches {
            s.hits += c.stats.hits;
            s.misses += c.stats.misses;
            s.evictions += c.stats.evictions;
            s.writebacks += c.stats.writebacks;
        }
        s
    }

    /// Extract the functional counters after a stream walk — the
    /// technology-independent half of the controller's state (see
    /// module docs and [`FunctionalCounts`]).
    pub fn counts(&self) -> FunctionalCounts {
        FunctionalCounts {
            cache_stats: self.caches.iter().map(|c| c.stats).collect(),
            element_accesses: self.element_accesses,
            dram_line_accesses: self.dram_line_accesses,
            dram_hier_accesses: self.dram_hier_accesses,
            levels: self
                .levels
                .iter()
                .map(|l| LevelCounts { accesses: l.accesses, hits: l.hits, misses: l.misses })
                .collect(),
        }
    }

    /// Restore functional counters into a **fresh** controller (pricing
    /// pass): sets the integer counts and derives every traffic figure
    /// (`cache_words`, `dma_words`, DRAM random bytes/accesses, level
    /// words) exactly as the per-access path would have accumulated
    /// them — u64 sums commute, so the results are identical, and every
    /// busy figure already derives from the counts. Cache *tag* state is
    /// **not** restored: a loaded controller prices and reports, it does
    /// not continue the walk.
    pub fn load_counts(&mut self, counts: &FunctionalCounts) {
        assert_eq!(counts.cache_stats.len(), self.caches.len(), "cache count mismatch");
        assert_eq!(counts.levels.len(), self.levels.len(), "level stack mismatch");
        let mut cache_words = 0u64;
        for (c, s) in self.caches.iter_mut().zip(&counts.cache_stats) {
            c.stats = *s;
            cache_words += s.accesses() * self.probe_words
                + (s.misses + s.writebacks) * self.words_per_line;
        }
        self.cache_words += cache_words;
        self.element_accesses = counts.element_accesses;
        self.dma_words += counts.element_accesses * self.element_charge.buffer_words;
        self.dram_line_accesses = counts.dram_line_accesses;
        self.dram_hier_accesses = counts.dram_hier_accesses;
        self.dram.bytes_random += counts.dram_line_accesses * self.line_bytes
            + counts.dram_hier_accesses * self.hier_line_bytes;
        self.dram.random_accesses += counts.dram_line_accesses + counts.dram_hier_accesses;
        for (lv, lc) in self.levels.iter_mut().zip(&counts.levels) {
            lv.accesses = lc.accesses;
            lv.hits = lc.hits;
            lv.misses = lc.misses;
            lv.words = lc.accesses * lv.request_words + lc.misses * lv.line_words;
        }
    }

    /// Busiest single resource the controller owns, in cycles (the
    /// engine's bottleneck scan folds this in).
    pub fn max_busy(&self) -> f64 {
        let cache_max =
            (0..self.caches.len()).map(|ci| self.cache_busy(ci)).fold(0.0f64, f64::max);
        let level_max = self.levels.iter().map(|l| l.busy()).fold(0.0f64, f64::max);
        cache_max
            .max(level_max)
            .max(self.dram_busy())
            .max(self.stream_busy)
            .max(self.element_busy())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default()
    }

    #[test]
    fn routing_matrix_to_cache_round_robin() {
        let mc = MemoryController::new(&cfg(), &esram(), &[100, 100, 100, 100]);
        assert_eq!(mc.cache_of(0), 0);
        assert_eq!(mc.cache_of(1), 1);
        assert_eq!(mc.cache_of(2), 2);
        assert_eq!(mc.cache_of(3), 0);
    }

    #[test]
    fn hit_and_miss_paths_charge_resources() {
        let mut mc = MemoryController::new(&cfg(), &esram(), &[1000]);
        let s1 = mc.factor_row_load(0, 7);
        assert!(matches!(s1, Served::CacheMiss { cache: 0, writeback: false }));
        let dram_after_miss = mc.dram_busy();
        assert!(dram_after_miss > 0.0);
        let s2 = mc.factor_row_load(0, 7);
        assert_eq!(s2, Served::CacheHit { cache: 0 });
        // hit adds cache busy but no dram
        assert_eq!(mc.dram_busy(), dram_after_miss);
        assert!(mc.cache_busy(0) > 0.0);
        assert_eq!(mc.cache_stats().hits, 1);
        assert_eq!(mc.cache_stats().misses, 1);
    }

    #[test]
    fn bypass_off_by_default_routes_everything_to_cache() {
        let huge = u32::MAX as u64; // would bypass under any finite factor
        let mut mc = MemoryController::new(&cfg(), &esram(), &[huge]);
        assert!(!mc.is_bypassed(0));
        mc.factor_row_load(0, 3);
        assert_eq!(mc.cache_stats().accesses(), 1);
    }

    #[test]
    fn esram_miss_concurrency_derate_applies() {
        let me = MemoryController::new(&cfg(), &esram(), &[10]);
        let mo = MemoryController::new(&cfg(), &osram(), &[10]);
        assert!(me.dram_cfg.random_overlap < mo.dram_cfg.random_overlap);
        // stream bandwidth untouched
        assert_eq!(me.dram_cfg.stream_bytes_per_cycle(), mo.dram_cfg.stream_bytes_per_cycle());
    }

    #[test]
    fn huge_matrices_bypass_to_element_dma() {
        let mut c = cfg();
        c.cache_bypass_factor = Some(64);
        let huge = (c.cache_lines * 64 + 1) as u64;
        let cfg = move || c.clone();
        let mut mc = MemoryController::new(&cfg(), &esram(), &[huge, 100]);
        assert!(mc.is_bypassed(0));
        assert!(!mc.is_bypassed(1));
        assert_eq!(mc.factor_row_load(0, 3), Served::Bypass);
        // bypass never touches the caches
        assert_eq!(mc.cache_stats().accesses(), 0);
        assert!(mc.element_busy() > 0.0);
        assert!(mc.dram.random_accesses == 1);
    }

    #[test]
    fn stream_charges_dram_and_buffer() {
        let mut mc = MemoryController::new(&cfg(), &osram(), &[10]);
        mc.stream(1 << 20);
        assert!(mc.dram.bytes_streamed == 1 << 20);
        assert!(mc.stream_busy > 0.0);
        assert!(mc.dma_words > 0);
    }

    #[test]
    fn osram_cache_busy_far_below_esram() {
        let mut me = MemoryController::new(&cfg(), &esram(), &[1000]);
        let mut mo = MemoryController::new(&cfg(), &osram(), &[1000]);
        for r in 0..1000u32 {
            me.factor_row_load(0, r % 50);
            mo.factor_row_load(0, r % 50);
        }
        assert!(me.cache_busy(0) > 10.0 * mo.cache_busy(0));
        // functional behaviour identical: same hit counts
        assert_eq!(me.cache_stats(), mo.cache_stats());
    }

    #[test]
    fn energy_words_accumulate() {
        let mut mc = MemoryController::new(&cfg(), &esram(), &[1000]);
        mc.factor_row_load(0, 1); // miss: probe + fill words
        let w_miss = mc.cache_words;
        mc.factor_row_load(0, 1); // hit: probe words only
        let w_hit = mc.cache_words - w_miss;
        assert!(w_miss > w_hit);
        // synchronous E-SRAM reads all 4 ways speculatively:
        // 4×16 data + 4×2 tag = 72 words per probe (Table I assoc 4)
        assert_eq!(w_hit, 4 * 16 + 8);
    }

    #[test]
    fn two_level_stack_serves_pe_cache_misses() {
        let mut c = cfg();
        // outer 64 KiB of 256 B lines (4 rows/line), inner 4 KiB of the
        // PE's own 64 B line
        c.levels =
            crate::mem::hierarchy::parse_levels("outer:64KiB:line256,inner:4KiB").unwrap();
        c.validate().unwrap();
        let mut mc = MemoryController::new(&c, &esram(), &[1000]);
        assert_eq!(mc.n_levels(), 2);
        assert_eq!(mc.hier_miss_dram_cycles(), mc.dram_cfg.random_access_cycles(256));

        // row 0: PE miss, inner miss, outer miss ⇒ DRAM (depth 2)
        assert!(matches!(mc.factor_row_load(0, 0), Served::CacheMiss { .. }));
        assert_eq!(mc.last_fill_depth(), 2);
        assert_eq!(mc.dram.random_accesses, 1);
        assert_eq!(mc.dram.bytes_random, 256, "all-miss fetches the outermost line");

        // rows 1..3 share row 0's outer line: PE miss, inner miss,
        // outer HIT (depth 1) — no new DRAM traffic
        for r in 1..4u32 {
            assert!(matches!(mc.factor_row_load(0, r), Served::CacheMiss { .. }));
            assert_eq!(mc.last_fill_depth(), 1);
        }
        assert_eq!(mc.dram.random_accesses, 1);

        let reports = mc.level_reports();
        assert_eq!(reports.len(), 2);
        let (outer, inner) = (&reports[0], &reports[1]);
        assert_eq!(inner.accesses, 4, "innermost sees every PE-cache fill");
        assert_eq!(inner.misses, 4);
        assert_eq!(outer.accesses, inner.misses, "telescoping invariant");
        assert_eq!(outer.hits, 3);
        assert_eq!(outer.misses, 1);
        // traffic = accesses × inner request line
        assert_eq!(inner.traffic_bytes, 4 * 64);
        assert_eq!(outer.traffic_bytes, 4 * 64);
        assert!(inner.words > 0 && outer.words > 0);
        assert!(inner.busy_cycles > 0.0 && outer.busy_cycles > 0.0);
        assert!((outer.hit_rate() - 0.75).abs() < 1e-12);

        // a PE-cache hit never reaches the stack
        mc.factor_row_load(0, 0);
        assert_eq!(mc.level_reports()[1].accesses, 4);

        // event-constant export is innermost-first
        let consts = mc.level_event_constants();
        assert_eq!(consts.len(), 2);
        assert!(consts[0].1 < consts[1].1, "inner fill (64 B) cheaper than outer (256 B)");
    }

    #[test]
    fn degenerate_stack_keeps_the_direct_dram_path() {
        let mut mc = MemoryController::new(&cfg(), &esram(), &[1000]);
        assert_eq!(mc.n_levels(), 0);
        assert!(mc.level_reports().is_empty());
        assert_eq!(mc.hier_miss_dram_cycles(), 0.0);
        assert!(mc.level_event_constants().is_empty());
        mc.factor_row_load(0, 7);
        assert_eq!(mc.dram.bytes_random, 64, "degenerate miss fetches the PE line");
    }

    #[test]
    fn fast_array_serializes_tag_then_data() {
        // O-SRAM (40× fabric speed) reads tags first, then only the
        // matching way: 16 data + 8 tag words per hit probe.
        let mut mc = MemoryController::new(&cfg(), &osram(), &[1000]);
        mc.factor_row_load(0, 1);
        let w_miss = mc.cache_words;
        mc.factor_row_load(0, 1);
        let w_hit = mc.cache_words - w_miss;
        assert_eq!(w_hit, 16 + 8);
        // ~3× fewer active bits per lookup than the E-SRAM path
        let mut me = MemoryController::new(&cfg(), &esram(), &[1000]);
        me.factor_row_load(0, 1);
        let we0 = me.cache_words;
        me.factor_row_load(0, 1);
        assert_eq!((me.cache_words - we0) / w_hit, 3);
    }

    /// The functional/timing contract: walk a stream directly on one
    /// controller, extract [`FunctionalCounts`], restore them into a
    /// fresh controller of the same geometry — every traffic counter
    /// and every derived busy figure must be bit-identical.
    #[test]
    fn counts_roundtrip_prices_bit_identically() {
        let mut shapes = vec![cfg()];
        let mut leveled = cfg();
        leveled.levels =
            crate::mem::hierarchy::parse_levels("outer:64KiB:line256,inner:4KiB").unwrap();
        leveled.validate().unwrap();
        shapes.push(leveled);
        let mut bypassing = cfg();
        bypassing.cache_bypass_factor = Some(1);
        shapes.push(bypassing);
        for c in &shapes {
            let rows = [(c.cache_lines * 2) as u64, 500, 300];
            let mut direct = MemoryController::new(c, &esram(), &rows);
            let mut x = 1u64;
            for _ in 0..4000 {
                // LCG-scrambled matrix/row pattern with real reuse
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let m = (x >> 33) as usize % rows.len();
                let r = ((x >> 16) % 512) as u32;
                direct.factor_row_load(m, r);
            }
            let counts = direct.counts();
            let mut priced = MemoryController::new(c, &esram(), &rows);
            priced.load_counts(&counts);
            assert_eq!(priced.cache_stats(), direct.cache_stats());
            assert_eq!(priced.cache_words, direct.cache_words);
            assert_eq!(priced.dma_words, direct.dma_words);
            assert_eq!(priced.dram.bytes_random, direct.dram.bytes_random);
            assert_eq!(priced.dram.random_accesses, direct.dram.random_accesses);
            for ci in 0..c.n_caches {
                assert_eq!(priced.cache_busy(ci).to_bits(), direct.cache_busy(ci).to_bits());
            }
            assert_eq!(priced.dram_busy().to_bits(), direct.dram_busy().to_bits());
            assert_eq!(priced.element_busy().to_bits(), direct.element_busy().to_bits());
            for i in 0..direct.n_levels() {
                assert_eq!(priced.level_busy(i).to_bits(), direct.level_busy(i).to_bits());
            }
            let (ra, rb) = (direct.level_reports(), priced.level_reports());
            assert_eq!(ra.len(), rb.len());
            for (a, b) in ra.iter().zip(&rb) {
                assert_eq!(a.accesses, b.accesses);
                assert_eq!(a.words, b.words);
                assert_eq!(a.busy_cycles.to_bits(), b.busy_cycles.to_bits());
            }
            // streams replay verbatim on the pricing path and commute
            // with the loaded counts
            direct.stream(1 << 16);
            priced.stream(1 << 16);
            assert_eq!(priced.dma_words, direct.dma_words);
            assert_eq!(priced.stream_busy.to_bits(), direct.stream_busy.to_bits());
            assert_eq!(priced.dram_busy().to_bits(), direct.dram_busy().to_bits());
        }
    }
}
