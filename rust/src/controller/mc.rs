//! Per-PE memory controller: functional caches + timing for the three
//! access types of §IV-A.
//!
//! 1. **Cache transfers** — random accesses with reuse potential (input
//!    factor rows). Routed to one of the `n_caches` set-associative
//!    caches (matrix → cache, round-robin as "each cache is shared with
//!    multiple input factor matrices").
//! 2. **DMA stream transfers** — sequential loads/stores (the mode-sorted
//!    tensor nonzeros in; output factor rows out).
//! 3. **DMA element-wise transfers** — no locality at all: factor matrices
//!    whose row space is hopeless for the cache (≫ capacity × bypass
//!    factor) bypass the cache so they neither pollute it nor pay tag
//!    overhead; they go straight to DRAM as independent bursts.

use crate::accel::config::AcceleratorConfig;
use crate::cache::cache::{Access, CacheStats, SetAssocCache};
use crate::cache::pipeline::{ArrayTiming, CacheTiming};
use crate::dma::elementwise::ElementDma;
use crate::dma::stream::StreamDma;
use crate::mem::dram::{DramChannelState, DramConfig};
use crate::mem::tech::MemTechnology;

/// How a factor-row access was served (for the engine's accounting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Served {
    CacheHit { cache: usize },
    CacheMiss { cache: usize, writeback: bool },
    Bypass,
}

/// Per-PE memory controller: functional + timing state.
pub struct MemoryController {
    pub tech: MemTechnology,
    pub caches: Vec<SetAssocCache>,
    pub cache_timing: CacheTiming,
    pub stream_dma: StreamDma,
    pub element_dma: ElementDma,
    pub dram_cfg: DramConfig,
    pub dram: DramChannelState,
    /// Busy cycles per cache (hit path + fill path share the arrays).
    pub cache_busy: Vec<f64>,
    /// Busy cycles of the stream/element DMA buffers.
    pub stream_busy: f64,
    pub element_busy: f64,
    /// Active-word counters for the Eq. 3 `S_active` energy terms.
    pub cache_words: u64,
    pub dma_words: u64,
    /// Matrices bypassing the cache (index = matrix slot).
    bypass: Vec<bool>,
    line_bytes: u64,
    /// Data-array ways read per lookup: `assoc` for synchronous arrays
    /// (speculative parallel way read, Fig. 6), 1 for fast arrays that
    /// serialize tag→data within a fabric cycle (energy model only; see
    /// `MemTechnology::serial_tag_data`).
    ways_read_per_lookup: u64,
    /// Tag words pulled per probe (all `assoc` candidate tags).
    tag_words_per_access: u64,
    // --- hoisted per-access constants (§Perf: computed once, the
    // factor_row_load fast path runs hundreds of millions of times) ---
    hit_occ: f64,
    fill_occ: f64,
    probe_words: u64,
    words_per_line: u64,
    miss_dram_cycles: f64,
}

/// A fabric-synchronous (electrical) cache's MEM pipeline sustains fewer
/// in-flight misses than a fast (optical-class) one, reducing the
/// effective bank-level overlap its DRAM channel achieves on miss bursts
/// (MSHR depth scales with the pipeline clock). Applied as a multiplier
/// on `DramConfig::random_overlap` whenever the technology fails the
/// [`MemTechnology::is_fast_array`] predicate.
pub const SLOW_ARRAY_MISS_OVERLAP_DERATE: f64 = 0.875;

impl MemoryController {
    /// Build a controller for one PE for an already-resolved (and, by the
    /// engine, already [`tuned`](AcceleratorConfig::tuned_tech))
    /// technology. `matrix_rows[j]` = row count of input factor matrix
    /// slot `j` (used for the §IV-A type-3 bypass routing decision when
    /// `cfg.cache_bypass_factor` is set).
    pub fn new(cfg: &AcceleratorConfig, tech: &MemTechnology, matrix_rows: &[u64]) -> Self {
        let t = tech;
        let banks = cfg.bank_factor(t);
        let cache_timing = CacheTiming::new(t, cfg.fabric_hz, banks, cfg.line_bytes);
        let buffer_timing = ArrayTiming::new(t, cfg.fabric_hz, banks);
        let caches = (0..cfg.n_caches)
            .map(|_| SetAssocCache::new(cfg.cache_sets(), cfg.cache_assoc))
            .collect();
        let capacity_lines = cfg.cache_lines as u64;
        let bypass = matrix_rows
            .iter()
            .map(|&rows| match cfg.cache_bypass_factor {
                Some(f) => rows > capacity_lines * f as u64,
                None => false,
            })
            .collect();
        let mut dram_cfg = cfg.dram.clone();
        if !t.is_fast_array(cfg.fabric_hz) {
            dram_cfg.random_overlap *= SLOW_ARRAY_MISS_OVERLAP_DERATE;
        }
        let ways_read = if t.serial_tag_data(cfg.fabric_hz) { 1 } else { cfg.cache_assoc as u64 };
        let words_per_line = (cfg.line_bytes / 4) as u64;
        let tag_words = cfg.cache_assoc as u64 * 2;
        MemoryController {
            tech: tech.clone(),
            caches,
            hit_occ: cache_timing.hit_occupancy(),
            fill_occ: cache_timing.fill_occupancy(),
            probe_words: tag_words + ways_read * words_per_line,
            words_per_line,
            miss_dram_cycles: dram_cfg.random_access_cycles(cfg.line_bytes as u64),
            cache_timing,
            stream_dma: StreamDma::new(buffer_timing.clone(), cfg.dma_buffer_bytes),
            element_dma: ElementDma::new(buffer_timing),
            dram_cfg,
            dram: DramChannelState::default(),
            cache_busy: vec![0.0; cfg.n_caches],
            stream_busy: 0.0,
            element_busy: 0.0,
            cache_words: 0,
            dma_words: 0,
            bypass,
            line_bytes: cfg.line_bytes as u64,
            ways_read_per_lookup: ways_read,
            tag_words_per_access: tag_words,
        }
    }

    /// Which cache serves factor-matrix slot `j`.
    #[inline]
    pub fn cache_of(&self, matrix: usize) -> usize {
        matrix % self.caches.len()
    }

    /// Is matrix slot `j` routed around the cache?
    pub fn is_bypassed(&self, matrix: usize) -> bool {
        self.bypass.get(matrix).copied().unwrap_or(false)
    }

    /// One factor-row load: the §IV-A type-1 (or type-3, if bypassed) path.
    /// Charges timing + traffic; returns how it was served.
    #[inline]
    pub fn factor_row_load(&mut self, matrix: usize, row: u32) -> Served {
        if self.is_bypassed(matrix) {
            let c = self.element_dma.access(&self.dram_cfg, self.line_bytes);
            self.dram.random_access(&self.dram_cfg, self.line_bytes);
            self.element_busy += c.buffer_cycles;
            self.dma_words += c.buffer_words;
            return Served::Bypass;
        }
        let ci = self.cache_of(matrix);
        let key = crate::cache::cache::row_key(matrix, row);
        // Fig. 6: every probe reads all `assoc` tags and, on a read hit,
        // `ways_read_per_lookup` data ways — active words for the energy
        // model include that fan-out even though the *timing* sees
        // parallel way banks (one line-time of occupancy). All occupancy
        // constants are hoisted into the controller (§Perf).
        match self.caches[ci].access(key, false) {
            Access::Hit => {
                self.cache_busy[ci] += self.hit_occ;
                self.cache_words += self.probe_words;
                Served::CacheHit { cache: ci }
            }
            Access::Miss { evicted_dirty } => {
                // probe + MEM-pipeline line fill (Fig. 5)
                self.cache_busy[ci] += self.hit_occ + self.fill_occ;
                self.cache_words += self.probe_words + self.words_per_line;
                self.dram.busy_cycles += self.miss_dram_cycles;
                self.dram.bytes_random += self.line_bytes;
                self.dram.random_accesses += 1;
                if evicted_dirty {
                    self.dram.busy_cycles += self.miss_dram_cycles;
                    self.dram.bytes_random += self.line_bytes;
                    self.dram.random_accesses += 1;
                    self.cache_words += self.words_per_line;
                }
                Served::CacheMiss { cache: ci, writeback: evicted_dirty }
            }
        }
    }

    /// Sequential stream of `bytes` (tensor in / output rows out):
    /// §IV-A type 2.
    pub fn stream(&mut self, bytes: u64) {
        let c = self.stream_dma.stream(&self.dram_cfg, bytes);
        self.dram.stream(&self.dram_cfg, bytes);
        self.stream_busy += c.buffer_cycles;
        self.dma_words += c.buffer_words;
    }

    /// Combined cache statistics across the subsystem.
    pub fn cache_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.caches {
            s.hits += c.stats.hits;
            s.misses += c.stats.misses;
            s.evictions += c.stats.evictions;
            s.writebacks += c.stats.writebacks;
        }
        s
    }

    /// Busiest single resource the controller owns, in cycles (the
    /// engine's bottleneck scan folds this in).
    pub fn max_busy(&self) -> f64 {
        let cache_max = self.cache_busy.iter().cloned().fold(0.0f64, f64::max);
        cache_max.max(self.dram.busy_cycles).max(self.stream_busy).max(self.element_busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default()
    }

    #[test]
    fn routing_matrix_to_cache_round_robin() {
        let mc = MemoryController::new(&cfg(), &esram(), &[100, 100, 100, 100]);
        assert_eq!(mc.cache_of(0), 0);
        assert_eq!(mc.cache_of(1), 1);
        assert_eq!(mc.cache_of(2), 2);
        assert_eq!(mc.cache_of(3), 0);
    }

    #[test]
    fn hit_and_miss_paths_charge_resources() {
        let mut mc = MemoryController::new(&cfg(), &esram(), &[1000]);
        let s1 = mc.factor_row_load(0, 7);
        assert!(matches!(s1, Served::CacheMiss { cache: 0, writeback: false }));
        let dram_after_miss = mc.dram.busy_cycles;
        assert!(dram_after_miss > 0.0);
        let s2 = mc.factor_row_load(0, 7);
        assert_eq!(s2, Served::CacheHit { cache: 0 });
        // hit adds cache busy but no dram
        assert_eq!(mc.dram.busy_cycles, dram_after_miss);
        assert!(mc.cache_busy[0] > 0.0);
        assert_eq!(mc.cache_stats().hits, 1);
        assert_eq!(mc.cache_stats().misses, 1);
    }

    #[test]
    fn bypass_off_by_default_routes_everything_to_cache() {
        let huge = u32::MAX as u64; // would bypass under any finite factor
        let mut mc = MemoryController::new(&cfg(), &esram(), &[huge]);
        assert!(!mc.is_bypassed(0));
        mc.factor_row_load(0, 3);
        assert_eq!(mc.cache_stats().accesses(), 1);
    }

    #[test]
    fn esram_miss_concurrency_derate_applies() {
        let me = MemoryController::new(&cfg(), &esram(), &[10]);
        let mo = MemoryController::new(&cfg(), &osram(), &[10]);
        assert!(me.dram_cfg.random_overlap < mo.dram_cfg.random_overlap);
        // stream bandwidth untouched
        assert_eq!(me.dram_cfg.stream_bytes_per_cycle(), mo.dram_cfg.stream_bytes_per_cycle());
    }

    #[test]
    fn huge_matrices_bypass_to_element_dma() {
        let mut c = cfg();
        c.cache_bypass_factor = Some(64);
        let huge = (c.cache_lines * 64 + 1) as u64;
        let cfg = move || c.clone();
        let mut mc = MemoryController::new(&cfg(), &esram(), &[huge, 100]);
        assert!(mc.is_bypassed(0));
        assert!(!mc.is_bypassed(1));
        assert_eq!(mc.factor_row_load(0, 3), Served::Bypass);
        // bypass never touches the caches
        assert_eq!(mc.cache_stats().accesses(), 0);
        assert!(mc.element_busy > 0.0);
        assert!(mc.dram.random_accesses == 1);
    }

    #[test]
    fn stream_charges_dram_and_buffer() {
        let mut mc = MemoryController::new(&cfg(), &osram(), &[10]);
        mc.stream(1 << 20);
        assert!(mc.dram.bytes_streamed == 1 << 20);
        assert!(mc.stream_busy > 0.0);
        assert!(mc.dma_words > 0);
    }

    #[test]
    fn osram_cache_busy_far_below_esram() {
        let mut me = MemoryController::new(&cfg(), &esram(), &[1000]);
        let mut mo = MemoryController::new(&cfg(), &osram(), &[1000]);
        for r in 0..1000u32 {
            me.factor_row_load(0, r % 50);
            mo.factor_row_load(0, r % 50);
        }
        assert!(me.cache_busy[0] > 10.0 * mo.cache_busy[0]);
        // functional behaviour identical: same hit counts
        assert_eq!(me.cache_stats(), mo.cache_stats());
    }

    #[test]
    fn energy_words_accumulate() {
        let mut mc = MemoryController::new(&cfg(), &esram(), &[1000]);
        mc.factor_row_load(0, 1); // miss: probe + fill words
        let w_miss = mc.cache_words;
        mc.factor_row_load(0, 1); // hit: probe words only
        let w_hit = mc.cache_words - w_miss;
        assert!(w_miss > w_hit);
        // synchronous E-SRAM reads all 4 ways speculatively:
        // 4×16 data + 4×2 tag = 72 words per probe (Table I assoc 4)
        assert_eq!(w_hit, 4 * 16 + 8);
    }

    #[test]
    fn fast_array_serializes_tag_then_data() {
        // O-SRAM (40× fabric speed) reads tags first, then only the
        // matching way: 16 data + 8 tag words per hit probe.
        let mut mc = MemoryController::new(&cfg(), &osram(), &[1000]);
        mc.factor_row_load(0, 1);
        let w_miss = mc.cache_words;
        mc.factor_row_load(0, 1);
        let w_hit = mc.cache_words - w_miss;
        assert_eq!(w_hit, 16 + 8);
        // ~3× fewer active bits per lookup than the E-SRAM path
        let mut me = MemoryController::new(&cfg(), &esram(), &[1000]);
        me.factor_row_load(0, 1);
        let we0 = me.cache_words;
        me.factor_row_load(0, 1);
        assert_eq!((me.cache_words - we0) / w_hit, 3);
    }
}
