//! The PE memory controller (§IV-A): routes each access class to the
//! right engine — caches for reusable factor rows, streaming DMA for
//! sequential tensor/output traffic, element-wise DMA for locality-free
//! accesses.
//!
//! [`mc::MemoryController`] is the shared functional + accounting core of
//! **both** simulation backends: the analytic engine
//! ([`crate::sim::engine`]) uses its accumulated busy totals directly,
//! and the event engine ([`crate::sim::event`]) replays the
//! [`mc::Served`] outcomes of the very same calls through arbitrated
//! bank/channel clocks. Traffic, hit rates and active-word counters are
//! therefore bit-identical across engines by construction.

pub mod mc;
