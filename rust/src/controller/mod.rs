//! The PE memory controller (§IV-A): routes each access class to the
//! right engine — caches for reusable factor rows, streaming DMA for
//! sequential tensor/output traffic, element-wise DMA for locality-free
//! accesses.

pub mod mc;
