//! # photon-mttkrp
//!
//! Reproduction of *"Performance Modeling Sparse MTTKRP Using Optical Static
//! Random Access Memory on FPGA"* (Wijeratne et al., 2022).
//!
//! The crate models a wafer-scale FPGA whose on-chip electrical SRAM
//! (BRAM/URAM) has been replaced by optical SRAM (O-SRAM: 20 GHz, 5 WDM
//! wavelengths, 200 concurrent 32-bit ports per 32 Kb block) and simulates a
//! sparse-MTTKRP accelerator (4 PEs × 80 parallel rank-R pipelines, a
//! 3-cache subsystem, stream/element DMAs, DDR4 external memory) on both
//! memory technologies, reproducing the paper's speedup (Fig. 7), energy
//! (Fig. 8, Table III) and area (Table IV) results.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — the accelerator simulator, energy/area models,
//!   tensor substrates, PE scheduler, CP-ALS driver, CLI, benches.
//! * **L2/L1 (build-time python)** — the MTTKRP block compute as a JAX
//!   graph wrapping a Pallas kernel, AOT-lowered to HLO text.
//! * **[`runtime`]** — loads `artifacts/*.hlo.txt` via the PJRT C API and
//!   executes them from the Rust hot path; python never runs at runtime.
//!
//! ## Quick start
//!
//! ```no_run
//! use photon_mttkrp::prelude::*;
//!
//! let tensor = frostt::preset(FrosttTensor::Nell2).scaled(1.0 / 256.0).generate(42);
//! let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 256.0);
//! let e = simulate_mode(&tensor, 0, &cfg, MemTech::ESram);
//! let o = simulate_mode(&tensor, 0, &cfg, MemTech::OSram);
//! println!("mode-0 speedup: {:.2}x", e.runtime_s() / o.runtime_s());
//! ```

pub mod accel;
pub mod area;
pub mod cache;
pub mod controller;
pub mod coordinator;
pub mod dma;
pub mod energy;
pub mod mem;
pub mod mttkrp;
pub mod pe;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::accel::config::AcceleratorConfig;
    pub use crate::area::model::AreaModel;
    pub use crate::coordinator::cpals::{cp_als, low_rank_tensor, CpAlsConfig};
    pub use crate::coordinator::driver::{
        compare_technologies, simulate_all_modes, simulate_mode, Compute,
    };
    pub use crate::energy::model::{EnergyBreakdown, EnergyModel};
    pub use crate::mem::tech::MemTech;
    pub use crate::mttkrp::reference::FactorMatrix;
    pub use crate::runtime::client::Runtime;
    pub use crate::sim::result::{ModeReport, SimReport};
    pub use crate::tensor::coo::SparseTensor;
    pub use crate::tensor::gen as frostt;
    pub use crate::tensor::gen::{FrosttTensor, TensorSpec};
}
