//! # photon-mttkrp
//!
//! Reproduction of *"Performance Modeling Sparse MTTKRP Using Optical Static
//! Random Access Memory on FPGA"* (Wijeratne et al., 2022) — grown into a
//! multi-technology, multi-engine design-space exploration simulator.
//!
//! The crate models a wafer-scale FPGA whose on-chip electrical SRAM
//! (BRAM/URAM) has been replaced by an alternative memory technology and
//! simulates a sparse-MTTKRP accelerator (4 PEs × 80 parallel rank-R
//! pipelines, a 3-cache subsystem, stream/element DMAs, DDR4 external
//! memory) on each of them, reproducing the paper's speedup (Fig. 7),
//! energy (Fig. 8, Table III) and area (Table IV) results for the
//! `e-sram`/`o-sram` pair.
//!
//! A module-by-module map of the crate, with dataflow diagrams of both
//! simulation engines tied to the paper's Fig. 4 / Algorithm 1 / Eq. 2–3,
//! lives in `docs/ARCHITECTURE.md` at the repository root; the
//! experiment-harness conventions and performance expectations live in
//! `EXPERIMENTS.md` alongside it. (Plain paths, not hyperlinks — the
//! rendered rustdoc tree does not ship those files.)
//!
//! ## Quick start
//!
//! ```no_run
//! use photon_mttkrp::prelude::*;
//!
//! let tensor = frostt::preset(FrosttTensor::Nell2).scaled(1.0 / 256.0).generate(42);
//! let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 256.0);
//! let e = simulate_mode(&tensor, 0, &cfg, &tech("e-sram"));
//! let o = simulate_mode(&tensor, 0, &cfg, &tech("o-sram"));
//! println!("mode-0 speedup: {:.2}x", e.runtime_s() / o.runtime_s());
//!
//! // cross-validate the analytic numbers with the event-driven engine:
//! for d in cross_validate(&tensor, &cfg, &registry::all()) {
//!     println!("{:<12} roofline error bound: +{:.1}%", d.tech, d.delta_pct());
//! }
//!
//! // any registered technology sweeps the same way, on either engine:
//! let mut spec = SweepSpec::new(
//!     vec![frostt::preset(FrosttTensor::Nell2)],
//!     vec![1.0 / 256.0],
//!     registry::all(),
//! );
//! spec.engine = EngineKind::Event;
//! let points = run_sweep(&spec).unwrap();
//! println!("{} scenarios", points.len());
//! ```
//!
//! ## Choosing a simulation engine
//!
//! Two backends implement [`sim::SimEngine`] and are selected by
//! [`sim::EngineKind`] (or `--engine analytic|event` on the CLI):
//!
//! * **`analytic`** ([`sim::engine`]) — the paper's own
//!   bottleneck/roofline model: every resource is assumed deeply
//!   pipelined and perfectly overlapped, a mode costs its busiest
//!   resource's total occupancy. Fastest; use it for large sweeps and for
//!   reproducing the paper's numbers.
//! * **`event`** ([`sim::event`]) — a cycle-level replay of the identical
//!   access stream through bank-arbitrated caches, a FIFO DRAM channel
//!   and windowed execution slots. It measures the queueing and
//!   bank-conflict stalls the roofline hides and reports them as
//!   [`sim::result::PeReport::stall_cycles`], so `event ≥ analytic`
//!   always holds and the delta is the analytic model's error bound on
//!   that workload. Use it whenever a headline number needs a trust
//!   interval ([`coordinator::driver::cross_validate`] automates the
//!   pairing).
//!
//! Both engines share the functional caches, the traffic/active-word
//! accounting and the [`sim::engine::partition_slices`] work split, so
//! hit rates and energy inputs are bit-identical between them — the
//! engines disagree only about *time*, which is exactly the quantity
//! under test.
//!
//! ## The kernel layer
//!
//! The *workload* is as pluggable as the memory technology: a
//! [`kernel::SparseKernel`] describes a sparse kernel as a chunked
//! access-stream IR (per-nonzero factor reads + slice boundaries,
//! generated in O(chunk) memory — never a materialized trace), its
//! per-nonzero execution charges and its closed-form totals. Both
//! engines consume only that interface. Builtins
//! ([`kernel::KernelKind`], `--kernel` on the CLI):
//!
//! | name       | workload                                              |
//! |------------|-------------------------------------------------------|
//! | `spmttkrp` | sparse MTTKRP (CP-ALS) — the paper's kernel, default  |
//! | `spttm`    | sparse Tucker TTM-chain (TTMc)                        |
//! | `spmm`     | sparse × dense matrix multiply (2-mode degenerate)    |
//!
//! The `spmttkrp` builtin is pinned **bit-identical** to the
//! pre-kernel-IR engines (`rust/tests/engine_agreement.rs`), so every
//! paper number is unchanged by the refactor.
//!
//! ## The technology registry
//!
//! Memory technologies are open, not a closed enum: every layer resolves a
//! [`mem::tech::MemTechnology`] parameter set by name through
//! [`mem::registry`]. Builtins:
//!
//! | name         | device                                                  |
//! |--------------|---------------------------------------------------------|
//! | `e-sram`     | electrical BRAM-class SRAM — the paper's baseline       |
//! | `o-sram`     | optical SRAM of ref. 14: 20 GHz, 5λ WDM, 200 ports/block |
//! | `o-sram-imc` | photonic in-memory-computing SRAM (arXiv 2503.18206)    |
//! | `e-uram`     | URAM288-class electrical SRAM: denser, still port-bound |
//!
//! `[tech.<name>]` sections in a config file register further entries
//! (see [`mem::registry::TechRegistry::load_config`]), and code can
//! register any [`mem::registry::TechSpec`] implementation. Both engines
//! are closed over the registry: any entry — builtin, config-file or
//! programmatic — simulates on either backend with no per-name code.
//!
//! ## Design-space exploration
//!
//! [`explore`] searches the hardware design space instead of replaying
//! one point: a [`explore::DesignSpace`] axis grammar over
//! [`accel::config::AcceleratorConfig`] knobs × technologies × kernels
//! is screened on the analytic engine, the Pareto frontier over
//! (runtime, energy, area) is extracted, the **whole grid** is confirmed
//! on the event engine under chunk sampling ([`sim::SampleSpec`]), and
//! an exact event pass pins the reported frontier numbers — any rank
//! flip, exact or sampled, is surfaced as an
//! [`explore::ExploreDelta`], never silently dropped. Evaluations are
//! memoized in a content-keyed [`explore::EvalCache`] shared across
//! searches — optionally persistent on disk ([`explore::store`],
//! `--cache-dir`), so a warm re-run answers without simulating.
//! Front-ends: `photon-mttkrp explore`, the `design_space`
//! example, and the frontier table `reproduce` prints.
//!
//! ## The serving layer
//!
//! [`serve`] turns the evaluator into a long-lived daemon
//! (`photon-mttkrp serve`): newline-delimited JSON requests on stdin or
//! a Unix socket, answered in order, with batch windows that share
//! workload preparation and a persistent cache that makes warm traffic
//! O(hash lookup) — byte-identical `"result"` payloads, cold or warm.
//!
//! ## The sweep engine and host parallelism
//!
//! [`sim::sweep`] fans the cartesian product of
//! {tensor × mode × technology × scale} across OS threads with
//! deterministic result ordering, on either simulation backend — the
//! `photon-mttkrp sweep` subcommand and the `design_space` example are
//! its front-ends. One level down, both engines fan their independent
//! per-PE walks across threads too, and the two levels share one
//! [`sim::SimBudget`] thread budget so they compose without
//! oversubscription (`--threads`/`--chunk-nnz` on the CLI). Every host
//! knob is bit-transparent: any thread count and chunk size reproduce
//! identical reports. The one deliberate exception is `--sample-rate`
//! ([`sim::SampleSpec`]): below 1.0 the event engine times a seeded
//! subset of chunks and extrapolates stalls with a confidence band —
//! still deterministic at any thread count, but a different estimate
//! than the exact replay.
//!
//! ## Observability
//!
//! [`obs`] watches the simulator's own performance without perturbing
//! it: RAII [`obs::Span`]s over a process-anchored monotonic clock
//! (explore phases, stream walks, engine mode runs, daemon batch
//! windows), a process-wide [`obs::metrics::Registry`] of counters /
//! gauges / log2 histograms (cache hits, walk counts, request
//! latencies), Chrome trace-event export (`--trace-out trace.json`,
//! loadable in Perfetto), a Prometheus-style exposition, and one
//! structured stderr log helper (`--log-json`, `PHOTON_LOG`). The
//! recorder is disabled by default and merges parallel workers'
//! events slot-ordered, so golden bit-identity holds with tracing on.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — the accelerator simulator (both engines),
//!   energy/area models, tensor substrates, PE scheduler, CP-ALS driver,
//!   CLI, benches.
//! * **L2/L1 (build-time python)** — the MTTKRP block compute as a JAX
//!   graph wrapping a Pallas kernel, AOT-lowered to HLO text.
//! * **[`runtime`]** — loads `artifacts/*.hlo.txt` via the PJRT C API and
//!   executes them from the Rust hot path; python never runs at runtime.
//!   (Built as a stub unless the `photon_pjrt` cfg enables the XLA bindings.)

pub mod accel;
pub mod area;
pub mod cache;
pub mod controller;
pub mod coordinator;
pub mod dma;
pub mod energy;
pub mod explore;
pub mod kernel;
pub mod mem;
pub mod mttkrp;
pub mod obs;
pub mod pe;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::accel::config::AcceleratorConfig;
    pub use crate::area::model::AreaModel;
    pub use crate::coordinator::cpals::{cp_als, low_rank_tensor, CpAlsConfig};
    pub use crate::coordinator::driver::{
        compare_all_registered, compare_paper_pair, compare_paper_pair_with_engine,
        compare_technologies, compare_technologies_on_engines, compare_technologies_with_budget,
        compare_technologies_with_engine, compare_technologies_with_kernel, cross_validate,
        cross_validate_kernel, paper_pair, simulate_all_modes, simulate_all_modes_with_engine,
        simulate_all_modes_with_kernel, simulate_mode, simulate_mode_with_engine,
        simulate_mode_with_kernel, Compute, EngineDelta, TechComparison, TechRun,
    };
    pub use crate::energy::model::{EnergyBreakdown, EnergyModel};
    pub use crate::explore::{
        frontier_table, run_explore, run_explore_with_cache, Axis, DesignSpace, EvalCache,
        ExploreResult, ExploreSpec, Knob, ObjectiveKind, Objectives,
    };
    pub use crate::kernel::{KernelKind, KernelTotals, SparseKernel};
    pub use crate::mem::hierarchy::{format_levels, parse_levels, LevelReport, MemLevelSpec};
    pub use crate::mem::registry::{self, tech, TechRegistry, TechSpec};
    pub use crate::mem::tech::MemTechnology;
    pub use crate::mttkrp::reference::FactorMatrix;
    pub use crate::runtime::client::Runtime;
    pub use crate::sim::result::{ModeReport, SimReport};
    pub use crate::sim::sweep::{run_sweep, summary_table, SweepPoint, SweepSpec};
    pub use crate::sim::{EngineKind, SampleSpec, SimBudget, SimEngine};
    pub use crate::tensor::coo::SparseTensor;
    pub use crate::tensor::gen as frostt;
    pub use crate::tensor::gen::{FrosttTensor, TensorSpec};
}
