//! # photon-mttkrp
//!
//! Reproduction of *"Performance Modeling Sparse MTTKRP Using Optical Static
//! Random Access Memory on FPGA"* (Wijeratne et al., 2022) — grown into a
//! multi-technology design-space exploration engine.
//!
//! The crate models a wafer-scale FPGA whose on-chip electrical SRAM
//! (BRAM/URAM) has been replaced by an alternative memory technology and
//! simulates a sparse-MTTKRP accelerator (4 PEs × 80 parallel rank-R
//! pipelines, a 3-cache subsystem, stream/element DMAs, DDR4 external
//! memory) on each of them, reproducing the paper's speedup (Fig. 7),
//! energy (Fig. 8, Table III) and area (Table IV) results for the
//! `e-sram`/`o-sram` pair.
//!
//! ## The technology registry
//!
//! Memory technologies are open, not a closed enum: every layer resolves a
//! [`mem::tech::MemTechnology`] parameter set by name through
//! [`mem::registry`]. Builtins:
//!
//! | name         | device                                                  |
//! |--------------|---------------------------------------------------------|
//! | `e-sram`     | electrical BRAM-class SRAM — the paper's baseline       |
//! | `o-sram`     | optical SRAM of [14]: 20 GHz, 5λ WDM, 200 ports/block   |
//! | `o-sram-imc` | photonic in-memory-computing SRAM (arXiv 2503.18206)    |
//! | `e-uram`     | URAM288-class electrical SRAM: denser, still port-bound |
//!
//! `[tech.<name>]` sections in a config file register further entries
//! (see [`mem::registry::TechRegistry::load_config`]), and code can
//! register any [`mem::registry::TechSpec`] implementation.
//!
//! ## The sweep engine
//!
//! [`sim::sweep`] fans the cartesian product of
//! {tensor × mode × technology × scale} across OS threads with
//! deterministic result ordering — the `photon-mttkrp sweep` subcommand
//! and the `design_space` example are its front-ends.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — the accelerator simulator, energy/area models,
//!   tensor substrates, PE scheduler, CP-ALS driver, CLI, benches.
//! * **L2/L1 (build-time python)** — the MTTKRP block compute as a JAX
//!   graph wrapping a Pallas kernel, AOT-lowered to HLO text.
//! * **[`runtime`]** — loads `artifacts/*.hlo.txt` via the PJRT C API and
//!   executes them from the Rust hot path; python never runs at runtime.
//!   (Built as a stub unless the `photon_pjrt` cfg enables the XLA bindings.)
//!
//! ## Quick start
//!
//! ```no_run
//! use photon_mttkrp::prelude::*;
//!
//! let tensor = frostt::preset(FrosttTensor::Nell2).scaled(1.0 / 256.0).generate(42);
//! let cfg = AcceleratorConfig::paper_default().scaled(1.0 / 256.0);
//! let e = simulate_mode(&tensor, 0, &cfg, &tech("e-sram"));
//! let o = simulate_mode(&tensor, 0, &cfg, &tech("o-sram"));
//! println!("mode-0 speedup: {:.2}x", e.runtime_s() / o.runtime_s());
//!
//! // any registered technology sweeps the same way:
//! let spec = SweepSpec::new(
//!     vec![frostt::preset(FrosttTensor::Nell2)],
//!     vec![1.0 / 256.0],
//!     registry::all(),
//! );
//! let points = run_sweep(&spec).unwrap();
//! println!("{} scenarios", points.len());
//! ```

pub mod accel;
pub mod area;
pub mod cache;
pub mod controller;
pub mod coordinator;
pub mod dma;
pub mod energy;
pub mod mem;
pub mod mttkrp;
pub mod pe;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::accel::config::AcceleratorConfig;
    pub use crate::area::model::AreaModel;
    pub use crate::coordinator::cpals::{cp_als, low_rank_tensor, CpAlsConfig};
    pub use crate::coordinator::driver::{
        compare_all_registered, compare_paper_pair, compare_technologies, simulate_all_modes,
        simulate_mode, Compute, TechComparison, TechRun,
    };
    pub use crate::energy::model::{EnergyBreakdown, EnergyModel};
    pub use crate::mem::registry::{self, tech, TechRegistry, TechSpec};
    pub use crate::mem::tech::MemTechnology;
    pub use crate::mttkrp::reference::FactorMatrix;
    pub use crate::runtime::client::Runtime;
    pub use crate::sim::result::{ModeReport, SimReport};
    pub use crate::sim::sweep::{run_sweep, summary_table, SweepPoint, SweepSpec};
    pub use crate::tensor::coo::SparseTensor;
    pub use crate::tensor::gen as frostt;
    pub use crate::tensor::gen::{FrosttTensor, TensorSpec};
}
