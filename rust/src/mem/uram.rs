//! UltraRAM-class electrical SRAM (`e-uram`) device parameters.
//!
//! The second electrical design point of a data-center FPGA: the deep,
//! dense URAM288-style block (Alveo U250-class, §V-A's platform). Compared
//! to the BRAM-class `e-sram` baseline it is:
//!
//! * **denser** — an 8T high-density macro at ~0.65× the BRAM-class area
//!   per bit (the periphery amortizes over a 288 Kb block);
//! * **slower to access** — the deep array is internally pipelined with a
//!   2-cycle read latency at the fabric clock;
//! * **cheaper to keep, costlier to swing** — leakage per bit drops
//!   slightly (fewer peripheral circuits per bit) while the long bit lines
//!   raise the per-access switching energy.
//!
//! It exists so the registry ships more than one *electrical* point: the
//! programmable-memory-controller design-space work (arXiv 2207.08298)
//! tunes exactly this BRAM/URAM split, and the sweep engine can now cover
//! it without touching any consumer layer.

use crate::mem::esram::{
    ESRAM_AREA_UM2_PER_BIT, ESRAM_PORT_WIDTH, ESRAM_PORTS, ESRAM_STATIC_PJ_PER_BIT_CYCLE,
};
use crate::mem::tech::{MemTechnology, FABRIC_HZ};

/// Synchronous with the 500 MHz fabric, like all electrical arrays here.
pub const URAM_FREQ_HZ: f64 = FABRIC_HZ;
/// URAM288: 288 Kb per block (4096 × 72 b).
pub const URAM_BLOCK_BITS: u64 = 288 * 1024;
/// 4096 word lines per block.
pub const URAM_DATA_LINES: u32 = 4096;
/// Internally pipelined deep array: 2-cycle access at the fabric clock.
pub const URAM_ACCESS_LATENCY_CYCLES: u32 = 2;

/// Slightly lower leakage per bit than the BRAM-class macro.
pub const URAM_STATIC_PJ_PER_BIT_CYCLE: f64 = ESRAM_STATIC_PJ_PER_BIT_CYCLE * 0.9;
/// Long bit lines: higher switching than the 4.68 pJ/bit baseline, with
/// the same bitline/sense-amp-dominated Eq. 3 split.
pub const URAM_CONVERSION_PJ_PER_BIT: f64 = 4.32;
pub const URAM_STORAGE_PJ_PER_BIT: f64 = 0.88;
pub const URAM_SWITCHING_PJ_PER_BIT: f64 =
    URAM_CONVERSION_PJ_PER_BIT + URAM_STORAGE_PJ_PER_BIT;

/// High-density macro: ~0.65× the BRAM-class area per bit.
pub const URAM_AREA_UM2_PER_BIT: f64 = ESRAM_AREA_UM2_PER_BIT * 0.65;

/// The E-URAM `MemTechnology` parameter set.
pub fn uram() -> MemTechnology {
    MemTechnology {
        name: "e-uram".to_string(),
        freq_hz: URAM_FREQ_HZ,
        wavelengths: 1,
        lanes_per_core_cycle: ESRAM_PORTS,
        port_width_bits: ESRAM_PORT_WIDTH,
        ports_per_block: ESRAM_PORTS,
        block_bits: URAM_BLOCK_BITS,
        data_lines: URAM_DATA_LINES,
        access_latency_cycles: URAM_ACCESS_LATENCY_CYCLES,
        static_pj_per_bit_cycle: URAM_STATIC_PJ_PER_BIT_CYCLE,
        switching_pj_per_bit: URAM_SWITCHING_PJ_PER_BIT,
        conversion_pj_per_bit: URAM_CONVERSION_PJ_PER_BIT,
        storage_pj_per_bit: URAM_STORAGE_PJ_PER_BIT,
        area_um2_per_bit: URAM_AREA_UM2_PER_BIT,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;

    #[test]
    fn denser_but_hotter_than_bram() {
        let u = uram();
        let e = esram();
        assert!(u.area_um2_per_bit < e.area_um2_per_bit);
        assert!(u.switching_pj_per_bit > e.switching_pj_per_bit);
        assert!(u.static_pj_per_bit_cycle < e.static_pj_per_bit_cycle);
    }

    #[test]
    fn same_port_throughput_as_bram() {
        // the dual-port electrical bottleneck is the point of the paper's
        // comparison; URAM changes density/energy, not port count
        let u = uram();
        assert!((u.words_per_fabric_cycle(FABRIC_HZ) - 2.0).abs() < 1e-12);
        assert!(!u.is_fast_array(FABRIC_HZ));
    }

    #[test]
    fn block_geometry_is_uram288() {
        assert_eq!(URAM_BLOCK_BITS, 294_912);
        let u = uram();
        assert!(u.blocks_for_bits(URAM_BLOCK_BITS) == 1);
        assert!(u.blocks_for_bits(URAM_BLOCK_BITS + 1) == 2);
    }

    #[test]
    fn eq3_decomposition_sums() {
        let u = uram();
        assert!(
            (u.conversion_pj_per_bit + u.storage_pj_per_bit - u.switching_pj_per_bit).abs() < 1e-12
        );
    }
}
