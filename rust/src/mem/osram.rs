//! O-SRAM device parameters (paper §II, §III-A, Table III, Table IV).
//!
//! The optical SRAM of [14]: a bistable element of photodiodes + microring
//! resonators storing complementary optical levels, accessed through
//! wordline/bit waveguides, sensed by electro-optic sense amplifiers
//! (Fig. 1). Headline properties used by the model:
//!
//! * 20 GHz operating frequency (§III-A);
//! * 5 WDM wavelengths ⇒ concurrent same-block access (§III-A);
//! * one block stores 32 Kb as 1024 × 32-bit data lines with 200 parallel
//!   32-bit read/write ports (§III-A, Fig. 2) — note 200 = λ·f_opt/f_elec;
//! * Table III energies: static 4.17e-6 pJ/bit/cycle, switching 1.04 pJ/bit;
//! * Table IV area: 103.7×10⁴ mm² for 54 MB ⇒ ≈ 2289 µm²/bit (the "over
//!   three orders of magnitude larger than E-SRAM" bit-cell of §II).

use crate::mem::tech::MemTechnology;

/// O-SRAM core frequency (§III-A).
pub const OSRAM_FREQ_HZ: f64 = 20e9;
/// WDM wavelengths λ (§III-A: "typically 5").
pub const OSRAM_WAVELENGTHS: u32 = 5;
/// Port width z (§III-A: 32-bit data lines / ports).
pub const OSRAM_PORT_WIDTH: u32 = 32;
/// Parallel read/write ports per block (§III-A).
pub const OSRAM_PORTS: u32 = 200;
/// Block capacity: 32 Kb (§III-A).
pub const OSRAM_BLOCK_BITS: u64 = 32 * 1024;
/// Data lines per block (§III-A: 1024 lines × 32 b).
pub const OSRAM_DATA_LINES: u32 = 1024;

/// Table III, optical technology column.
pub const OSRAM_STATIC_PJ_PER_BIT_CYCLE: f64 = 4.17e-6;
pub const OSRAM_SWITCHING_PJ_PER_BIT: f64 = 1.04;
/// Eq. 3 split of the 1.04 pJ/bit switching energy. The O→E interface
/// (electro-optic sense amplifier + E→O modulator, SPICE-simulated in the
/// paper) dominates; the reverse-biased photodiode/MRR storage cell itself
/// switches nearly for free. 0.90 / 0.14 keeps the published total while
/// exposing both Eq. 3 terms to ablation.
pub const OSRAM_CONVERSION_PJ_PER_BIT: f64 = 0.90;
pub const OSRAM_STORAGE_PJ_PER_BIT: f64 = 0.14;

/// Table IV: 54 MB of O-SRAM occupy 103.7×10⁴ mm².
pub const OSRAM_AREA_UM2_PER_BIT: f64 = 103.7e4 * 1e6 / (54.0 * 1024.0 * 1024.0 * 8.0);

/// Access latency in 20 GHz core cycles: wordline waveguide pulse + bit
/// waveguide traversal + sense amplifier, ≈ 2 core cycles (100 ps) — the
/// "ultra-fast" property of §II; any value under one fabric cycle is
/// equivalent at system level.
pub const OSRAM_ACCESS_LATENCY_CYCLES: u32 = 2;

/// The O-SRAM `MemTechnology` parameter set.
pub fn osram() -> MemTechnology {
    MemTechnology {
        name: "o-sram".to_string(),
        freq_hz: OSRAM_FREQ_HZ,
        wavelengths: OSRAM_WAVELENGTHS,
        lanes_per_core_cycle: OSRAM_WAVELENGTHS,
        port_width_bits: OSRAM_PORT_WIDTH,
        ports_per_block: OSRAM_PORTS,
        block_bits: OSRAM_BLOCK_BITS,
        data_lines: OSRAM_DATA_LINES,
        access_latency_cycles: OSRAM_ACCESS_LATENCY_CYCLES,
        static_pj_per_bit_cycle: OSRAM_STATIC_PJ_PER_BIT_CYCLE,
        switching_pj_per_bit: OSRAM_SWITCHING_PJ_PER_BIT,
        conversion_pj_per_bit: OSRAM_CONVERSION_PJ_PER_BIT,
        storage_pj_per_bit: OSRAM_STORAGE_PJ_PER_BIT,
        area_um2_per_bit: OSRAM_AREA_UM2_PER_BIT,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;

    #[test]
    fn block_geometry_consistent() {
        // 1024 data lines × 32 b = 32 Kb (§III-A's numbers are consistent)
        assert_eq!(OSRAM_DATA_LINES as u64 * OSRAM_PORT_WIDTH as u64, OSRAM_BLOCK_BITS);
    }

    #[test]
    fn ports_equal_lambda_times_clock_ratio() {
        // 200 = 5 × (20 GHz / 500 MHz)
        let ratio = OSRAM_FREQ_HZ / crate::mem::tech::FABRIC_HZ;
        assert_eq!(OSRAM_PORTS as f64, OSRAM_WAVELENGTHS as f64 * ratio);
    }

    #[test]
    fn table_iv_area_roundtrips() {
        // 54 MB at the derived per-bit area must reproduce 103.7e4 mm²
        let bits = 54u64 * 1024 * 1024 * 8;
        let area = osram().area_mm2(bits);
        assert!((area - 103.7e4).abs() / 103.7e4 < 1e-9, "area={area}");
    }

    #[test]
    fn over_three_orders_larger_than_esram() {
        let ratio = OSRAM_AREA_UM2_PER_BIT / esram().area_um2_per_bit;
        assert!(ratio > 1e3, "O/E area ratio {ratio}");
    }

    #[test]
    fn table_iii_constants() {
        let o = osram();
        assert_eq!(o.static_pj_per_bit_cycle, 4.17e-6);
        assert_eq!(o.switching_pj_per_bit, 1.04);
        // optical switches cheaper, leaks more, than electrical (Table III)
        let e = esram();
        assert!(o.switching_pj_per_bit < e.switching_pj_per_bit);
        assert!(o.static_pj_per_bit_cycle > e.static_pj_per_bit_cycle);
    }

    #[test]
    fn access_is_subnanosecond() {
        let t = OSRAM_ACCESS_LATENCY_CYCLES as f64 / OSRAM_FREQ_HZ;
        assert!(t < 1e-9, "O-SRAM access {t}s");
    }
}
