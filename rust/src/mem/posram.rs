//! Photonic in-memory-computing SRAM (`o-sram-imc`) device parameters.
//!
//! Models the pSRAM-based in-memory-computing array of the follow-up work
//! *Predictive Performance of Photonic SRAM-based In-Memory Computing for
//! Tensor Decomposition* (arXiv 2503.18206): the same microring-resonator
//! bistable cell as the O-SRAM of [14], but with the multiply-accumulate
//! moved into the optical domain so an access avoids one full
//! optical→electrical conversion per operand. Modeled consequences:
//!
//! * wider WDM comb (8 wavelengths vs the O-SRAM's 5) — the IMC array is
//!   laid out for operand broadcast, which amortizes the comb laser;
//! * lower switching energy (0.62 pJ/bit vs 1.04): the dominant Eq. 3
//!   conversion term shrinks because partial products stay optical;
//! * higher static power (5.21e-6 pJ/bit/cycle): the always-on comb laser
//!   and bias of the in-array modulators leak more than plain storage;
//! * larger bit cell (~1.3× the O-SRAM footprint): the per-column
//!   photonic MAC periphery is area the plain array does not pay.
//!
//! These are *derived estimates* anchored on the published O-SRAM numbers,
//! not digitized values from 2503.18206 — the registry keeps them in one
//! place so refinement touches only this file.

use crate::mem::osram::{
    OSRAM_AREA_UM2_PER_BIT, OSRAM_BLOCK_BITS, OSRAM_DATA_LINES, OSRAM_FREQ_HZ, OSRAM_PORT_WIDTH,
};
use crate::mem::tech::MemTechnology;

/// Same 20 GHz optical core clock as the base O-SRAM device.
pub const OSRAM_IMC_FREQ_HZ: f64 = OSRAM_FREQ_HZ;
/// Wider WDM comb: 8 wavelengths for operand broadcast.
pub const OSRAM_IMC_WAVELENGTHS: u32 = 8;
/// Parallel ports per block: λ × f_opt / f_elec = 8 × 40 = 320 (the Eq. 1
/// relation is asserted in the tests below).
pub const OSRAM_IMC_PORTS: u32 = 320;

/// Static power: comb laser + in-array modulator bias on top of the
/// O-SRAM's 4.17e-6 pJ/bit/cycle.
pub const OSRAM_IMC_STATIC_PJ_PER_BIT_CYCLE: f64 = 5.21e-6;
/// Switching energy per bit, with the Eq. 3 conversion term reduced —
/// partial products stay in the optical domain.
pub const OSRAM_IMC_CONVERSION_PJ_PER_BIT: f64 = 0.48;
pub const OSRAM_IMC_STORAGE_PJ_PER_BIT: f64 = 0.14;
pub const OSRAM_IMC_SWITCHING_PJ_PER_BIT: f64 =
    OSRAM_IMC_CONVERSION_PJ_PER_BIT + OSRAM_IMC_STORAGE_PJ_PER_BIT;

/// Bit-cell + MAC periphery area: ~1.3× the plain O-SRAM cell.
pub const OSRAM_IMC_AREA_UM2_PER_BIT: f64 = OSRAM_AREA_UM2_PER_BIT * 1.3;

/// One extra core cycle over the O-SRAM's 2: the in-array MAC stage.
pub const OSRAM_IMC_ACCESS_LATENCY_CYCLES: u32 = 3;

/// The photonic-IMC `MemTechnology` parameter set.
pub fn osram_imc() -> MemTechnology {
    MemTechnology {
        name: "o-sram-imc".to_string(),
        freq_hz: OSRAM_IMC_FREQ_HZ,
        wavelengths: OSRAM_IMC_WAVELENGTHS,
        lanes_per_core_cycle: OSRAM_IMC_WAVELENGTHS,
        port_width_bits: OSRAM_PORT_WIDTH,
        ports_per_block: OSRAM_IMC_PORTS,
        block_bits: OSRAM_BLOCK_BITS,
        data_lines: OSRAM_DATA_LINES,
        access_latency_cycles: OSRAM_IMC_ACCESS_LATENCY_CYCLES,
        static_pj_per_bit_cycle: OSRAM_IMC_STATIC_PJ_PER_BIT_CYCLE,
        switching_pj_per_bit: OSRAM_IMC_SWITCHING_PJ_PER_BIT,
        conversion_pj_per_bit: OSRAM_IMC_CONVERSION_PJ_PER_BIT,
        storage_pj_per_bit: OSRAM_IMC_STORAGE_PJ_PER_BIT,
        area_um2_per_bit: OSRAM_IMC_AREA_UM2_PER_BIT,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;

    #[test]
    fn ports_follow_eq1() {
        assert_eq!(OSRAM_IMC_PORTS, 320);
        let t = osram_imc();
        assert_eq!(
            t.ports_per_block as f64,
            t.lanes_per_core_cycle as f64 * t.freq_hz / FABRIC_HZ
        );
    }

    #[test]
    fn imc_trades_static_for_switching() {
        let imc = osram_imc();
        let o = osram();
        assert!(imc.switching_pj_per_bit < o.switching_pj_per_bit);
        assert!(imc.static_pj_per_bit_cycle > o.static_pj_per_bit_cycle);
        assert!(imc.area_um2_per_bit > o.area_um2_per_bit);
    }

    #[test]
    fn eq3_decomposition_sums() {
        let t = osram_imc();
        assert!(
            (t.conversion_pj_per_bit + t.storage_pj_per_bit - t.switching_pj_per_bit).abs() < 1e-12
        );
    }

    #[test]
    fn higher_bandwidth_than_base_osram() {
        let imc = osram_imc();
        let o = osram();
        assert!(
            imc.words_per_fabric_cycle(FABRIC_HZ) > o.words_per_fabric_cycle(FABRIC_HZ),
            "8λ must out-deliver 5λ"
        );
        assert!(imc.is_fast_array(FABRIC_HZ));
    }
}
