//! Clock-domain synchronization interface (Fig. 2: "An O-SRAM uses a
//! synchronization interface to connect with the configurable mesh due to
//! the operation frequency difference between electrical compute components
//! and optical memory components").
//!
//! Modeled as a dual-clock FIFO: a request crossing from the 500 MHz mesh
//! into the 20 GHz memory domain (and its response crossing back) pays a
//! fixed synchronizer latency per direction, and the interface throughput
//! is bounded by Eq. 1's `b_process` on the memory side and by the mesh
//! port width on the fabric side. For E-SRAM (synchronous) the crossing
//! cost is zero.

use crate::mem::tech::MemTechnology;

/// A clock domain with frequency in Hz.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClockDomain {
    pub hz: f64,
}

impl ClockDomain {
    pub fn new(hz: f64) -> Self {
        assert!(hz > 0.0);
        ClockDomain { hz }
    }

    /// Convert a cycle count in this domain to seconds.
    pub fn to_seconds(&self, cycles: f64) -> f64 {
        cycles / self.hz
    }

    /// Convert seconds to cycles of this domain.
    pub fn cycles(&self, seconds: f64) -> f64 {
        seconds * self.hz
    }

    /// Convert cycles of `self` to cycles of `other`.
    pub fn convert(&self, cycles: f64, other: &ClockDomain) -> f64 {
        cycles * other.hz / self.hz
    }
}

/// The mesh↔memory synchronization interface for one memory technology.
#[derive(Clone, Debug)]
pub struct SyncInterface {
    pub fabric: ClockDomain,
    pub memory: ClockDomain,
    /// Dual-clock FIFO synchronizer depth, in *fabric* cycles per crossing
    /// direction (2-flop synchronizer ⇒ 2 cycles of the receiving clock;
    /// the receiving clock for requests is the fast memory clock — free —
    /// and for responses the fabric clock — 2 cycles).
    pub crossing_fabric_cycles: f64,
}

impl SyncInterface {
    /// Build the interface for a memory technology at a given fabric clock.
    pub fn new(tech: &MemTechnology, fabric_hz: f64) -> Self {
        let synchronous = (tech.freq_hz - fabric_hz).abs() < 1.0;
        SyncInterface {
            fabric: ClockDomain::new(fabric_hz),
            memory: ClockDomain::new(tech.freq_hz),
            // asynchronous domains pay a 2-flop synchronizer on the
            // response path; synchronous arrays pay nothing.
            crossing_fabric_cycles: if synchronous { 0.0 } else { 2.0 },
        }
    }

    /// Round-trip latency of one memory access seen from the fabric, in
    /// fabric cycles: request crossing + array access + response crossing.
    pub fn round_trip_fabric_cycles(&self, tech: &MemTechnology) -> f64 {
        let array = tech.access_latency_cycles as f64 * self.fabric.hz / self.memory.hz;
        let floor = if self.crossing_fabric_cycles == 0.0 { 1.0 } else { 0.0 };
        self.crossing_fabric_cycles + array.max(floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;
    use crate::mem::tech::FABRIC_HZ;

    #[test]
    fn clock_conversions() {
        let fast = ClockDomain::new(20e9);
        let slow = ClockDomain::new(500e6);
        assert!((fast.convert(40.0, &slow) - 1.0).abs() < 1e-12);
        assert!((slow.convert(1.0, &fast) - 40.0).abs() < 1e-12);
        assert!((slow.to_seconds(500e6) - 1.0).abs() < 1e-12);
        assert!((slow.cycles(2e-9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn esram_crossing_is_free() {
        let e = esram();
        let s = SyncInterface::new(&e, FABRIC_HZ);
        assert_eq!(s.crossing_fabric_cycles, 0.0);
        // synchronous round trip = the array's own latency
        assert_eq!(s.round_trip_fabric_cycles(&e), 1.0);
    }

    #[test]
    fn osram_pays_synchronizer_but_still_fast() {
        let o = osram();
        let s = SyncInterface::new(&o, FABRIC_HZ);
        assert_eq!(s.crossing_fabric_cycles, 2.0);
        let rt = s.round_trip_fabric_cycles(&o);
        // 2 fabric cycles of synchronizer + 0.05 of array ≈ 2.05
        assert!(rt > 2.0 && rt < 2.1, "rt={rt}");
    }

    #[test]
    fn osram_round_trip_longer_than_esram_latency_but_bandwidth_wins() {
        // the paper's design hides the crossing latency behind the two
        // pipelines (Figs. 5–6); the model must still expose it honestly.
        let e = esram();
        let o = osram();
        let se = SyncInterface::new(&e, FABRIC_HZ);
        let so = SyncInterface::new(&o, FABRIC_HZ);
        assert!(so.round_trip_fabric_cycles(&o) > se.round_trip_fabric_cycles(&e));
        assert!(o.words_per_fabric_cycle(FABRIC_HZ) > 50.0 * e.words_per_fabric_cycle(FABRIC_HZ));
    }
}
