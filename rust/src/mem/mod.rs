//! Memory technology models (paper §II–III) and the open registry.
//!
//! * [`tech`] — the [`tech::MemTechnology`] device model shared by every
//!   SRAM variant: frequency, WDM wavelengths, ports, Eq. 1 bandwidth,
//!   Table III per-bit energies, Table IV per-bit area.
//! * [`registry`] — the name → parameter-set registry every consumer
//!   layer resolves technologies through (builtins + config-file-defined
//!   + programmatic [`registry::TechSpec`] entries).
//! * [`esram`] — electrical SRAM (Xilinx BRAM/URAM-class) parameters.
//! * [`osram`] — optical SRAM parameters ([14]'s device: 20 GHz, λ = 5,
//!   200 × 32-bit concurrent ports per 32 Kb block).
//! * [`posram`] — photonic in-memory-computing SRAM (`o-sram-imc`),
//!   modeled after arXiv 2503.18206.
//! * [`uram`] — URAM288-class electrical SRAM (`e-uram`): denser, deeper,
//!   still port-limited.
//! * [`dram`] — the DDR4 external-memory channel model (§III-A "inputs
//!   initially reside in the FPGA external memory").
//! * [`hierarchy`] — the configurable multi-level on-chip stack between
//!   the PE caches and DRAM (`--levels` grammar, per-level
//!   [`hierarchy::LevelReport`] accounting, double-buffer flag).
//! * [`sync`] — the synchronization interface between the 500 MHz
//!   electrical mesh and the 20 GHz optical memory clock domain (Fig. 2).

pub mod dram;
pub mod hierarchy;
pub mod esram;
pub mod osram;
pub mod posram;
pub mod registry;
pub mod sync;
pub mod tech;
pub mod uram;
