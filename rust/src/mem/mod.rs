//! Memory technology models (paper §II–III).
//!
//! * [`tech`] — the [`tech::MemTechnology`] device model shared by both
//!   SRAM variants: frequency, WDM wavelengths, ports, Eq. 1 bandwidth,
//!   Table III per-bit energies, Table IV per-bit area.
//! * [`esram`] — electrical SRAM (Xilinx BRAM/URAM-class) parameters.
//! * [`osram`] — optical SRAM parameters ([14]'s device: 20 GHz, λ = 5,
//!   200 × 32-bit concurrent ports per 32 Kb block).
//! * [`dram`] — the DDR4 external-memory channel model (§III-A "inputs
//!   initially reside in the FPGA external memory").
//! * [`sync`] — the synchronization interface between the 500 MHz
//!   electrical mesh and the 20 GHz optical memory clock domain (Fig. 2).

pub mod dram;
pub mod esram;
pub mod osram;
pub mod sync;
pub mod tech;
