//! The device-level memory technology model shared by every SRAM variant.
//!
//! Everything the simulator, the energy model (Eq. 2–3) and the area model
//! (Table IV) need about an on-chip memory is captured by one parameter
//! struct; the *only* difference between the baseline FPGA, the paper's
//! proposal, and any follow-up device is which parameter set is plugged in.
//!
//! Parameter sets are looked up by name through the open
//! [`registry`](crate::mem::registry) — `e-sram` and `o-sram` reproduce the
//! paper, and new technologies (photonic IMC variants, config-file-defined
//! devices) register without touching any consumer layer.

/// Device parameters of one on-chip memory block family.
///
/// Energies follow Table III's split (static vs switching, per bit); the
/// switching energy is further decomposed per Eq. 3 into the
/// optical↔electrical conversion part and the storage-cell part (for
/// E-SRAM the "conversion" part is the bit-line/sense-amp energy).
#[derive(Clone, Debug, PartialEq)]
pub struct MemTechnology {
    /// Registry name the consumers resolve this parameter set by
    /// (e.g. `e-sram`, `o-sram`, `o-sram-imc`).
    pub name: String,
    /// Memory core clock, Hz (f_optical in Eq. 1; for E-SRAM this equals
    /// the fabric clock — the array is synchronous with the mesh).
    pub freq_hz: f64,
    /// Number of WDM wavelengths λ usable concurrently (1 for E-SRAM).
    pub wavelengths: u32,
    /// Independent word accesses the block serves per *memory-core* cycle:
    /// λ for WDM optical arrays, the physical port count for electrical
    /// arrays (Eq. 1 generalized — for O-SRAM this equals λ, reproducing
    /// the paper's formula verbatim).
    pub lanes_per_core_cycle: u32,
    /// Port width z in bits.
    pub port_width_bits: u32,
    /// Physical concurrent read/write ports per block.
    pub ports_per_block: u32,
    /// Capacity of one block in bits (32 Kb for O-SRAM per §III-A;
    /// 36 Kb BRAM-class for E-SRAM).
    pub block_bits: u64,
    /// Word lines per block (1024 × 32 b for the O-SRAM of Fig. 2).
    pub data_lines: u32,
    /// Access latency in *memory-core* cycles (tag or data array read).
    pub access_latency_cycles: u32,

    // --- Table III energies (pJ, per bit) ---
    /// Static power, pJ per bit per *fabric* cycle (Table III "Static").
    pub static_pj_per_bit_cycle: f64,
    /// Switching energy per accessed bit (Table III "Switching"), total.
    pub switching_pj_per_bit: f64,
    /// Eq. 3 decomposition: conversion (O↔E or bitline/sense-amp) part.
    pub conversion_pj_per_bit: f64,
    /// Eq. 3 decomposition: storage-cell part.
    pub storage_pj_per_bit: f64,

    // --- Table IV area ---
    /// Layout area per bit, µm² (array + periphery, amortized).
    pub area_um2_per_bit: f64,
}

impl MemTechnology {
    /// Equation 1: bits deliverable to the electrical compute elements per
    /// electrical cycle, **per block**:
    /// `b_process = λ × f_optical × z / f_electrical`
    /// with λ generalized to [`lanes_per_core_cycle`](Self::lanes_per_core_cycle)
    /// (= λ for the O-SRAM, = physical ports for the synchronous E-SRAM).
    pub fn bits_per_fabric_cycle(&self, fabric_hz: f64) -> f64 {
        assert!(fabric_hz > 0.0);
        self.lanes_per_core_cycle as f64 * self.freq_hz * self.port_width_bits as f64 / fabric_hz
    }

    /// Independent 32-bit word accesses a block can serve per fabric cycle
    /// (the simulator's port-arbitration unit).
    pub fn words_per_fabric_cycle(&self, fabric_hz: f64) -> f64 {
        self.bits_per_fabric_cycle(fabric_hz) / self.port_width_bits as f64
    }

    /// Access latency seen from the fabric, in fabric cycles (ceil of the
    /// core-cycle latency converted across the frequency ratio; min 1).
    pub fn access_latency_fabric_cycles(&self, fabric_hz: f64) -> f64 {
        (self.access_latency_cycles as f64 * fabric_hz / self.freq_hz).max(1.0)
    }

    /// Blocks needed to store `bits` of state.
    pub fn blocks_for_bits(&self, bits: u64) -> u64 {
        bits.div_ceil(self.block_bits)
    }

    /// Static power of `bits` of this memory, in pJ per fabric cycle
    /// (Eq. 3: `P_static = S_total × (p̂_static_optical + p̂_static_electrical)`;
    /// the two leakage terms are folded into `static_pj_per_bit_cycle`).
    pub fn static_pj_per_cycle(&self, bits: u64) -> f64 {
        bits as f64 * self.static_pj_per_bit_cycle
    }

    /// Switching energy for accessing `bits` of data (Eq. 3:
    /// `P_switching = S_active × (p̂_conversion + p̂_storage)`).
    pub fn switching_pj(&self, bits: u64) -> f64 {
        bits as f64 * self.switching_pj_per_bit
    }

    /// Layout area of `bits` of this memory, mm².
    pub fn area_mm2(&self, bits: u64) -> f64 {
        bits as f64 * self.area_um2_per_bit * 1e-6
    }

    /// Is the array fast enough relative to the fabric (≥ 4×) to hide
    /// multi-step array sequencing inside one fabric cycle? This single
    /// predicate drives every "electrical vs optical" structural choice in
    /// the consumer layers — tag→data serialization, data-array bank
    /// cascading, and the MSHR-depth DRAM-overlap derate — so a new
    /// registry technology picks up the right behaviour from its clock
    /// alone, without any per-name special-casing.
    pub fn is_fast_array(&self, fabric_hz: f64) -> bool {
        self.freq_hz >= 4.0 * fabric_hz
    }

    /// Can a cache built from this memory serialize tag→data within one
    /// fabric cycle? A synchronous (fabric-speed) array must read all
    /// `assoc` candidate ways speculatively in parallel with the tag
    /// compare (Fig. 6) — burning `assoc×` the data-array energy per
    /// lookup; an array ≥ 4× faster than the fabric resolves the tag first
    /// and reads only the matching way with no throughput loss.
    pub fn serial_tag_data(&self, fabric_hz: f64) -> bool {
        self.is_fast_array(fabric_hz)
    }
}

/// The fabric (electrical mesh) clock the paper models: 500 MHz (§V-A).
pub const FABRIC_HZ: f64 = 500e6;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::esram::esram;
    use crate::mem::osram::osram;

    #[test]
    fn eq1_matches_paper_example() {
        // §III-A: λ=5, f_opt=20 GHz, z=32, f_elec=500 MHz ⇒ 6400 bits/cycle
        // (= the 200 × 32 b parallel ports claim).
        let o = osram();
        let b = o.bits_per_fabric_cycle(FABRIC_HZ);
        assert!((b - 6400.0).abs() < 1e-9, "b_process = {b}");
        assert!((o.words_per_fabric_cycle(FABRIC_HZ) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn esram_is_port_limited() {
        let e = esram();
        // dual-port 32b at fabric clock: 64 bits per cycle
        assert!((e.bits_per_fabric_cycle(FABRIC_HZ) - 64.0).abs() < 1e-9);
        assert!((e.words_per_fabric_cycle(FABRIC_HZ) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn effective_ports_match_paper_claim() {
        // §III-A: "each O-SRAM consists of 200 parallel read-write ports"
        // — 200 = λ × f_opt / f_elec is exactly Eq. 1's word count.
        let o = osram();
        assert_eq!(o.ports_per_block, 200);
        assert_eq!(
            o.ports_per_block as f64,
            o.lanes_per_core_cycle as f64 * o.freq_hz / FABRIC_HZ
        );
    }

    #[test]
    fn latency_converts_across_domains() {
        let o = osram();
        // 20 GHz core, 500 MHz fabric: a 2-core-cycle access is well under
        // one fabric cycle ⇒ clamps to 1.
        assert_eq!(o.access_latency_fabric_cycles(FABRIC_HZ), 1.0);
        let e = esram();
        // synchronous: latency in fabric cycles = core cycles
        assert_eq!(e.access_latency_fabric_cycles(FABRIC_HZ), e.access_latency_cycles as f64);
    }

    #[test]
    fn blocks_for_bits_rounds_up() {
        let o = osram();
        assert_eq!(o.blocks_for_bits(1), 1);
        assert_eq!(o.blocks_for_bits(o.block_bits), 1);
        assert_eq!(o.blocks_for_bits(o.block_bits + 1), 2);
    }

    #[test]
    fn energy_helpers_scale_linearly() {
        let o = osram();
        assert!((o.switching_pj(2000) - 2.0 * o.switching_pj(1000)).abs() < 1e-9);
        assert!((o.static_pj_per_cycle(2000) - 2.0 * o.static_pj_per_cycle(1000)).abs() < 1e-12);
    }

    #[test]
    fn switching_decomposition_sums() {
        for m in [esram(), osram()] {
            assert!(
                (m.conversion_pj_per_bit + m.storage_pj_per_bit - m.switching_pj_per_bit).abs()
                    < 1e-9,
                "{}: Eq.3 decomposition must sum to Table III switching",
                m.name
            );
        }
    }

    #[test]
    fn fast_array_predicate_splits_the_builtin_pair() {
        assert!(!esram().is_fast_array(FABRIC_HZ));
        assert!(osram().is_fast_array(FABRIC_HZ));
        // the predicate is what serial_tag_data forwards to
        assert_eq!(osram().serial_tag_data(FABRIC_HZ), osram().is_fast_array(FABRIC_HZ));
    }
}
