//! E-SRAM (electrical SRAM) device parameters — the baseline (§V-A3).
//!
//! Models the BRAM/URAM-class 6T SRAM of a data-center FPGA, synthesized at
//! the GlobalFoundries 12 nm node in the paper. The array is synchronous
//! with the 500 MHz fabric, dual-ported (true dual-port BRAM), and pays the
//! Table III electrical energy figures. Area comes from Table IV's
//! 43.2 mm² for 54 MB.

use crate::mem::tech::{MemTechnology, FABRIC_HZ};

/// E-SRAM operating frequency: synchronous with the fabric (§V-A).
pub const ESRAM_FREQ_HZ: f64 = FABRIC_HZ;
/// Electrical memory has a single "wavelength".
pub const ESRAM_WAVELENGTHS: u32 = 1;
/// Port width matched to the O-SRAM comparison (32-bit words).
pub const ESRAM_PORT_WIDTH: u32 = 32;
/// True dual-port (Xilinx BRAM): 2 independent read/write ports.
pub const ESRAM_PORTS: u32 = 2;
/// Block capacity: 36 Kb (Xilinx BRAM36; the paper replaces "the same
/// amount" of memory, so capacity bookkeeping uses bits, not blocks).
pub const ESRAM_BLOCK_BITS: u64 = 36 * 1024;
/// 1024 lines of 36 b in BRAM36 configuration (32 data + 4 parity); the
/// model uses the 32 usable data bits.
pub const ESRAM_DATA_LINES: u32 = 1024;

/// Table III, electrical technology column.
pub const ESRAM_STATIC_PJ_PER_BIT_CYCLE: f64 = 1.175e-6;
pub const ESRAM_SWITCHING_PJ_PER_BIT: f64 = 4.68;
/// Eq. 3 split for the electrical array: bit-line charge/discharge +
/// sense amplifiers dominate read/write energy; the cross-coupled cell
/// flip itself is the smaller share. 3.80 / 0.88 keeps the Table III total.
pub const ESRAM_CONVERSION_PJ_PER_BIT: f64 = 3.80;
pub const ESRAM_STORAGE_PJ_PER_BIT: f64 = 0.88;

/// Table IV: 54 MB of E-SRAM occupy 43.2 mm².
pub const ESRAM_AREA_UM2_PER_BIT: f64 = 43.2 * 1e6 / (54.0 * 1024.0 * 1024.0 * 8.0);

/// Synchronous single-cycle array access at 500 MHz.
pub const ESRAM_ACCESS_LATENCY_CYCLES: u32 = 1;

/// The E-SRAM `MemTechnology` parameter set.
pub fn esram() -> MemTechnology {
    MemTechnology {
        name: "e-sram".to_string(),
        freq_hz: ESRAM_FREQ_HZ,
        wavelengths: ESRAM_WAVELENGTHS,
        lanes_per_core_cycle: ESRAM_PORTS,
        port_width_bits: ESRAM_PORT_WIDTH,
        ports_per_block: ESRAM_PORTS,
        block_bits: ESRAM_BLOCK_BITS,
        data_lines: ESRAM_DATA_LINES,
        access_latency_cycles: ESRAM_ACCESS_LATENCY_CYCLES,
        static_pj_per_bit_cycle: ESRAM_STATIC_PJ_PER_BIT_CYCLE,
        switching_pj_per_bit: ESRAM_SWITCHING_PJ_PER_BIT,
        conversion_pj_per_bit: ESRAM_CONVERSION_PJ_PER_BIT,
        storage_pj_per_bit: ESRAM_STORAGE_PJ_PER_BIT,
        area_um2_per_bit: ESRAM_AREA_UM2_PER_BIT,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iii_constants() {
        let e = esram();
        assert_eq!(e.static_pj_per_bit_cycle, 1.175e-6);
        assert_eq!(e.switching_pj_per_bit, 4.68);
    }

    #[test]
    fn table_iv_area_roundtrips() {
        let bits = 54u64 * 1024 * 1024 * 8;
        let area = esram().area_mm2(bits);
        assert!((area - 43.2).abs() / 43.2 < 1e-9, "area={area}");
    }

    #[test]
    fn per_bit_area_plausible_for_12nm() {
        // 12 nm SRAM macro density is ~0.04–0.15 µm²/bit with periphery
        let a = ESRAM_AREA_UM2_PER_BIT;
        assert!((0.02..0.2).contains(&a), "{a} µm²/bit");
    }

    #[test]
    fn synchronous_with_fabric() {
        let e = esram();
        assert_eq!(e.freq_hz, FABRIC_HZ);
        assert_eq!(e.words_per_fabric_cycle(FABRIC_HZ), 2.0);
    }
}
