//! DDR4 external-memory channel model (§III-A: "FPGA external memory
//! contains multiple DRAMs which use DDR4 technology").
//!
//! The Alveo U250-class card the paper parameterizes against has 4 DDR4-2400
//! 64-bit channels (one per PE in the Fig. 4 design). The model is a
//! throughput/latency hybrid: streams are charged at sustained bandwidth,
//! random (element-wise) accesses are charged the row-buffer-aware service
//! time, and every access accrues interface energy. This is the shared
//! substrate both memory technologies see — external memory is *identical*
//! in the two systems, which is exactly why DRAM-bound tensors (NELL-1,
//! DELICIOUS) show little O-SRAM speedup in Fig. 7.

use crate::mem::tech::FABRIC_HZ;

/// DDR4 channel parameters (DDR4-2400, 64-bit, typical data-center card).
#[derive(Clone, Debug, PartialEq)]
pub struct DramConfig {
    /// Peak transfer rate, bytes/s (2400 MT/s × 8 B = 19.2 GB/s).
    pub peak_bytes_per_s: f64,
    /// Sustained fraction of peak for long sequential streams.
    pub stream_efficiency: f64,
    /// Burst granularity in bytes (BL8 × 64-bit bus = 64 B — deliberately
    /// equal to the cache line of Table I).
    pub burst_bytes: u32,
    /// Row-buffer hit service latency, ns (CAS-bound).
    pub row_hit_ns: f64,
    /// Row-buffer miss service latency, ns (precharge + activate + CAS).
    pub row_miss_ns: f64,
    /// Probability an element-wise access hits an open row (captures
    /// residual locality of the index stream).
    pub random_row_hit_rate: f64,
    /// Effective overlap of independent random accesses (bank-level
    /// parallelism × memory-controller reordering): the channel sustains
    /// `overlap` in-flight requests, so the per-access *occupancy* is the
    /// service time divided by this factor.
    pub random_overlap: f64,
    /// Interface + array energy per transferred bit, pJ (DDR4 device-level
    /// array access + I/O ≈ 4 pJ/bit; the paper's E_DRAM-FPGA term).
    pub energy_pj_per_bit: f64,
    /// Extra energy per row activation, pJ.
    pub activate_pj: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            peak_bytes_per_s: 19.2e9,
            stream_efficiency: 0.85,
            burst_bytes: 64,
            row_hit_ns: 15.0,
            row_miss_ns: 45.0,
            random_row_hit_rate: 0.35,
            random_overlap: 4.0,
            energy_pj_per_bit: 4.0,
            activate_pj: 900.0,
        }
    }
}

impl DramConfig {
    /// Sustained stream bandwidth in bytes per fabric cycle.
    pub fn stream_bytes_per_cycle(&self) -> f64 {
        self.peak_bytes_per_s * self.stream_efficiency / FABRIC_HZ
    }

    /// Fabric cycles to stream `bytes` sequentially (DMA stream transfers).
    pub fn stream_cycles(&self, bytes: u64) -> f64 {
        bytes as f64 / self.stream_bytes_per_cycle()
    }

    /// Fabric cycles for one element-wise access of `bytes` (≤ one burst:
    /// a 64 B burst is the minimum transfer; larger requests take multiple
    /// bursts pipelined at the row-hit rate).
    pub fn random_access_cycles(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            // an empty request moves no bursts and must cost no time
            // (the pre-fix model charged a full first-burst latency here)
            return 0.0;
        }
        let bursts = (bytes as f64 / self.burst_bytes as f64).ceil();
        let first_ns = self.random_row_hit_rate * self.row_hit_ns
            + (1.0 - self.random_row_hit_rate) * self.row_miss_ns;
        // follow-on bursts in the same request stay in the open row
        let ns = first_ns + (bursts - 1.0) * self.row_hit_ns;
        // bank-level parallelism overlaps independent requests
        ns * 1e-9 * FABRIC_HZ / self.random_overlap
    }

    /// Interface energy for transferring `bytes`, pJ (plus expected
    /// activation energy for `accesses` independent requests).
    pub fn transfer_pj(&self, bytes: u64, accesses: u64) -> f64 {
        let miss_rate = 1.0 - self.random_row_hit_rate;
        bytes as f64 * 8.0 * self.energy_pj_per_bit
            + accesses as f64 * miss_rate * self.activate_pj
    }
}

/// Mutable per-channel accounting used by the simulator: busy time and
/// traffic counters accumulate as the engine charges work to the channel.
#[derive(Clone, Debug, Default)]
pub struct DramChannelState {
    pub busy_cycles: f64,
    pub bytes_streamed: u64,
    pub bytes_random: u64,
    pub random_accesses: u64,
}

impl DramChannelState {
    /// Charge a sequential stream of `bytes`; returns cycles consumed.
    pub fn stream(&mut self, cfg: &DramConfig, bytes: u64) -> f64 {
        let c = cfg.stream_cycles(bytes);
        self.busy_cycles += c;
        self.bytes_streamed += bytes;
        c
    }

    /// Charge one element-wise access of `bytes`; returns cycles consumed.
    pub fn random_access(&mut self, cfg: &DramConfig, bytes: u64) -> f64 {
        let c = cfg.random_access_cycles(bytes);
        self.busy_cycles += c;
        self.bytes_random += bytes;
        self.random_accesses += 1;
        c
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_streamed + self.bytes_random
    }

    /// Total DRAM-side energy (the paper's `E_DRAM-FPGA`), pJ.
    pub fn energy_pj(&self, cfg: &DramConfig) -> f64 {
        cfg.transfer_pj(self.bytes_streamed, 0)
            + cfg.transfer_pj(self.bytes_random, self.random_accesses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_bandwidth_matches_config() {
        let d = DramConfig::default();
        // 19.2 GB/s × 0.85 at 500 MHz ⇒ 32.64 B/cycle
        assert!((d.stream_bytes_per_cycle() - 32.64).abs() < 1e-9);
        // 1 MiB stream
        let cyc = d.stream_cycles(1 << 20);
        assert!((cyc - (1 << 20) as f64 / 32.64).abs() < 1e-6);
    }

    #[test]
    fn random_slower_than_stream_per_byte() {
        let d = DramConfig::default();
        let per_byte_stream = d.stream_cycles(64) / 64.0;
        let per_byte_random = d.random_access_cycles(64) / 64.0;
        assert!(
            per_byte_random > 2.0 * per_byte_stream,
            "random {per_byte_random} vs stream {per_byte_stream}"
        );
    }

    #[test]
    fn random_access_latency_band() {
        let d = DramConfig::default();
        // expected occupancy between overlapped row-hit and row-miss extremes
        let cyc = d.random_access_cycles(64);
        let lo = d.row_hit_ns * 1e-9 * FABRIC_HZ / d.random_overlap;
        let hi = d.row_miss_ns * 1e-9 * FABRIC_HZ / d.random_overlap;
        assert!(cyc > lo && cyc < hi, "{cyc} not in ({lo}, {hi})");
    }

    #[test]
    fn overlap_divides_occupancy() {
        let mut d = DramConfig::default();
        let base = d.random_access_cycles(64);
        d.random_overlap = 8.0;
        assert!((d.random_access_cycles(64) - base / 2.0).abs() < 1e-12);
    }

    #[test]
    fn multi_burst_requests_pipeline() {
        let d = DramConfig::default();
        let one = d.random_access_cycles(64);
        let four = d.random_access_cycles(256);
        assert!(four < 4.0 * one, "follow-on bursts must be cheaper");
        assert!(four > one);
    }

    #[test]
    fn channel_state_accumulates_and_energizes() {
        let d = DramConfig::default();
        let mut ch = DramChannelState::default();
        ch.stream(&d, 1000);
        ch.random_access(&d, 64);
        ch.random_access(&d, 64);
        assert_eq!(ch.total_bytes(), 1128);
        assert_eq!(ch.random_accesses, 2);
        assert!(ch.busy_cycles > 0.0);
        let e = ch.energy_pj(&d);
        // at least the pure interface energy
        assert!(e >= 1128.0 * 8.0 * d.energy_pj_per_bit);
        // activation overhead present
        assert!(e > 1128.0 * 8.0 * d.energy_pj_per_bit + 0.5 * d.activate_pj);
    }

    #[test]
    fn energy_scales_with_traffic() {
        let d = DramConfig::default();
        assert!(d.transfer_pj(2000, 0) == 2.0 * d.transfer_pj(1000, 0));
    }

    #[test]
    fn zero_byte_access_is_free() {
        // the empty-pop analog: charging a request that carries no data
        // used to cost a full first-burst latency
        let d = DramConfig::default();
        assert_eq!(d.random_access_cycles(0), 0.0);
        assert_eq!(d.stream_cycles(0), 0.0);
        let mut ch = DramChannelState::default();
        assert_eq!(ch.random_access(&d, 0), 0.0);
        assert_eq!(ch.stream(&d, 0), 0.0);
        // counters still record the (degenerate) events, time does not
        assert_eq!(ch.random_accesses, 1);
        assert_eq!(ch.total_bytes(), 0);
        assert_eq!(ch.busy_cycles, 0.0);
    }

    #[test]
    fn burst_boundary_arrivals_round_exactly() {
        let d = DramConfig::default();
        let b = d.burst_bytes as u64;
        // a request ending exactly on a burst boundary must not charge
        // the next burst ...
        assert_eq!(d.random_access_cycles(b).to_bits(), d.random_access_cycles(1).to_bits());
        assert_eq!(
            d.random_access_cycles(2 * b).to_bits(),
            d.random_access_cycles(b + 1).to_bits()
        );
        // ... and one byte past it must
        assert!(d.random_access_cycles(b + 1) > d.random_access_cycles(b));
        // each follow-on burst is exactly one pipelined row hit
        let inc = d.random_access_cycles(2 * b) - d.random_access_cycles(b);
        let hit = d.row_hit_ns * 1e-9 * FABRIC_HZ / d.random_overlap;
        assert!((inc - hit).abs() < 1e-12, "{inc} vs {hit}");
    }
}
