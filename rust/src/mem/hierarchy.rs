//! Multi-level on-chip memory hierarchy between the PE caches and DRAM.
//!
//! The paper prices a single cache level in front of one FIFO DRAM
//! channel. A placeable design streams through a deeper stack — DRAM →
//! shared SRAM → per-PE local memory — with per-level double buffering
//! (the KULeuven-MICAS `fpga_asb.py` shape). This module holds the
//! *configuration* and *reporting* types for that stack:
//!
//! - [`MemLevelSpec`] — one level: capacity, banks, line size and the
//!   `double_buffer` flag that lets the event engine overlap a level's
//!   fill latency with its drain.
//! - [`parse_levels`] / [`format_levels`] — the `--levels` CLI grammar
//!   (`name:capacity[:Nbanks][:lineN][:db]`, outermost/DRAM-side first).
//! - [`LevelReport`] — per-level hit/traffic/energy accounting carried
//!   by `PeReport` and rolled up through `ModeReport` / `SimReport`.
//!
//! The functional and timing models live in `controller::mc` (which
//! probes the stack innermost-first on a PE-cache miss) and `sim::event`
//! (which arbitrates each level as a banked-throughput FIFO). An empty
//! level stack is the *degenerate* configuration: the controller and
//! both engines execute exactly the pre-hierarchy code paths, so the
//! paper-default output is bit-identical to the single-level model
//! (pinned by `tests/golden.rs`).
//!
//! Level accounting is purely *functional* — per-level accesses, hits,
//! misses, traffic and words are integer counters carried by
//! `controller::mc::FunctionalCounts` and priced into busy cycles at
//! read time. That is what lets the reuse-distance profiler
//! ([`crate::sim::profile`]) capture a leveled geometry's counts in one
//! stream walk (it runs a live controller per leveled config) and
//! reprice them later under any technology without re-walking.

use std::fmt;

/// One level of the on-chip memory hierarchy, DRAM-side first in
/// `AcceleratorConfig::levels` (index 0 is nearest DRAM, the last entry
/// is nearest the PE caches).
#[derive(Clone, Debug, PartialEq)]
pub struct MemLevelSpec {
    /// Human-readable level name (unique within a stack).
    pub name: String,
    /// Data capacity in bytes; must be `line × 2^k` for the functional
    /// set-associative model.
    pub capacity_bytes: u64,
    /// Bank count: widens the level's serve/fill throughput in the
    /// timing model (`ArrayTiming`), exactly like the PE-cache banks.
    pub banks: usize,
    /// Level line (transfer block) in bytes. `None` inherits the PE
    /// cache line. When set it must be a power-of-two multiple of the
    /// PE cache line.
    pub line_bytes: Option<usize>,
    /// Double buffering: the event engine overlaps this level's fill
    /// latency with its drain, so a fill never sits on the request's
    /// critical path (throughput is still charged).
    pub double_buffer: bool,
}

impl MemLevelSpec {
    /// A single-bank, inherit-line, no-double-buffer level.
    pub fn new(name: &str, capacity_bytes: u64) -> Self {
        MemLevelSpec {
            name: name.to_string(),
            capacity_bytes,
            banks: 1,
            line_bytes: None,
            double_buffer: false,
        }
    }

    /// The level line in bytes, with `default_line` (the PE cache line)
    /// substituted when the spec inherits it.
    pub fn resolved_line_bytes(&self, default_line: usize) -> usize {
        self.line_bytes.unwrap_or(default_line)
    }
}

impl fmt::Display for MemLevelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.name, format_size(self.capacity_bytes))?;
        if self.banks != 1 {
            write!(f, ":{}banks", self.banks)?;
        }
        if let Some(line) = self.line_bytes {
            write!(f, ":line{line}")?;
        }
        if self.double_buffer {
            write!(f, ":db")?;
        }
        Ok(())
    }
}

/// Render a stack in the [`parse_levels`] grammar (round-trips exactly).
pub fn format_levels(levels: &[MemLevelSpec]) -> String {
    levels.iter().map(|l| l.to_string()).collect::<Vec<_>>().join(",")
}

fn format_size(bytes: u64) -> String {
    const MIB: u64 = 1024 * 1024;
    const KIB: u64 = 1024;
    if bytes >= MIB && bytes % MIB == 0 {
        format!("{}MiB", bytes / MIB)
    } else if bytes >= KIB && bytes % KIB == 0 {
        format!("{}KiB", bytes / KIB)
    } else {
        format!("{bytes}")
    }
}

fn parse_size(s: &str) -> Result<u64, String> {
    let lower = s.to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = lower.strip_suffix("gib").or(lower.strip_suffix("gb")) {
        (d, 1024u64 * 1024 * 1024)
    } else if let Some(d) = lower.strip_suffix("mib").or(lower.strip_suffix("mb")) {
        (d, 1024 * 1024)
    } else if let Some(d) = lower.strip_suffix("kib").or(lower.strip_suffix("kb")) {
        (d, 1024)
    } else if let Some(d) = lower.strip_suffix('g') {
        (d, 1024 * 1024 * 1024)
    } else if let Some(d) = lower.strip_suffix('m') {
        (d, 1024 * 1024)
    } else if let Some(d) = lower.strip_suffix('k') {
        (d, 1024)
    } else if let Some(d) = lower.strip_suffix('b') {
        (d, 1)
    } else {
        (lower.as_str(), 1)
    };
    let n: u64 = digits
        .parse()
        .map_err(|_| format!("cannot parse size `{s}` (expected e.g. 4096, 256KiB, 4MiB)"))?;
    n.checked_mul(mult).ok_or_else(|| format!("size `{s}` overflows"))
}

/// Parse the `--levels` grammar: comma-separated level specs, each
/// `name:capacity[:Nbanks][:lineN][:db]` with the post-capacity tokens
/// in any order. Capacities accept `KiB`/`MiB`/`GiB` suffixes. Levels
/// are listed DRAM-side (outermost) first, matching
/// `AcceleratorConfig::levels`. An empty string yields the degenerate
/// (empty) stack.
///
/// ```
/// use photon_mttkrp::mem::hierarchy::parse_levels;
/// let stack = parse_levels("sram:256KiB:8banks,local:4KiB:db").unwrap();
/// assert_eq!(stack.len(), 2);
/// assert_eq!(stack[0].capacity_bytes, 256 * 1024);
/// assert_eq!(stack[0].banks, 8);
/// assert!(stack[1].double_buffer);
/// ```
pub fn parse_levels(s: &str) -> Result<Vec<MemLevelSpec>, String> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    let mut levels = Vec::new();
    for spec in s.split(',') {
        let spec = spec.trim();
        let mut parts = spec.split(':');
        let name = parts.next().unwrap_or("").trim();
        if name.is_empty() {
            return Err(format!("level `{spec}`: empty name"));
        }
        if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_') {
            return Err(format!(
                "level `{spec}`: name `{name}` must be alphanumeric/-/_"
            ));
        }
        let cap = parts
            .next()
            .ok_or_else(|| format!("level `{spec}`: missing capacity (name:capacity[...])"))?;
        let capacity_bytes = parse_size(cap.trim()).map_err(|e| format!("level `{spec}`: {e}"))?;
        if capacity_bytes == 0 {
            return Err(format!("level `{spec}`: capacity must be positive"));
        }
        let mut level = MemLevelSpec::new(name, capacity_bytes);
        for tok in parts {
            let tok = tok.trim();
            if tok == "db" {
                level.double_buffer = true;
            } else if let Some(n) = tok.strip_suffix("banks").or(tok.strip_suffix("bank")) {
                level.banks = n
                    .parse()
                    .map_err(|_| format!("level `{spec}`: bad bank count `{tok}`"))?;
                if level.banks == 0 {
                    return Err(format!("level `{spec}`: bank count must be positive"));
                }
            } else if let Some(n) = tok.strip_prefix("line") {
                let line = parse_size(n).map_err(|e| format!("level `{spec}`: {e}"))?;
                if line == 0 {
                    return Err(format!("level `{spec}`: line must be positive"));
                }
                level.line_bytes = Some(line as usize);
            } else {
                return Err(format!(
                    "level `{spec}`: unknown token `{tok}` (expected Nbanks, lineN or db)"
                ));
            }
        }
        if levels.iter().any(|l: &MemLevelSpec| l.name == level.name) {
            return Err(format!("duplicate level name `{}`", level.name));
        }
        levels.push(level);
    }
    Ok(levels)
}

/// Per-level hit/traffic/energy accounting for one simulated PE (or an
/// aggregate of PEs/modes — see the merge helpers). Produced by
/// `MemoryController::level_reports` and carried on `PeReport::levels`
/// in the same stack order as `AcceleratorConfig::levels` (outermost
/// first).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LevelReport {
    /// Level name from the spec.
    pub name: String,
    /// Configured capacity in bytes (spec echo).
    pub capacity_bytes: u64,
    /// Resolved level line in bytes.
    pub line_bytes: u64,
    /// Whether the level double-buffers its fills (spec echo).
    pub double_buffer: bool,
    /// Lookups that reached this level (== misses of the next-inner
    /// level; the innermost level sees every PE-cache line fill).
    pub accesses: u64,
    /// Lookups served from this level's array.
    pub hits: u64,
    /// Lookups forwarded outward (to the next level or DRAM).
    pub misses: u64,
    /// Bytes delivered inward: `accesses × inner request line`.
    pub traffic_bytes: u64,
    /// Active 32-bit words moved through this level's array (reads of
    /// the inner request on every access, plus line fills on misses).
    /// Feeds the Eq. 3 switching-energy term exactly like cache words.
    pub words: u64,
    /// Array occupancy charged to this level, in fabric cycles.
    pub busy_cycles: f64,
}

impl LevelReport {
    /// Fraction of accesses served from this level.
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }

    /// Fold another report for the *same* level from a concurrently
    /// executing unit (PEs within a mode): counters add, busy takes the
    /// max (PEs run in parallel, like `ModeReport::runtime_cycles`).
    pub fn absorb_parallel(&mut self, other: &LevelReport) {
        self.accesses += other.accesses;
        self.hits += other.hits;
        self.misses += other.misses;
        self.traffic_bytes += other.traffic_bytes;
        self.words += other.words;
        self.busy_cycles = self.busy_cycles.max(other.busy_cycles);
    }

    /// Fold another report for the *same* level from a sequentially
    /// executed phase (modes within a run): counters and busy both add.
    pub fn absorb_serial(&mut self, other: &LevelReport) {
        self.accesses += other.accesses;
        self.hits += other.hits;
        self.misses += other.misses;
        self.traffic_bytes += other.traffic_bytes;
        self.words += other.words;
        self.busy_cycles += other.busy_cycles;
    }
}

/// Merge a unit's level stack into an accumulator (same stack order).
/// `parallel` selects [`LevelReport::absorb_parallel`] (PEs) vs
/// [`LevelReport::absorb_serial`] (modes). An empty accumulator clones
/// the incoming stack.
pub fn merge_level_reports(acc: &mut Vec<LevelReport>, other: &[LevelReport], parallel: bool) {
    if acc.is_empty() {
        acc.extend(other.iter().cloned());
        return;
    }
    debug_assert_eq!(acc.len(), other.len(), "level stacks must match to merge");
    for (a, o) in acc.iter_mut().zip(other) {
        if parallel {
            a.absorb_parallel(o);
        } else {
            a.absorb_serial(o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let stack = parse_levels("sram:256KiB:8banks,local:4KiB:db").unwrap();
        assert_eq!(stack.len(), 2);
        assert_eq!(stack[0].name, "sram");
        assert_eq!(stack[0].capacity_bytes, 256 * 1024);
        assert_eq!(stack[0].banks, 8);
        assert!(!stack[0].double_buffer);
        assert_eq!(stack[1].name, "local");
        assert_eq!(stack[1].capacity_bytes, 4 * 1024);
        assert_eq!(stack[1].banks, 1);
        assert!(stack[1].double_buffer);
        assert_eq!(stack[1].line_bytes, None);
    }

    #[test]
    fn tokens_after_capacity_commute() {
        let a = parse_levels("l0:64KiB:db:4banks:line256").unwrap();
        let b = parse_levels("l0:64KiB:line256:4banks:db").unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].line_bytes, Some(256));
        assert_eq!(a[0].banks, 4);
        assert!(a[0].double_buffer);
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("4096").unwrap(), 4096);
        assert_eq!(parse_size("64KiB").unwrap(), 64 * 1024);
        assert_eq!(parse_size("64kb").unwrap(), 64 * 1024);
        assert_eq!(parse_size("2MiB").unwrap(), 2 * 1024 * 1024);
        assert_eq!(parse_size("1g").unwrap(), 1024 * 1024 * 1024);
        assert_eq!(parse_size("128b").unwrap(), 128);
        assert!(parse_size("four").is_err());
    }

    #[test]
    fn format_round_trips() {
        let src = "outer:2MiB:8banks:line512,mid:64KiB:line128:db,inner:4KiB";
        let stack = parse_levels(src).unwrap();
        let rendered = format_levels(&stack);
        assert_eq!(parse_levels(&rendered).unwrap(), stack);
        // and the canonical rendering is stable under re-rendering
        assert_eq!(format_levels(&parse_levels(&rendered).unwrap()), rendered);
    }

    #[test]
    fn empty_is_degenerate() {
        assert!(parse_levels("").unwrap().is_empty());
        assert!(parse_levels("   ").unwrap().is_empty());
        assert_eq!(format_levels(&[]), "");
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(parse_levels("noname").is_err(), "missing capacity");
        assert!(parse_levels(":4KiB").is_err(), "empty name");
        assert!(parse_levels("a b:4KiB").is_err(), "bad name chars");
        assert!(parse_levels("l0:0").is_err(), "zero capacity");
        assert!(parse_levels("l0:4KiB:0banks").is_err(), "zero banks");
        assert!(parse_levels("l0:4KiB:line0").is_err(), "zero line");
        assert!(parse_levels("l0:4KiB:bogus").is_err(), "unknown token");
        assert!(parse_levels("l0:4KiB,l0:8KiB").is_err(), "duplicate name");
        assert!(parse_levels("l0:4QiB").is_err(), "bad size suffix");
    }

    #[test]
    fn level_report_merges() {
        let a = LevelReport {
            name: "sram".into(),
            capacity_bytes: 1024,
            line_bytes: 64,
            double_buffer: false,
            accesses: 10,
            hits: 6,
            misses: 4,
            traffic_bytes: 640,
            words: 200,
            busy_cycles: 5.0,
        };
        let mut p = a.clone();
        p.absorb_parallel(&a);
        assert_eq!(p.accesses, 20);
        assert_eq!(p.hits, 12);
        assert_eq!(p.busy_cycles, 5.0, "parallel busy is a max");
        let mut s = a.clone();
        s.absorb_serial(&a);
        assert_eq!(s.accesses, 20);
        assert_eq!(s.busy_cycles, 10.0, "serial busy accumulates");
        assert!((a.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(LevelReport::default().hit_rate(), 0.0);
    }

    #[test]
    fn merge_helper_clones_into_empty_and_folds() {
        let stack = vec![LevelReport { accesses: 3, busy_cycles: 2.0, ..Default::default() }];
        let mut acc = Vec::new();
        merge_level_reports(&mut acc, &stack, true);
        assert_eq!(acc, stack);
        merge_level_reports(&mut acc, &stack, false);
        assert_eq!(acc[0].accesses, 6);
        assert_eq!(acc[0].busy_cycles, 4.0);
    }
}
